"""Render EXPERIMENTS.md roofline tables from results/dryrun/*.json."""
import glob
import json
import os
import sys

DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def rows(tag):
    out = []
    for p in sorted(glob.glob(os.path.join(DIR, f"*-{tag}.json"))):
        out.append(json.load(open(p)))
    return out


def table(tag, label):
    print(f"\n### {label}\n")
    print("| arch | shape | dominant | T_comp s | T_mem s | T_coll s | "
          "frac | MODEL/HLO flops | GB/dev | fits 16GB | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    cells = rows(tag)
    ran = [c for c in cells if not c.get("skipped")]
    ran.sort(key=lambda c: (c["arch"], c["shape"]))
    for c in ran:
        rl = c["roofline"]
        print(f"| {c['arch']} | {c['shape']} | {rl['dominant']} | "
              f"{rl['t_comp']:.2f} | {rl['t_mem']:.2f} | {rl['t_coll']:.2f} | "
              f"{rl['roofline_fraction']:.3f} | {rl['flops_ratio']:.3f} | "
              f"{c['bytes_per_device'] / 1e9:.1f} | "
              f"{'yes' if c['fits_hbm'] else 'NO'} | {c['t_compile_s']} |")
    for c in cells:
        if c.get("skipped"):
            print(f"| {c['arch']} | {c['shape']} | — skipped: "
                  f"{c['reason']} | | | | | | | | |")
    print(f"\n{len(ran)} cells compiled, "
          f"{sum(1 for c in cells if c.get('skipped'))} documented skips.")


if __name__ == "__main__":
    table("sp", "Single-pod mesh (16 x 16 = 256 chips)")
    table("mp", "Multi-pod mesh (2 x 16 x 16 = 512 chips)")
