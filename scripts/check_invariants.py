"""CI gate: run the repro-lint invariant checker over the repo.

Thin wrapper over ``repro.analysis`` with the CI-friendly shape: lint the
default targets (src/repro, scripts, benchmarks, examples) against the
committed baseline, write the JSON report as a build artifact, and exit
with the lint contract:

  0  clean — no findings, no stale baseline entries
  1  findings outside the baseline, or baseline entries matching nothing
  2  usage/configuration error (bad path, malformed baseline)

Same check as ``python -m repro lint --json --out REPORT`` — this script
exists so the CI lint job does not need the package's console entry point
wired up to get a report artifact.

Usage: PYTHONPATH=src python scripts/check_invariants.py
           [--root DIR] [--report FILE] [--baseline FILE|none]
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import lint_paths, render_json, render_text


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="repo root (default: this script's parent repo)")
    ap.add_argument("--report", default="lint_report.json",
                    help="where to write the JSON report artifact "
                         "(default: lint_report.json)")
    ap.add_argument("--baseline", default=None, metavar="FILE|none",
                    help="baseline file (default: <root>/lint_baseline.json "
                         "if present; 'none' disables suppression)")
    args = ap.parse_args()
    root = args.root or str(Path(__file__).resolve().parent.parent)
    try:
        result, _ = lint_paths(root=root, baseline_path=args.baseline)
    except (ValueError, FileNotFoundError) as e:
        print(f"check_invariants: error: {e}", file=sys.stderr)
        return 2
    Path(args.report).write_text(render_json(result) + "\n")
    print(render_text(result))
    print(f"check_invariants: JSON report written to {args.report}")
    return result.exit_code()


if __name__ == "__main__":
    sys.exit(main())
