"""CI check: the docs/ tree must cover the living surface area.

Asserts, against the code (not a hand-maintained list):

  * every scenario name in the registry appears somewhere under docs/;
  * every `python -m repro` subcommand (introspected from the argument
    parser) appears under docs/;
  * every `--flag` the sweep and run subcommands accept appears in
    docs/cli.md, so the CLI reference cannot silently rot;
  * every fault kind (`FAULT_KINDS`), escalation stage (`STAGES`) and
    healing metric the runner reports appears in docs/faults.md;
  * every `serve/*` scenario, every SLO metric name (`SLO_METRICS`),
    every arrival process and every manager objective appears in
    docs/serving.md;
  * every metric name in the observability catalog (`METRICS`), every
    alert rule kind (`RULE_KINDS`) and every alert lifecycle state
    (`ALERT_STATES`) appears in docs/observability.md — which must also
    cover the `monitor` subcommand;
  * every lint rule id in `repro.analysis.RULES`, with its title,
    appears in docs/analysis.md — which must also cover the baseline
    workflow and the exit-code contract.

Exit 0 when covered, 1 with a per-item listing otherwise — same contract
as the other scripts/ smokes.

Usage: PYTHONPATH=src python scripts/check_docs.py [--docs DIR]
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.api.cli import build_parser
from repro.api.registry import list_scenarios


def _docs_text(docs_dir: Path) -> dict:
    """{relative path: text} for every markdown file under docs/."""
    files = sorted(docs_dir.rglob("*.md"))
    if not files:
        print(f"ERROR: no markdown files under {docs_dir}", file=sys.stderr)
        sys.exit(1)
    return {str(p.relative_to(docs_dir)): p.read_text() for p in files}


def _subcommands_and_flags():
    """(subcommand names, {subcommand: flag strings}) from the parser."""
    ap = build_parser()
    subs = next(a for a in ap._actions
                if isinstance(a, argparse._SubParsersAction))
    names, flags = [], {}
    for name, sub in subs.choices.items():
        names.append(name)
        flags[name] = sorted(
            opt for a in sub._actions for opt in a.option_strings
            if opt.startswith("--") and opt != "--help")
    return names, flags


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", default=None,
                    help="docs directory (default: <repo>/docs)")
    args = ap.parse_args()
    root = Path(__file__).resolve().parent.parent
    docs_dir = Path(args.docs) if args.docs else root / "docs"

    docs = _docs_text(docs_dir)
    all_text = "\n".join(docs.values())
    cli_text = docs.get("cli.md", "")
    missing = []

    for name, _scope, _desc in list_scenarios():
        if name not in all_text:
            missing.append(f"scenario {name!r} is not mentioned under docs/")

    names, flags = _subcommands_and_flags()
    for name in names:
        if name not in all_text:
            missing.append(f"CLI subcommand {name!r} is not mentioned "
                           f"under docs/")
    if not cli_text:
        missing.append("docs/cli.md does not exist")
    else:
        for name, opts in flags.items():
            for opt in opts:
                if opt not in cli_text:
                    missing.append(f"`{name}` flag {opt} is not documented "
                                   f"in docs/cli.md")

    from repro.core.escalate import STAGES
    from repro.core.faults import FAULT_KINDS
    faults_text = docs.get("faults.md", "")
    heal_metrics = ("goodput", "useful_units", "lost_units",
                    "time_to_detect_s", "time_to_heal_s", "false_drains")
    if not faults_text:
        missing.append("docs/faults.md does not exist")
    else:
        for kind in FAULT_KINDS:
            if f"`{kind}`" not in faults_text:
                missing.append(f"fault kind `{kind}` is not documented in "
                               f"docs/faults.md")
        for stage in STAGES:
            if stage not in faults_text:
                missing.append(f"escalation stage {stage!r} is not "
                               f"documented in docs/faults.md")
        for metric in heal_metrics:
            if f"`{metric}`" not in faults_text:
                missing.append(f"healing metric `{metric}` is not "
                               f"documented in docs/faults.md")

    from repro.core.manager import OBJECTIVES
    from repro.serve.metrics import SLO_METRICS
    from repro.serve.traffic import ARRIVAL_PROCESSES
    serving_text = docs.get("serving.md", "")
    if not serving_text:
        missing.append("docs/serving.md does not exist")
    else:
        for name, _scope, _desc in list_scenarios():
            if name.startswith("serve/") and name not in serving_text:
                missing.append(f"serve scenario {name!r} is not documented "
                               f"in docs/serving.md")
        for metric in SLO_METRICS:
            if f"`{metric}`" not in serving_text:
                missing.append(f"SLO metric `{metric}` is not documented "
                               f"in docs/serving.md")
        for proc in ARRIVAL_PROCESSES:
            if f"`{proc}`" not in serving_text:
                missing.append(f"arrival process `{proc}` is not "
                               f"documented in docs/serving.md")
        for obj in OBJECTIVES:
            if f"`{obj}`" not in serving_text:
                missing.append(f"manager objective `{obj}` is not "
                               f"documented in docs/serving.md")

    from repro.obs.metrics import METRICS
    from repro.obs.rules import ALERT_STATES, RULE_KINDS
    obs_text = docs.get("observability.md", "")
    if not obs_text:
        missing.append("docs/observability.md does not exist")
    else:
        for metric in METRICS:
            if f"`{metric}`" not in obs_text:
                missing.append(f"observability metric `{metric}` is not "
                               f"documented in docs/observability.md")
        for kind in RULE_KINDS:
            if f"`{kind}`" not in obs_text:
                missing.append(f"alert rule kind `{kind}` is not "
                               f"documented in docs/observability.md")
        for state in ALERT_STATES:
            if f"`{state}`" not in obs_text:
                missing.append(f"alert state `{state}` is not documented "
                               f"in docs/observability.md")
        if "monitor" not in obs_text:
            missing.append("the `monitor` subcommand is not mentioned in "
                           "docs/observability.md")

    from repro.analysis import RULES
    analysis_text = docs.get("analysis.md", "")
    if not analysis_text:
        missing.append("docs/analysis.md does not exist")
    else:
        for rid, rule in sorted(RULES.items()):
            if f"`{rid}`" not in analysis_text:
                missing.append(f"lint rule `{rid}` is not documented in "
                               f"docs/analysis.md")
            elif rule.title not in analysis_text:
                missing.append(f"lint rule `{rid}` title is out of date in "
                               f"docs/analysis.md (expected: {rule.title!r})")
        for needed in ("baseline", "--update-baseline", "exit"):
            if needed not in analysis_text:
                missing.append(f"docs/analysis.md does not cover {needed!r}")

    if missing:
        print(f"check_docs: {len(missing)} item(s) missing from docs/ "
              f"({len(docs)} file(s) scanned):", file=sys.stderr)
        for m in missing:
            print(f"  {m}", file=sys.stderr)
        return 1
    n_cmds = len(names)
    n_flags = sum(len(v) for v in flags.values())
    print(f"check_docs: ok — {len(list_scenarios())} scenarios, "
          f"{n_cmds} subcommands, {n_flags} flags, "
          f"{len(FAULT_KINDS)} fault kinds, {len(STAGES)} stages, "
          f"{len(SLO_METRICS)} SLO metrics, "
          f"{len(METRICS)} obs metrics, {len(RULE_KINDS)} rule kinds, "
          f"{len(RULES)} lint rules "
          f"covered across {len(docs)} docs file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
