"""CI telemetry smoke: record a short managed cluster run, persist the
trace (JSONL + Chrome trace artifacts), replay the fleet manager offline,
and fail unless the replayed cap schedule matches the live one bit-for-bit.

The cluster/manager setup is ``benchmarks.telemetry_bench.
record_managed_cluster`` — the same configuration the benchmark's
``telemetry_replay`` row measures — so CI validates one setup, not two
drifting copies.

    PYTHONPATH=src python scripts/telemetry_smoke.py --out DIR

Exit status 0 = replay matched; 1 = mismatch (prints the first divergence).
"""
import argparse
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np                                            # noqa: E402

from benchmarks.telemetry_bench import (fleet_cfg,            # noqa: E402
                                        record_managed_cluster)
from repro.telemetry import (export_chrome_trace,             # noqa: E402
                             fleet_replay_matches, load_trace,
                             replay_fleet, save_trace)

N_NODES, ITERS, TUNE_AFTER = 2, 40, 10


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="telemetry_smoke",
                    help="artifact directory (JSONL + Chrome trace)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cl, col, live = record_managed_cluster(N_NODES, ITERS, TUNE_AFTER)

    jsonl = os.path.join(args.out, "cluster_trace.jsonl")
    chrome = os.path.join(args.out, "cluster_trace.chrome.json")
    lines = save_trace(col, jsonl)
    events = export_chrome_trace(col, chrome, max_samples=5 * N_NODES)
    print(f"recorded {len(col.samples)} node-samples, "
          f"{len(col.actions)} manager actions "
          f"({lines} JSONL lines, {events} Chrome-trace events)")

    rp = replay_fleet(load_trace(jsonl), fleet_cfg(N_NODES),
                      tune_after=TUNE_AFTER)
    live_caps = np.stack([cl.get_node_caps(n) for n in range(N_NODES)])
    rp.export_caps(os.path.join(args.out, "caps_node0.json"))

    ok = fleet_replay_matches(live, rp, live_caps, log=print)
    if ok:
        print(f"replay matched live bit-for-bit: "
              f"{len(live.budget_log)} budget adjustments, "
              f"{sum(len(m.adjust_log) for m in live.managers)} node cap "
              f"adjustments, final caps identical")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
