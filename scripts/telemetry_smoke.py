"""CI telemetry smoke: run the registered ``telemetry/replay`` scenario (a
short managed cluster recorded losslessly), persist the trace (JSONL +
Chrome trace artifacts), replay the fleet manager offline, and fail unless
the replayed cap schedule matches the live one bit-for-bit.

The whole setup is the one scenario definition the benchmark's
``telemetry_replay`` row measures (``repro.api`` registry) — CI validates
one configuration, not two drifting copies.

    PYTHONPATH=src python scripts/telemetry_smoke.py --out DIR

Exit status 0 = replay matched; 1 = mismatch (prints the first divergence).
"""
import argparse
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np                                            # noqa: E402

from repro.api import get_scenario, run_scenario              # noqa: E402
from repro.telemetry import (fleet_replay_matches, load_trace,  # noqa: E402
                             replay_fleet)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="telemetry_smoke",
                    help="artifact directory (JSONL + Chrome trace)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    sc = get_scenario("telemetry/replay")
    jsonl = os.path.join(args.out, "cluster_trace.jsonl")
    chrome = os.path.join(args.out, "cluster_trace.chrome.json")
    res = run_scenario(sc, save_trace_path=jsonl,
                       chrome_trace_path=chrome)
    col, live = res.collector, res.manager
    print(f"recorded {len(col.samples)} node-samples, "
          f"{len(col.actions)} manager actions -> {jsonl}")

    rp = replay_fleet(load_trace(jsonl), sc.manager.config,
                      tune_after=sc.manager.tune_after)
    live_caps = np.stack([res.cluster.get_node_caps(n)
                          for n in range(res.cluster.N)])
    rp.export_caps(os.path.join(args.out, "caps_node0.json"))

    ok = fleet_replay_matches(live, rp, live_caps, log=print)
    if ok:
        print(f"replay matched live bit-for-bit: "
              f"{len(live.budget_log)} budget adjustments, "
              f"{sum(len(m.adjust_log) for m in live.managers)} node cap "
              f"adjustments, final caps identical")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
