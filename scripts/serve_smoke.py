"""CI serving-SLO smoke: run the registered ``serve/straggler-slo``
scenario (pinned hot-node preset, tail-latency power objective), record
the request-level trace (JSONL artifact), and fail unless

  * the tail-latency objective strictly beats the ``throughput``
    objective on p99 TTFT — same trace, same seed, same budget: the
    SLO-aware manager must actually buy tail latency;
  * both runs also beat the unmanaged fleet (the budget shift pays at
    all);
  * every SLO metric in both summaries is finite — the ``-1.0``
    empty-population sentinel is allowed, NaN never is;
  * the SLO summary replays bit-for-bit from the recorded trace
    (``replay_slo`` / ``slo_replay_matches``).

The scenarios are the same registry entries ``benchmarks/serve_bench.py``
pins in BENCH_serve.json — CI validates one configuration, not two
drifting copies.

    PYTHONPATH=src python scripts/serve_smoke.py --out DIR

Exit status 0 = ordering + finiteness + replay hold; 1 = a gate failed.
"""
import argparse
import math
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.api import get_scenario, run_scenario, with_overrides  # noqa: E402
from repro.serve.metrics import (replay_slo, slo_replay_matches)  # noqa: E402
from repro.telemetry import load_trace                            # noqa: E402


def _nan_keys(metrics) -> list:
    return [k for k, v in metrics.items()
            if isinstance(v, float) and math.isnan(v)]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="serve_smoke",
                    help="artifact directory (request trace JSONL)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    jsonl = os.path.join(args.out, "serve_trace.jsonl")

    base = get_scenario("serve/straggler-slo")        # tail-latency objective
    tail = run_scenario(base, save_trace_path=jsonl)
    tput = run_scenario(with_overrides(
        base, {"manager.config.objective": "throughput"}))
    none = run_scenario(with_overrides(base, {"manager": None}))

    p_tail = tail.metrics["ttft_p99"]
    p_tput = tput.metrics["ttft_p99"]
    p_none = none.metrics["ttft_p99"]
    print(f"p99 TTFT: unmanaged {p_none:.3f}s, throughput-objective "
          f"{p_tput:.3f}s, tail-latency-objective {p_tail:.3f}s "
          f"({100 * (p_tput - p_tail) / p_tput:.1f}% gain vs throughput) "
          f"-> {jsonl}")

    failures = []
    if not p_tail < p_tput:
        failures.append(f"SLO-aware management did not pay: tail-objective "
                        f"p99 TTFT {p_tail:.4f}s >= throughput-objective "
                        f"{p_tput:.4f}s")
    if not p_tput < p_none:
        failures.append(f"power management did not pay at all: managed p99 "
                        f"TTFT {p_tput:.4f}s >= unmanaged {p_none:.4f}s")
    for name, res in (("tail", tail), ("throughput", tput),
                      ("unmanaged", none)):
        bad = _nan_keys(res.metrics)
        if bad:
            failures.append(f"NaN SLO metrics in {name} run: {bad}")

    trace = load_trace(jsonl)
    rp = replay_slo(trace)
    live = {k: tail.metrics.get(k) for k in rp}
    log = []
    if not slo_replay_matches(live, rp, log=log.append):
        failures.extend(["SLO replay diverged from the recording:", *log])
    else:
        print(f"replay matched recording bit-for-bit: "
              f"{int(rp['offered'])} requests, "
              f"{int(rp['completed'])} completed")

    if failures:
        print("serve_smoke: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("serve_smoke: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
