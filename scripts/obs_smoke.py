"""CI observability smoke: run the two pinned alerting scenarios
(``cluster/fault-heal`` and ``serve/straggler-slo``) with the default
rule set at lossless fidelity, write the fleet-health artifacts
(dashboard HTML + incident JSONL + telemetry traces), and fail unless

  * every unrecoverable fault on ``cluster/fault-heal`` raises a firing
    alert within the escalation policy's patience window (time-to-alert
    <= ``patience_s``) with **zero false positives** — the alert layer
    must beat the drain it is meant to corroborate;
  * offline rule evaluation over both recorded traces reproduces the
    live alert transitions **bit-for-bit** (``alert_replay_matches``) —
    the same contract the cap-schedule and drain replays already hold.

The scenarios are the same registry entries ``tests/test_obs.py`` pins —
CI validates one configuration, not two drifting copies.

    PYTHONPATH=src python scripts/obs_smoke.py --out DIR

Exit status 0 = gates hold; 1 = a gate failed.
"""
import argparse
import math
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.api import get_scenario, run_scenario              # noqa: E402
from repro.obs import (alert_replay_matches, render_dashboard,  # noqa: E402
                       save_incidents, score_alerts)
from repro.telemetry import load_trace                        # noqa: E402


def _check(name: str, jsonl: str, failures: list) -> None:
    """Replay gate shared by both scenarios: the recorded alert rows must
    reproduce bit-for-bit from the trace alone."""
    trace = load_trace(jsonl)
    n_alerts = sum(1 for e in trace.events if e.source == "alert")
    if n_alerts == 0:
        failures.append(f"{name}: no alert transitions were recorded")
        return
    log = []
    if not alert_replay_matches(trace, log=log):
        failures.append(f"{name}: alert replay diverged from the recording:")
        failures.extend(f"  {line}" for line in log)
    else:
        print(f"{name}: replay matched recording bit-for-bit "
              f"({n_alerts} alert transitions)")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="obs_smoke",
                    help="artifact directory (dashboards, incidents, traces)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    failures = []

    # ---- cluster/fault-heal: detection quality vs fault ground truth ----
    heal_jsonl = os.path.join(args.out, "heal_trace.jsonl")
    heal = run_scenario(get_scenario("cluster/fault-heal"),
                        save_trace_path=heal_jsonl)
    patience = heal.scenario.escalation.patience_s
    trace = load_trace(heal_jsonl)
    score = score_alerts(trace, patience_s=patience)
    tta = score["time_to_alert_s"]
    fp = score["false_positives"]
    print(f"cluster/fault-heal: {int(score['n_alerts_firing'])} firing, "
          f"{int(fp)} false positive(s), time-to-alert "
          f"{tta:.3f}s vs patience {patience:g}s")
    if fp != 0:
        failures.append(f"cluster/fault-heal: {int(fp)} false positive(s) "
                        "at lossless fidelity")
    if not (tta == tta and tta <= patience):
        failures.append(f"cluster/fault-heal: time-to-alert {tta} did not "
                        f"beat the escalation patience {patience:g}s")
    if score["detected"] != 1.0:
        failures.append("cluster/fault-heal: an unrecoverable fault never "
                        "raised a firing alert on its node")
    _check("cluster/fault-heal", heal_jsonl, failures)
    render_dashboard(trace, os.path.join(args.out, "heal_dashboard.html"))
    save_incidents(trace, os.path.join(args.out, "heal_incidents.jsonl"))

    # ---- serve/straggler-slo: the SLO-burn path + replay --------------- #
    serve_jsonl = os.path.join(args.out, "serve_trace.jsonl")
    run_scenario(get_scenario("serve/straggler-slo"),
                 save_trace_path=serve_jsonl)
    s_score = score_alerts(load_trace(serve_jsonl), patience_s=math.nan)
    print(f"serve/straggler-slo: {int(s_score['n_alerts_firing'])} firing "
          f"(slo-burn on the backlog is operationally real; no fault "
          f"ground truth here)")
    if s_score["n_alerts_firing"] < 1:
        failures.append("serve/straggler-slo: the slo-burn rule never "
                        "fired on the pinned backlog")
    _check("serve/straggler-slo", serve_jsonl, failures)
    s_trace = load_trace(serve_jsonl)
    render_dashboard(s_trace, os.path.join(args.out, "serve_dashboard.html"))
    save_incidents(s_trace, os.path.join(args.out, "serve_incidents.jsonl"))

    if failures:
        print("obs_smoke: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("obs_smoke: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
