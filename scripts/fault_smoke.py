"""CI fault-healing smoke: run the registered ``cluster/fault-heal``
scenario (transient hang + thermal runaway ending in device loss), record
the healing trace (JSONL artifact), and fail unless

  * healing strictly out-goodputs the ``cluster/fault-ignored`` ablation
    (same faults, ``drain_mode="never"``) — draining + restarting must
    actually pay for itself;
  * no false drains — the transient hang is ridden out under patience;
  * the drain decisions replay bit-for-bit from the recorded trace
    (``replay_escalation`` / ``escalation_replay_matches``).

The scenarios are the same registry entries the benchmark's
``cluster_fault_recovery`` rows measure — CI validates one configuration,
not two drifting copies.

    PYTHONPATH=src python scripts/fault_smoke.py --out DIR

Exit status 0 = ordering + replay hold; 1 = a gate failed.
"""
import argparse
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.api import get_scenario, run_scenario              # noqa: E402
from repro.telemetry import (escalation_replay_matches,       # noqa: E402
                             load_trace, replay_escalation)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fault_smoke",
                    help="artifact directory (healing trace JSONL)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    jsonl = os.path.join(args.out, "heal_trace.jsonl")

    heal = run_scenario(get_scenario("cluster/fault-heal"),
                        save_trace_path=jsonl)
    ignored = run_scenario(get_scenario("cluster/fault-ignored"))
    g_heal = heal.metrics["goodput"]
    g_ign = ignored.metrics["goodput"]
    print(f"goodput: fault-heal {g_heal:.4f} vs fault-ignored {g_ign:.4f} "
          f"(x{g_heal / g_ign:.2f}); detect {heal.metrics['time_to_detect_s']:.1f}s, "
          f"heal {heal.metrics['time_to_heal_s']:.1f}s, "
          f"{heal.metrics['n_drains']} drain(s) -> {jsonl}")

    failures = []
    if not g_heal > g_ign:
        failures.append(f"healing did not pay: goodput {g_heal:.4f} <= "
                        f"ignored {g_ign:.4f}")
    if heal.metrics["false_drains"] != 0:
        failures.append(f"{heal.metrics['false_drains']} false drain(s): "
                        "the transient hang was not ridden out")
    if heal.metrics["n_drains"] < 1:
        failures.append("the unrecoverable fault was never drained")

    trace = load_trace(jsonl)
    rp = replay_escalation(trace)
    log = []
    if not escalation_replay_matches(trace, rp, log=log.append):
        failures.extend(["escalation replay diverged from the recording:",
                         *log])
    else:
        print(f"replay matched recording bit-for-bit: "
              f"{len(rp.events)} escalation events, "
              f"drained nodes {rp.drained_nodes}")

    if failures:
        print("fault_smoke: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("fault_smoke: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
