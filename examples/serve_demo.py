"""Batched serving demo: prefill + greedy decode on a reduced qwen3 config.

    PYTHONPATH=src python examples/serve_demo.py
"""
import _bootstrap  # noqa: F401
import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.models.common import init_params
from repro.serve.decode import ServeConfig, ServingLoop


def main():
    cfg = get_reduced_config("qwen3-4b")
    model = build_model(cfg, max_cache_len=48)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    loop = ServingLoop(model, params, batch_size=8, prompt_len=24,
                       cfg=ServeConfig(max_new_tokens=16))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (5, 24)).astype(np.int32)
    out = loop.serve(prompts)
    print(f"arch={cfg.name}: served {out.shape[0]} requests, "
          f"{out.shape[1]} new tokens each")
    print(out)


if __name__ == "__main__":
    main()
