"""Fault-tolerance demo: train, checkpoint, 'crash', resume — then replan
the mesh after a simulated device failure (elastic restart).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

import _bootstrap  # noqa: F401

from repro.configs import ParallelConfig, TrainConfig, get_reduced_config
from repro.train.data import DataConfig
from repro.train.fault import ElasticPlan
from repro.train.train_loop import Trainer, TrainerConfig


def main():
    cfg = get_reduced_config("llama3.1-8b")
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(
            model=cfg,
            train=TrainConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                              checkpoint_every=20, checkpoint_dir=d),
            parallel=ParallelConfig(),
            data=DataConfig(global_batch=8, seq_len=64))
        t1 = Trainer(tc)
        log = t1.run(40)
        t1.ckpt.wait()
        print(f"phase 1: trained to step {t1.step}, "
              f"loss {log[-1]['loss']:.3f}; checkpoint at "
              f"{t1.ckpt.latest_step()}")
        del t1                                    # 'crash'

        t2 = Trainer(tc)
        t2.init_or_restore()
        print(f"phase 2: restored at step {t2.step} "
              f"(atomic LATEST pointer)")
        log2 = t2.run(10)
        print(f"resumed: loss {log2[-1]['loss']:.3f} at step {t2.step}")

    # elastic replanning (production mesh math; restore re-places leaves
    # with the new mesh's shardings automatically)
    plan = ElasticPlan.after_failure(n_devices=256, failed=5,
                                     model_parallel=16, global_batch=256)
    print(f"\nelastic replan after losing 5/256 chips: mesh "
          f"{plan.mesh_shape()}, per-replica batch "
          f"{plan.batch_per_replica()} (was (16,16) x 16)")


if __name__ == "__main__":
    main()
