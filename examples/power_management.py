"""Compare the three power-management use cases (paper Table I) on one node:
GPU-Red vs GPU-Realloc vs CPU-Slosh, with converged cap export/import.

    PYTHONPATH=src python examples/power_management.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                            # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.core.backends import SimBackend                    # noqa: E402
from repro.core.c3sim import NodeSim, SimConfig               # noqa: E402
from repro.core.manager import (ManagerConfig, PowerManager,  # noqa: E402
                                run_closed_loop)
from repro.core.thermal import MI300X_PRESET                  # noqa: E402
from repro.core.workload import fsdp_llm_iteration            # noqa: E402

ITERS = 200


def run_case(use_case: str):
    cfg = get_config("llama3.1-8b")
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    node = NodeSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                   8, seed=1)
    mgr = run_closed_loop(
        SimBackend(node),
        ManagerConfig(use_case=use_case, sampling_period=2, warmup=3,
                      window_size=2, power_cap=700.0, cpu_budget=20.0),
        ITERS)
    h = node.history
    pre = h[ITERS // 2 - 30: ITERS // 2]
    post = h[-30:]
    tput = (np.mean([x["throughput"] for x in post])
            / np.mean([x["throughput"] for x in pre]))
    power = (np.mean([np.sum(x["power"]) for x in post])
             / np.mean([np.sum(x["power"]) for x in pre]))
    return node, mgr, tput, power


def main():
    print(f"{'use case':14s} {'throughput':>11s} {'node power':>11s}  "
          f"(paper: Red ~0%/-4%, Realloc +3%/0%, Slosh +4%/+3%)")
    managers = {}
    for uc in ("gpu-red", "gpu-realloc", "cpu-slosh"):
        node, mgr, tput, power = run_case(uc)
        managers[uc] = (node, mgr)
        print(f"{uc:14s} {tput - 1:+10.2%} {power - 1:+10.2%}   "
              f"caps={np.round(node.history[-1]['cap'], 0).astype(int)}")

    # converged caps are reusable (paper Fig 12 / §VII-D: tune twice in
    # three months) — export once, import on the next job
    node, mgr = managers["gpu-red"]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "caps.json")
        mgr.export_caps(path)
        cfg = get_config("mistral-7b")              # different workload!
        wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
        node2 = NodeSim(wl, MI300X_PRESET,
                        SimConfig(seed=1, comm_gbps=40.0), 8, seed=1)
        mgr2 = PowerManager(SimBackend(node2),
                            ManagerConfig(use_case="gpu-red"))
        mgr2.import_caps(path)
        p0 = np.sum(node2.step().util * 0 + node2.state.power)
        for _ in range(30):
            node2.step()
        p1 = np.mean([np.sum(h["power"]) for h in node2.history[-10:]])
        print(f"\nimported caps onto mistral-7b: node power {p1:.0f} W "
              f"(detection cost amortized — paper §VII-D)")


if __name__ == "__main__":
    main()
