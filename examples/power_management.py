"""Compare the three power-management use cases (paper Table I) on one
node — thin wrapper over the ``paper/table1-tdp`` / ``paper/node-cap`` /
``paper/cpu-slosh`` scenarios — then show converged-cap reuse (Fig 12):
export once, import onto a different workload.

    PYTHONPATH=src python examples/power_management.py
"""
import os, tempfile  # noqa: E401

import _bootstrap  # noqa: F401
import numpy as np
from repro.api import get_scenario, run_scenario, with_overrides
from repro.api.reports import use_case_table
from repro.core.backends import SimBackend
from repro.core.manager import ManagerConfig, PowerManager


def main():
    names = {"gpu-red": "paper/table1-tdp", "gpu-realloc": "paper/node-cap",
             "cpu-slosh": "paper/cpu-slosh"}
    results = {uc: run_scenario(get_scenario(n)) for uc, n in names.items()}
    print(use_case_table(results))

    # converged caps are reusable (paper Fig 12 / §VII-D) — export once,
    # import on the next job, even a different workload
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "caps.json")
        results["gpu-red"].manager.export_caps(path)
        other = run_scenario(with_overrides(
            get_scenario("paper/characterization"),
            {"workload.arch": "mistral-7b"}), iterations=1)
        mgr2 = PowerManager(SimBackend(other.node),
                            ManagerConfig(use_case="gpu-red"))
        mgr2.import_caps(path)
        for _ in range(30): other.node.step()  # noqa: E701
        p1 = np.mean([np.sum(h["power"]) for h in other.node.history[-10:]])
        print(f"\nimported caps onto mistral-7b: node power {p1:.0f} W "
              f"(detection cost amortized — paper §VII-D)")


if __name__ == "__main__":
    main()
