"""Reproduce the paper's characterization study (Figs 3-7) in one script:
settle a node at TDP, print the straggler/leader structure, correlations,
and the lead-wave dynamics.

    PYTHONPATH=src python examples/thermal_study.py [--arch llama3.1-8b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                            # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.core.c3sim import NodeSim, SimConfig               # noqa: E402
from repro.core.detect import (classify_overlap,              # noqa: E402
                               lead_value_detect,
                               overlap_duration_correlation,
                               straggler_index)
from repro.core.thermal import MI300X_PRESET                  # noqa: E402
from repro.core.workload import fsdp_llm_iteration            # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.1-8b")
    ap.add_argument("--iters", type=int, default=45)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    node = NodeSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                   8, seed=1)
    for _ in range(args.iters):
        tr = node.step()

    st = node.state
    s = straggler_index(tr.comp_start)
    print(f"== {args.arch}: node settled after {args.iters} iterations ==")
    print(f"temps  (°C):  {np.round(st.temp, 1)}  "
          f"ratio {st.temp.max() / st.temp.min():.3f}  (paper: 1.155x)")
    print(f"freqs  (GHz): {np.round(st.freq, 3)}  "
          f"ratio {st.freq.max() / st.freq.min():.3f}  (paper: 1.062x)")
    print(f"straggler: GPU{s} (hottest & slowest)")

    w = tr.comp_dur
    ov = (tr.overlap_ratio * w).sum(1) / w.sum(1)
    print(f"\nweighted overlap ratio per GPU: {np.round(ov, 3)}")
    print(f"straggler has the lowest overlap: "
          f"{ov[s] == ov.min()} (paper Insight 1)")

    const = classify_overlap(tr.overlap_ratio)
    dv = tr.comp_dur[:, ~const]
    dc = tr.comp_dur[:, const]
    print(f"\nconstant-overlap kernels: {const.sum()}/{len(const)}")
    if (~const).sum():
        print(f"straggler vs leaders on VARYING-overlap kernels: "
              f"{dv[s].mean() / np.delete(dv, s, 0).mean():.2f}x duration "
              f"(<1: straggler faster — paper Insight 3)")
    print(f"straggler vs leaders on CONSTANT-overlap kernels: "
          f"{dc[s].mean() / np.delete(dc, s, 0).mean():.2f}x duration "
          f"(>1: straggler slower)")

    # per-kernel correlation (paper Fig 4 is per unique kernel)
    import numpy as _np
    idx = [i for i, n in enumerate(tr.comp_names) if n == "f_qkv_ip"]
    p, c = overlap_duration_correlation(tr.overlap_ratio[:, idx],
                                        tr.comp_dur[:, idx])
    print(f"\noverlap-vs-duration correlation (f_qkv_ip): pearson={p:.3f} "
          f"cosine={c:.3f} (paper Fig 4: strong)")

    lead = lead_value_detect(tr.comp_start)
    print(f"\naggregate lead values (ms): {np.round(lead * 1e3, 1)}")
    print("straggler lead ~ 0 (everyone waits for it) — paper Fig 7")


if __name__ == "__main__":
    main()
