"""Reproduce the paper's characterization study (Figs 3-7): settle a node
at TDP, print the straggler/leader structure, correlations, and the
lead-wave dynamics.  Thin wrapper over the ``paper/characterization``
scenario — ``python -m repro run paper/characterization`` is equivalent
minus the study-specific report.

    PYTHONPATH=src python examples/thermal_study.py [--arch llama3.1-8b]
"""
import argparse

import _bootstrap  # noqa: F401
from repro.api import get_scenario, run_scenario, with_overrides
from repro.api.reports import characterization_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.1-8b")
    ap.add_argument("--iters", type=int, default=45)
    args = ap.parse_args()

    sc = with_overrides(get_scenario("paper/characterization"),
                        {"workload.arch": args.arch})
    print(characterization_report(run_scenario(sc,
                                               iterations=args.iters)))


if __name__ == "__main__":
    main()
