"""Cluster-scale Lit Silicon study: one hot GPU vs an N-node fleet.

Builds three fleets under the same provisioned power budget (N x 8 x 700 W):
  1. healthy         — no boosted straggler, uniform 700 W caps
  2. straggler       — one hot GPU on node 0, uniform caps (unmanaged)
  3. managed         — same straggler, FleetPowerManager running the paper's
                       Algorithms 1-3 inside each node *and* across nodes
                       (a node's lead is the topology's wait signal)

    PYTHONPATH=src python examples/cluster_study.py [--nodes 4]
        [--topology dp|pp|tp]

``--topology`` selects how nodes couple: data parallelism (ring all-reduce
+ barrier — the paper's case), pipeline stages (point-to-point bubbles,
weaker), or tensor parallelism (per-layer syncs on the fast link, tighter).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                            # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.core.backends import ClusterSimBackend             # noqa: E402
from repro.core.c3sim import SimConfig                        # noqa: E402
from repro.core.cluster import ClusterConfig, ClusterSim      # noqa: E402
from repro.core.manager import (FleetManagerConfig,           # noqa: E402
                                run_fleet_closed_loop)
from repro.core.thermal import MI300X_PRESET                  # noqa: E402
from repro.core.workload import fsdp_llm_iteration            # noqa: E402

CAP = 700.0


def build(n_nodes, boost, topology="dp", seed=5):
    cfg = get_config("llama3.1-8b").replace(n_layers=8)
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=n_nodes, straggler_boost=boost,
                                  topology=topology),
                    devices_per_node=8, seed=seed)
    for n in range(n_nodes):
        cl.set_node_caps(n, np.full(8, CAP))
    return cl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--topology", default="dp", choices=["dp", "pp", "tp"])
    args = ap.parse_args()
    N = args.nodes
    topo = args.topology

    healthy = build(N, 1.0, topo)
    strag = build(N, 1.28, topo)
    for _ in range(args.iters):
        healthy.step()
        strag.step()
    tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()

    print(f"== {N}-node {topo} fleet, one hot GPU on node 0 ==")
    print(f"exposed inter-node comm: "
          f"{strag.history[-1]['comm_time'] * 1e3:.1f} ms per iteration")
    wait_kind = {"dp": "every node waits at the barrier",
                 "pp": "downstream stages ride the bubble",
                 "tp": "every layer's collective drags"}[topo]
    print(f"healthy fleet:   {tp_h:.4f} iter/s")
    print(f"with straggler:  {tp_s:.4f} iter/s "
          f"({(tp_s - tp_h) / tp_h:+.2%} — {wait_kind})")
    slow = [h["slowest_node"] for h in strag.history[-20:]]
    print(f"slowest node (last 20 iters): {max(set(slow), key=slow.count)}")

    managed = build(N, 1.28, topo)
    mgr = run_fleet_closed_loop(
        ClusterSimBackend(managed),
        FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=CAP,
                           cluster_power_budget=N * 8 * CAP),
        2 * args.iters, tune_after=args.iters // 3)
    tp_m = managed.fleet_throughput()
    rec = (tp_m - tp_s) / max(tp_h - tp_s, 1e-12)
    print(f"\n== FleetPowerManager (cluster budget {N * 8 * CAP:.0f} W) ==")
    print(f"managed fleet:   {tp_m:.4f} iter/s  "
          f"(recovers {rec:.0%} of the straggler gap)")
    print(f"node budgets (W): {np.round(mgr.node_budgets).astype(int)}  "
          f"<- the topology's lead signal steers budget to the straggler")
    print(f"node 0 caps (W):  "
          f"{np.round(managed.get_node_caps(0)).astype(int)}")
    print(f"fleet power:      {managed.fleet_power():.0f} W "
          f"(budget {N * 8 * CAP:.0f} W)")


if __name__ == "__main__":
    main()
