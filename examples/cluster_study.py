"""Cluster-scale Lit Silicon study: healthy vs one-hot-GPU vs managed
fleet under one provisioned budget — thin wrapper over the registered
``cluster/{dp,pp,tp}`` scenarios (``--topology`` selects how nodes couple:
barrier + ring all-reduce, pipeline bubbles, or per-layer syncs).

    PYTHONPATH=src python examples/cluster_study.py [--nodes 4]
        [--topology dp|pp|tp]
"""
import argparse

import _bootstrap  # noqa: F401
from repro.api.reports import recovery_study


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--topology", default="dp", choices=["dp", "pp", "tp"])
    args = ap.parse_args()
    report, _ = recovery_study(args.topology, n_nodes=args.nodes,
                               iterations=args.iters)
    print(report)


if __name__ == "__main__":
    main()
