"""Shared example bootstrap: make ``src/`` (and the repo root) importable
when a script is run straight from a checkout — the one piece of
boilerplate every example used to carry itself.

    import _bootstrap  # noqa: F401
"""
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)
