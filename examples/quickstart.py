"""Quickstart: train a reduced Llama on CPU with the Lit Silicon
power-management layer enabled (GPU-Red), end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import _bootstrap  # noqa: F401
import numpy as np

from repro.configs import (ParallelConfig, TrainConfig, get_config,
                           get_reduced_config)
from repro.core.manager import ManagerConfig
from repro.train.data import DataConfig
from repro.train.train_loop import LitSiliconHook, Trainer, TrainerConfig


def main():
    model_cfg = get_reduced_config("llama3.1-8b")
    hook = LitSiliconHook(
        get_config("llama3.1-8b"),            # sim runs the real 8B workload
        ManagerConfig(use_case="gpu-red", sampling_period=2, warmup=3,
                      window_size=2),
        preset="mi300x")
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(
            model=model_cfg,
            train=TrainConfig(lr=3e-3, warmup_steps=5, total_steps=80,
                              checkpoint_every=40, checkpoint_dir=d),
            parallel=ParallelConfig(),
            data=DataConfig(global_batch=8, seq_len=64))
        trainer = Trainer(tc, hooks=[hook])
        log = trainer.run(80)
        trainer.ckpt.wait()          # let the async writer finish

    print(f"\nloss: {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")
    pw0 = np.mean([m["sim/node_power"] for m in log[:10]])
    pw1 = np.mean([m["sim/node_power"] for m in log[-10:]])
    print(f"simulated node power: {pw0:.0f} W -> {pw1:.0f} W "
          f"({pw1 / pw0 - 1:+.2%}) [GPU-Red]")
    print(f"converged caps: "
          f"{np.round(hook.backend.get_power_caps(), 0).tolist()}")


if __name__ == "__main__":
    main()
