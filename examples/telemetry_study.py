"""Telemetry study: how sensor fidelity degrades Lit Silicon detection.

Records one lossless trace per parallelism topology (the ``cluster/*``
scenarios with telemetry attached and the manager stripped), then degrades
it offline through a noise × sampling-period sensor grid
(`repro.api.reports.sensor_fidelity_report`).

    PYTHONPATH=src python examples/telemetry_study.py [--nodes 4]
        [--iters 60] [--topologies dp,pp,tp] [--save-trace PREFIX]
"""
import argparse

import _bootstrap  # noqa: F401
from repro.api import get_scenario, run_scenario, with_overrides
from repro.api.reports import sensor_fidelity_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--topologies", default="dp,pp,tp")
    ap.add_argument("--save-trace", default=None, metavar="PREFIX")
    args = ap.parse_args()

    for topo in args.topologies.split(","):
        sc = with_overrides(get_scenario(f"cluster/{topo}"),
                            {"manager": None, "telemetry": {},
                             "fleet.n_nodes": args.nodes})
        sv = args.save_trace and f"{args.save_trace}_{topo}"
        res = run_scenario(sc, iterations=args.iters,
                           save_trace_path=sv and sv + ".jsonl",
                           chrome_trace_path=sv and sv + ".chrome.json")
        trace = res.trace()
        strag = trace.meta["straggler_node"]
        print(f"\n=== topology {topo}: {args.nodes} nodes x 8 GPUs, "
              f"straggler on node {strag} "
              f"(device {trace.meta['straggler_hint'][strag]}), "
              f"{len(trace.samples)} node-samples recorded ===")
        if res.trace_path:
            print(f"  wrote {res.trace_path} (+ Perfetto chrome trace)")
        print(sensor_fidelity_report(trace, node=strag))


if __name__ == "__main__":
    main()
