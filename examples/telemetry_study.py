"""Telemetry study: how sensor fidelity degrades Lit Silicon detection.

Records one lossless trace per parallelism topology (a 4-node cluster with
one hot GPU), then degrades it offline through sensor models sweeping
timestamp noise and sampling period, and reports straggler-detection
accuracy and lead-estimate error — the robustness surface a deployment
needs before trusting rocm-smi-grade counters to drive power caps.

    PYTHONPATH=src python examples/telemetry_study.py [--nodes 4]
        [--iters 60] [--topologies dp,pp,tp] [--save-trace PREFIX]

``--save-trace PREFIX`` additionally writes PREFIX_{topo}.jsonl and a
Perfetto-loadable PREFIX_{topo}.chrome.json for visual inspection.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                            # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.core.c3sim import SimConfig                        # noqa: E402
from repro.core.cluster import ClusterConfig, ClusterSim      # noqa: E402
from repro.core.thermal import MI300X_PRESET                  # noqa: E402
from repro.core.workload import fsdp_llm_iteration            # noqa: E402
from repro.telemetry import (SensorConfig, SensorModel,       # noqa: E402
                             TelemetryCollector, TelemetryTrace, degrade,
                             detection_report, export_chrome_trace,
                             save_trace)

NOISES = (0.0, 0.002, 0.01, 0.05, 0.2)
PERIODS = (1, 10, 25)
SEEDS = 5


def record(topology, n_nodes, iters, seed=5):
    cfg = get_config("llama3.1-8b").replace(n_layers=8)
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=n_nodes, straggler_boost=1.28,
                                  topology=topology),
                    devices_per_node=8, seed=seed)
    for n in range(n_nodes):
        cl.set_node_caps(n, np.full(8, 700.0))
    col = TelemetryCollector(max_samples=n_nodes * iters + 1)
    col.attach_cluster(cl)
    for _ in range(iters):
        cl.step()
    return cl, TelemetryTrace.from_collector(col)


def sweep(trace, node=0):
    """accuracy[noise][period] on the straggler node's device stream."""
    grid = {}
    for sigma in NOISES:
        for period in PERIODS:
            accs, errs = [], []
            for s in range(SEEDS):
                d = degrade(trace, SensorModel(SensorConfig(
                    noise_time_s=sigma, sample_period=period,
                    quant_time_s=1e-5, seed=s)))
                rep = detection_report(d, node=node)
                accs.append(rep.accuracy)
                errs.append(rep.lead_rel_error)
            grid[sigma, period] = (float(np.mean(accs)), float(np.mean(errs)))
    return grid


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--topologies", default="dp,pp,tp")
    ap.add_argument("--save-trace", default=None, metavar="PREFIX")
    args = ap.parse_args()

    for topo in args.topologies.split(","):
        cl, trace = record(topo, args.nodes, args.iters)
        strag_node = trace.meta["straggler_node"]
        print(f"\n=== topology {topo}: {args.nodes} nodes x 8 GPUs, "
              f"straggler on node {strag_node} "
              f"(device {trace.meta['straggler_hint'][strag_node]}), "
              f"{len(trace.samples)} node-samples recorded ===")
        if args.save_trace:
            p = f"{args.save_trace}_{topo}.jsonl"
            save_trace(trace, p)
            c = f"{args.save_trace}_{topo}.chrome.json"
            export_chrome_trace(trace, c, max_samples=5 * args.nodes)
            print(f"  wrote {p} and {c} (load the latter in Perfetto)")
        grid = sweep(trace, node=strag_node)
        head = "  noise_s   " + "  ".join(f"period={p:<3d} " for p in PERIODS)
        print(head + "  (straggler-detection accuracy / lead error)")
        for sigma in NOISES:
            cells = []
            for period in PERIODS:
                acc, err = grid[sigma, period]
                cells.append(f"{acc:.2f}/{err:6.2f}")
            print(f"  {sigma:<8g}  " + "  ".join(cells))
        # fleet-level: the topology lead signal names the straggler node
        slow = [int(np.argmin(fs.lead)) for fs in trace.fleet[-20:]]
        named = int(np.bincount(slow).argmax())
        print(f"  fleet lead signal names node {named} "
              f"({'correct' if named == strag_node else 'WRONG'})")


if __name__ == "__main__":
    main()
