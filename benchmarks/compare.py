"""Benchmark-regression gate: compare a fresh ``run.py --smoke`` CSV
against the committed baseline (``benchmarks/BENCH_cluster.json``).

The baseline pins *simulated* throughput metrics (fleet_tput and friends),
which are deterministic given the seeds — not wall-clock timings, which
would flake on shared CI runners.  A fresh value more than ``tolerance``
below its baseline fails the gate; improvements pass (refresh the baseline
when a PR intentionally moves a metric).

Usage:
  python benchmarks/run.py --smoke | tee bench.csv
  python benchmarks/compare.py --baseline benchmarks/BENCH_cluster.json \
      --fresh bench.csv [--write-fresh bench_metrics.json]

The scenario-smoke CI step feeds a ``python -m repro run --json`` result
instead of a CSV (``--fresh-json``) and names which scenario metric maps
onto which baseline key (``--map fleet_tput=cluster_fleet_manager:managed``,
repeatable) — the same tolerance gate then applies to just the mapped
pairs.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


def parse_bench_csv(path: str) -> Dict[str, float]:
    """``name,us_per_call,derived`` rows -> {"name:key": value} for every
    numeric key=value pair in the derived column (';'-separated)."""
    metrics: Dict[str, float] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("name,"):
                continue
            parts = line.split(",", 2)
            if len(parts) != 3:
                continue
            name, _, derived = parts
            for pair in derived.split(";"):
                if "=" not in pair:
                    continue
                key, val = pair.split("=", 1)
                try:
                    metrics[f"{name}:{key}"] = float(val)
                except ValueError:
                    pass                      # non-numeric derived (labels)
    return metrics


def _flatten(d: dict, prefix: str = "") -> Dict[str, float]:
    """Nested numeric dicts -> dotted keys ({"throughput": {"p50": x}} ->
    {"throughput.p50": x}); non-numeric leaves are dropped."""
    out: Dict[str, float] = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, f"{key}."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def load_fresh_json(path: str) -> Dict[str, float]:
    """``python -m repro run --json`` output (``{"metrics": {...}}``), a
    ``repro sweep`` artifact (``{"summary": {...}}`` quantiles flattened to
    dotted keys), or any JSON object of numeric leaves -> flat metrics."""
    with open(path) as f:
        data = json.load(f)
    metrics = data.get("metrics", data.get("summary", data))
    return _flatten(metrics)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON (BENCH_cluster.json)")
    ap.add_argument("--fresh", default=None,
                    help="fresh run.py CSV output to check")
    ap.add_argument("--fresh-json", default=None,
                    help="fresh scenario-result JSON ({'metrics': ...}) "
                         "instead of a CSV")
    ap.add_argument("--map", action="append", default=None,
                    metavar="FRESHKEY=BASEKEY",
                    help="compare only these fresh->baseline metric pairs "
                         "(repeatable; required with --fresh-json)")
    ap.add_argument("--write-fresh", default=None,
                    help="dump all parsed fresh metrics as JSON (artifact)")
    args = ap.parse_args()
    if (args.fresh is None) == (args.fresh_json is None):
        ap.error("give exactly one of --fresh / --fresh-json")

    with open(args.baseline) as f:
        baseline = json.load(f)
    tol = float(baseline.get("tolerance", 0.20))
    fresh = (parse_bench_csv(args.fresh) if args.fresh
             else load_fresh_json(args.fresh_json))

    if args.write_fresh:
        with open(args.write_fresh, "w") as f:
            json.dump({"tolerance": tol, "metrics": fresh}, f, indent=2,
                      sort_keys=True, allow_nan=False)

    if args.map:
        pairs = []
        for m in args.map:
            if "=" not in m:
                ap.error(f"--map expects FRESHKEY=BASEKEY, got {m!r}")
            fk, bk = m.split("=", 1)
            if bk not in baseline["metrics"]:
                ap.error(f"--map: {bk!r} not in the baseline")
            pairs.append((fk, bk))
        checks = [(fk, baseline["metrics"][bk], fk) for fk, bk in pairs]
    else:
        checks = [(key, base, key)
                  for key, base in sorted(baseline["metrics"].items())]

    failures = []
    for fresh_key, base, key in checks:
        if fresh_key not in fresh:
            failures.append(f"MISSING  {fresh_key} (baseline {base:.4f})")
            continue
        val = fresh[fresh_key]
        rel = (val - base) / abs(base) if base else 0.0
        status = "REGRESSED" if rel < -tol else "ok"
        print(f"{status:9s} {key}: fresh={val:.4f} baseline={base:.4f} "
              f"({rel:+.1%}, tolerance -{tol:.0%})")
        if rel < -tol:
            failures.append(f"{key}: {val:.4f} vs {base:.4f} ({rel:+.1%})")
    if failures:
        print(f"\nbenchmark regression gate FAILED "
              f"({len(failures)} metric(s)):", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        sys.exit(1)
    print(f"\nbenchmark regression gate passed "
          f"({len(checks)} metrics within -{tol:.0%})")


if __name__ == "__main__":
    main()
