"""Shared benchmark harness: default paper setup (Table II) + result cache.

Default settings: Llama 3.1 8B, b2s4 (batch 2, seq 4096), FSDP over 8
devices, MI300X node.  Sim knobs are the calibrated defaults; closed-loop
runs tune from halfway (paper Fig 9).
"""
from __future__ import annotations

import os
import sys
import time
from functools import lru_cache
from typing import Dict, List, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config                        # noqa: E402
from repro.core.backends import SimBackend                  # noqa: E402
from repro.core.c3sim import NodeSim, SimConfig             # noqa: E402
from repro.core.manager import ManagerConfig, run_closed_loop  # noqa: E402
from repro.core.thermal import MI300X_PRESET                # noqa: E402
from repro.core.workload import fsdp_llm_iteration          # noqa: E402

ITERS = 200
Row = Tuple[str, float, str]


def make_node(arch: str = "llama3.1-8b", *, batch: int = 2, seq: int = 4096,
              seed: int = 1, n_layers: int = 32, **sim_kw) -> NodeSim:
    cfg = get_config(arch).replace(n_layers=n_layers)
    wl = fsdp_llm_iteration(cfg, batch=batch, seq=seq, n_shards=8)
    sim_kw.setdefault("engine", "batched")   # trace-identical, ~10x faster
    return NodeSim(wl, MI300X_PRESET, SimConfig(seed=seed, comm_gbps=40.0,
                                                **sim_kw), 8, seed=seed)


@lru_cache(maxsize=8)
def settled_baseline(arch: str = "llama3.1-8b", seed: int = 1):
    """Node settled at TDP + its last trace (shared across figures)."""
    node = make_node(arch, seed=seed)
    trace = None
    for _ in range(45):
        trace = node.step()
    return node, trace


def closed_loop_stats(use_case: str, *, iters: int = ITERS, seed: int = 1,
                      arch: str = "llama3.1-8b", **mgr_kw):
    node = make_node(arch, seed=seed)
    kw = dict(sampling_period=2, warmup=3, window_size=2, power_cap=700.0,
              cpu_budget=20.0)
    kw.update(mgr_kw)
    mc = ManagerConfig(use_case=use_case, **kw)
    mgr = run_closed_loop(SimBackend(node), mc, iters)
    h = node.history
    pre = h[iters // 2 - 30: iters // 2]
    post = h[-30:]
    tput = (np.mean([x["throughput"] for x in post])
            / np.mean([x["throughput"] for x in pre]))
    power = (np.mean([np.sum(x["power"]) for x in post])
             / np.mean([np.sum(x["power"]) for x in pre]))
    # convergence: samples until power within 0.5% of final
    powers = np.array([np.sum(x["power"]) for x in h[iters // 2:]])
    final = powers[-20:].mean()
    conv = int(np.argmax(np.abs(powers - final) / final < 0.005))
    cv = float(np.std(powers[conv:]) / np.mean(powers[conv:]))
    return {"node": node, "mgr": mgr, "tput": tput, "power": power,
            "conv_samples": conv, "cv": cv,
            "caps": h[-1]["cap"].copy()}


@lru_cache(maxsize=16)
def cached_case(use_case: str, seed: int = 1):
    return closed_loop_stats(use_case, seed=seed)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
