"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  * paper_figs     — §III characterization + §VII evaluation reproductions
  * kernels_bench  — Pallas kernel oracles + interpret-mode correctness
  * dryrun_summary — multi-pod dry-run / roofline aggregates
"""
import sys
import traceback


def main() -> None:
    from benchmarks import dryrun_summary, kernels_bench, paper_figs
    print("name,us_per_call,derived")
    sections = [("kernels", kernels_bench.run),
                ("dryrun", dryrun_summary.run)]
    sections += [(fn.__name__, fn) for fn in paper_figs.ALL]
    failures = 0
    for name, fn in sections:
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:
            failures += 1
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
