"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  * paper_figs     — §III characterization + §VII evaluation reproductions
  * kernels_bench  — Pallas kernel oracles + interpret-mode correctness
  * dryrun_summary — multi-pod dry-run / roofline aggregates
  * cluster_sweep  — N-node fleet scaling / straggler placement / recovery
  * telemetry      — recording overhead, replay fidelity, detection
                     robustness vs sensor noise
  * serve          — serving SLO surface under a thermal straggler:
                     unmanaged vs throughput vs tail-latency objective

Usage:
  python benchmarks/run.py [--smoke] [--only PREFIX]

``--smoke`` runs the CI subset (cluster sweep at reduced iterations plus the
fastest characterization figures) so the gate finishes in ~a minute; any
``ERROR=`` row still exits nonzero.  ``--only`` filters sections by name
prefix.
"""
import argparse
import os
import sys
import traceback

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: reduced iterations, fast sections only")
    ap.add_argument("--only", default=None,
                    help="run only sections whose name starts with PREFIX")
    args = ap.parse_args()

    from benchmarks import (cluster_sweep, dryrun_summary, kernels_bench,
                            paper_figs, serve_bench, telemetry_bench)
    sections = [("kernels", kernels_bench.run),
                ("dryrun", dryrun_summary.run),
                ("cluster", cluster_sweep.run),
                ("telemetry", telemetry_bench.run),
                ("serve", serve_bench.run)]
    sections += [(fn.__name__, fn) for fn in paper_figs.ALL]
    if args.smoke:
        cluster_sweep.SMOKE = True
        telemetry_bench.SMOKE = True
        serve_bench.SMOKE = True
        fast = {"dryrun", "cluster", "telemetry", "serve",
                "fig3_overlap_and_duration",
                "fig5_thermal_profile", "fig7_lead_waves"}
        sections = [(n, fn) for n, fn in sections if n in fast]
    if args.only:
        available = [n for n, _ in sections]
        sections = [(n, fn) for n, fn in sections
                    if n.startswith(args.only)]
        if not sections:
            print(f"error: --only {args.only!r} matches no benchmark "
                  f"section; available: {', '.join(available)}",
                  file=sys.stderr)
            sys.exit(2)

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:
            failures += 1
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
