"""Telemetry benchmarks: recording overhead and detection robustness.

Rows:
  * telemetry_overhead_{engine} — per-iteration cost of an attached
                                  lossless collector vs a bare sim
  * telemetry_replay            — record a short cluster run, replay the
                                  fleet manager offline, check the cap
                                  schedule matches bit-for-bit
  * telemetry_detect_s{i}       — straggler-detection accuracy + lead
                                  error vs sensor noise (offline degrade
                                  of one lossless recording)
  * telemetry_detect_monotonic  — the accuracy curve is non-increasing
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, make_node
from repro.core.backends import ClusterSimBackend
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.manager import FleetManagerConfig, run_fleet_closed_loop
from repro.core.thermal import MI300X_PRESET
from repro.core.workload import fsdp_llm_iteration
from repro.configs import get_config
from repro.telemetry import (SensorConfig, SensorModel, TelemetryCollector,
                             TelemetryTrace, degrade, detection_report,
                             fleet_replay_matches, replay_fleet)

SMOKE = False           # run.py --smoke trims iterations for CI

NOISE_LEVELS = (0.0, 0.002, 0.01, 0.05, 0.2)


def _iters(full: int) -> int:
    return max(10, full // 4) if SMOKE else full


def collector_overhead() -> List[Row]:
    """Recording cost per engine: the collector must stay a few percent of
    the iteration budget or nobody leaves it attached in production."""
    rows: List[Row] = []
    engines = ("batched",) if SMOKE else ("batched", "event", "vector")
    reps = _iters(24)
    for engine in engines:
        bare = make_node(n_layers=8, engine=engine)
        t0 = time.perf_counter()
        for _ in range(reps):
            bare.step()
        base_us = (time.perf_counter() - t0) / reps * 1e6
        rec = make_node(n_layers=8, engine=engine)
        TelemetryCollector(max_samples=reps + 1).attach_node(rec)
        t0 = time.perf_counter()
        for _ in range(reps):
            rec.step()
        rec_us = (time.perf_counter() - t0) / reps * 1e6
        over = (rec_us - base_us) / base_us
        rows.append((f"telemetry_overhead_{engine}", rec_us,
                     f"base_us={base_us:.0f};recorded_us={rec_us:.0f};"
                     f"overhead_pct={over * 100:.1f}"))
    return rows


def fleet_cfg(n_nodes: int = 2) -> FleetManagerConfig:
    return FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                              warmup=2, window_size=2, node_window_size=2,
                              power_cap=700.0,
                              cluster_power_budget=n_nodes * 8 * 700.0)


def record_managed_cluster(n_nodes: int = 2, iters: int = 40,
                           tune_after: int = 10):
    """The reference record-and-replay setup: a managed 2-node cluster with
    one hot GPU, recorded losslessly.  Returns (cluster, collector,
    live_manager).  Shared with scripts/telemetry_smoke.py so the CI smoke
    and the benchmark validate the exact same configuration.  The managed
    loop needs enough horizon to produce cap adjustments — otherwise a
    caps-match check is vacuous — and is cheap under the batched engine,
    so callers do not trim it in smoke mode."""
    cfg = get_config("llama3.1-8b").replace(n_layers=8)
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=n_nodes, straggler_boost=1.28),
                    devices_per_node=8, seed=5)
    for n in range(n_nodes):
        cl.set_node_caps(n, np.full(8, 700.0))
    col = TelemetryCollector(max_samples=n_nodes * iters + iters)
    col.attach_cluster(cl)
    live = run_fleet_closed_loop(ClusterSimBackend(cl), fleet_cfg(n_nodes),
                                 iters, tune_after=tune_after, collector=col)
    return cl, col, live


def replay_fidelity() -> List[Row]:
    """Record a managed 2-node cluster, replay the fleet manager offline,
    and report whether the replayed cap schedule matches bit-for-bit."""
    t0 = time.perf_counter()
    cl, col, live = record_managed_cluster()
    rp = replay_fleet(TelemetryTrace.from_collector(col), fleet_cfg(),
                      tune_after=10)
    live_caps = np.stack([cl.get_node_caps(n) for n in range(2)])
    match = fleet_replay_matches(live, rp, live_caps)
    us = (time.perf_counter() - t0) * 1e6
    return [("telemetry_replay", us,
             f"samples={len(col.samples)};adjusts={len(live.budget_log)};"
             f"caps_match={int(match)}")]


def detection_robustness() -> List[Row]:
    """Detection accuracy / lead error vs timestamp noise, offline from one
    lossless recording (5 sensor seeds per level)."""
    node = make_node(seed=1)
    col = TelemetryCollector(max_samples=128).attach_node(node)
    t0 = time.perf_counter()
    for _ in range(_iters(60)):
        node.step()
    trace = TelemetryTrace.from_collector(col)
    rows: List[Row] = []
    accs = []
    for i, sigma in enumerate(NOISE_LEVELS):
        t1 = time.perf_counter()
        acc, err = [], []
        for seed in range(5):
            rep = detection_report(degrade(trace, SensorModel(
                SensorConfig(noise_time_s=sigma, sample_period=10,
                             seed=seed))))
            acc.append(rep.accuracy)
            err.append(rep.lead_rel_error)
        accs.append(float(np.mean(acc)))
        us = (time.perf_counter() - t1) * 1e6
        rows.append((f"telemetry_detect_s{i}", us,
                     f"sigma={sigma};acc={np.mean(acc):.3f};"
                     f"lead_err={np.mean(err):.3f}"))
    mono = all(hi <= lo + 0.05 for lo, hi in zip(accs, accs[1:]))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("telemetry_detect_monotonic", us,
                 f"levels={len(NOISE_LEVELS)};monotonic={int(mono)};"
                 f"acc_first={accs[0]:.3f};acc_last={accs[-1]:.3f}"))
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    for fn in (collector_overhead, replay_fidelity, detection_robustness):
        rows.extend(fn())
    return rows
