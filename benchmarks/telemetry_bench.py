"""Telemetry benchmarks: recording overhead and detection robustness.

The recorded runs are scenario-API builds: the record/replay reference is
the registered ``telemetry/replay`` scenario (the same spec the CI smoke
runs — one configuration, zero drifting copies), and the overhead /
robustness nodes are programmatic `Scenario` variants driven through
`build_scenario`.

Rows:
  * telemetry_overhead_{engine} — per-iteration cost of an attached
                                  lossless collector vs a bare sim
  * obs_overhead                — per-iteration cost of the observability
                                  pipeline (metrics + alert rules) vs the
                                  bare collector, gated < 30%
  * telemetry_replay            — record a short cluster run, replay the
                                  fleet manager offline, check the cap
                                  schedule matches bit-for-bit
  * telemetry_detect_s{i}       — straggler-detection accuracy + lead
                                  error vs sensor noise (offline degrade
                                  of one lossless recording)
  * telemetry_detect_monotonic  — the accuracy curve is non-increasing
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.api import (NodeSpec, ObservabilitySpec, Scenario, TelemetrySpec,
                       WorkloadSpec, build_scenario, get_scenario,
                       run_scenario, with_overrides)
from repro.core.c3sim import SimConfig
from repro.core.manager import FleetManagerConfig
from repro.telemetry import (SensorConfig, SensorModel, TelemetryTrace,
                             degrade, detection_report, fleet_lead_report,
                             fleet_replay_matches, replay_fleet)

SMOKE = False           # run.py --smoke trims iterations for CI

NOISE_LEVELS = (0.0, 0.002, 0.01, 0.05, 0.2)


def _iters(full: int) -> int:
    return max(10, full // 4) if SMOKE else full


def _node_scenario(n_layers: int = 8, engine: str = "batched",
                   telemetry=None) -> Scenario:
    return Scenario(
        workload=WorkloadSpec(arch="llama3.1-8b", n_layers=n_layers),
        sim=SimConfig(seed=1, comm_gbps=40.0, engine=engine),
        node=NodeSpec(), telemetry=telemetry, seed=1)


def collector_overhead() -> List[Row]:
    """Recording cost per engine: the collector must stay a few percent of
    the iteration budget or nobody leaves it attached in production."""
    rows: List[Row] = []
    engines = ("batched",) if SMOKE else ("batched", "event", "vector")
    reps = _iters(24)
    for engine in engines:
        bare = build_scenario(_node_scenario(engine=engine)).node
        t0 = time.perf_counter()
        for _ in range(reps):
            bare.step()
        base_us = (time.perf_counter() - t0) / reps * 1e6
        rec = build_scenario(_node_scenario(
            engine=engine,
            telemetry=TelemetrySpec(max_samples=reps + 1))).node
        t0 = time.perf_counter()
        for _ in range(reps):
            rec.step()
        rec_us = (time.perf_counter() - t0) / reps * 1e6
        over = (rec_us - base_us) / base_us
        rows.append((f"telemetry_overhead_{engine}", rec_us,
                     f"base_us={base_us:.0f};recorded_us={rec_us:.0f};"
                     f"overhead_pct={over * 100:.1f}"))
    return rows


def obs_overhead() -> List[Row]:
    """Observability ingest cost: the full pipeline (metrics registry +
    alert rules, evaluated once per fleet sample) vs the bare lossless
    collector, on the managed 2-node reference cluster.  The baseline gate
    pins ``ok`` (overhead < 30%) — a ratio, not a raw wall-clock, so it
    stays stable on shared CI runners."""
    reps, rounds, warmup = _iters(40), 3, 3

    def _us_per_step(observability) -> float:
        sc = get_scenario("telemetry/replay").replace(
            telemetry=TelemetrySpec(max_samples=warmup + rounds * reps + 1),
            observability=observability)
        cl = build_scenario(sc).cluster
        for _ in range(warmup):             # lazy family creation, caches
            cl.step()
        best = float("inf")
        for _ in range(rounds):             # min-of-rounds rides out GC /
            t0 = time.perf_counter()        # scheduler noise on shared CI
            for _ in range(reps):
                cl.step()
            best = min(best, (time.perf_counter() - t0) / reps * 1e6)
        return best

    base_us = _us_per_step(None)
    obs_us = _us_per_step(ObservabilitySpec())
    over = (obs_us - base_us) / base_us
    ok = int(over < 0.30)
    return [("obs_overhead", obs_us,
             f"base_us={base_us:.0f};obs_us={obs_us:.0f};"
             f"overhead_pct={over * 100:.1f};ok={ok}")]


def fleet_cfg(n_nodes: int = 2) -> FleetManagerConfig:
    """The reference fleet-manager knobs — taken from the registered
    ``telemetry/replay`` scenario so the benchmark, the CI smoke and the
    tests all consume the one definition."""
    cfg = get_scenario("telemetry/replay").manager.config
    return dataclasses.replace(cfg,
                               cluster_power_budget=n_nodes * 8 * 700.0)


def record_managed_cluster(n_nodes: int = 2, iters: int = 40,
                           tune_after: int = 10):
    """Run the ``telemetry/replay`` scenario (resized if asked): a managed
    cluster with one hot GPU, recorded losslessly.  Returns (cluster,
    collector, live_manager) — the record-and-replay reference shared with
    scripts/telemetry_smoke.py."""
    sc = get_scenario("telemetry/replay")
    if n_nodes != sc.fleet.n_nodes:
        sc = with_overrides(sc, {"fleet.n_nodes": n_nodes})
        sc.manager.config = fleet_cfg(n_nodes)
    sc.manager.tune_after = tune_after
    res = run_scenario(sc, iterations=iters)
    return res.cluster, res.collector, res.manager


def replay_fidelity() -> List[Row]:
    """Record a managed 2-node cluster, replay the fleet manager offline,
    and report whether the replayed cap schedule matches bit-for-bit."""
    t0 = time.perf_counter()
    cl, col, live = record_managed_cluster()
    rp = replay_fleet(TelemetryTrace.from_collector(col), fleet_cfg(),
                      tune_after=10)
    live_caps = np.stack([cl.get_node_caps(n) for n in range(2)])
    match = fleet_replay_matches(live, rp, live_caps)
    us = (time.perf_counter() - t0) * 1e6
    return [("telemetry_replay", us,
             f"samples={len(col.samples)};adjusts={len(live.budget_log)};"
             f"caps_match={int(match)}")]


def fleet_lead_fidelity() -> List[Row]:
    """The fleet-scope lead estimator scored against the true topology
    lead: a lossless recording (estimator bias only — zero for DP) and a
    noisy fleet sensor (bias + sensed-timestamp noise)."""
    rows: List[Row] = []
    for tag, noise in (("lossless", 0.0), ("noisy", 0.005)):
        t0 = time.perf_counter()
        sc = get_scenario("cluster/dp").replace(
            telemetry=TelemetrySpec(
                sensor=SensorConfig(noise_time_s=noise), with_kernels=False,
                max_samples=64))
        res = run_scenario(sc, iterations=_iters(40))
        rep = fleet_lead_report(TelemetryTrace.from_collector(res.collector))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"telemetry_fleet_lead_{tag}", us,
                     f"noise_s={noise};{rep.row()}"))
    return rows


def detection_robustness() -> List[Row]:
    """Detection accuracy / lead error vs timestamp noise, offline from one
    lossless recording (5 sensor seeds per level)."""
    built = build_scenario(_node_scenario(
        n_layers=32, telemetry=TelemetrySpec(max_samples=128)))
    node = built.node
    t0 = time.perf_counter()
    for _ in range(_iters(60)):
        node.step()
    trace = TelemetryTrace.from_collector(built.collector)
    rows: List[Row] = []
    accs = []
    for i, sigma in enumerate(NOISE_LEVELS):
        t1 = time.perf_counter()
        acc, err = [], []
        for seed in range(5):
            rep = detection_report(degrade(trace, SensorModel(
                SensorConfig(noise_time_s=sigma, sample_period=10,
                             seed=seed))))
            acc.append(rep.accuracy)
            err.append(rep.lead_rel_error)
        accs.append(float(np.mean(acc)))
        us = (time.perf_counter() - t1) * 1e6
        rows.append((f"telemetry_detect_s{i}", us,
                     f"sigma={sigma};acc={np.mean(acc):.3f};"
                     f"lead_err={np.mean(err):.3f}"))
    mono = all(hi <= lo + 0.05 for lo, hi in zip(accs, accs[1:]))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("telemetry_detect_monotonic", us,
                 f"levels={len(NOISE_LEVELS)};monotonic={int(mono)};"
                 f"acc_first={accs[0]:.3f};acc_last={accs[-1]:.3f}"))
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    for fn in (collector_overhead, obs_overhead, replay_fidelity,
               fleet_lead_fidelity, detection_robustness):
        rows.extend(fn())
    return rows
