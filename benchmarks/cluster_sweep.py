"""Cluster-scale sweep: fleet throughput vs node count, straggler
placement, and parallelism topology, plus the hierarchical manager's
recovery — the datacenter-scale aggregation of the paper's node-level claim.

Rows:
  * cluster_scale_N{n}       — fleet throughput per node as the fleet grows
                               (barrier + slower inter-node all-reduce)
  * cluster_straggler_*      — healthy vs one hot GPU, by placement
  * cluster_topology_{t}     — coupling strength per topology (dp/pp/tp)
  * cluster_hetero           — preset-driven straggler (air-cooled node)
  * cluster_churn            — straggler migration under cooling churn
  * cluster_fleet_manager    — FleetPowerManager recovery under a fixed
                               cluster power budget
  * c3_engine_speedup        — batched fast path vs event-loop reference
  * cluster_vector_speedup   — vectorized all-lanes engine vs per-node
                               batched at sweep scale
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, make_node
from repro.configs import get_config
from repro.core.backends import ClusterSimBackend
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.manager import FleetManagerConfig, run_fleet_closed_loop
from repro.core.thermal import ChurnEvent, ChurnModel, MI300X_PRESET
from repro.core.workload import fsdp_llm_iteration

CAP = 700.0
SMOKE = False           # run.py --smoke trims iterations for CI


def _iters(full: int) -> int:
    return max(10, full // 4) if SMOKE else full


def _workload(n_layers: int = 8):
    cfg = get_config("llama3.1-8b").replace(n_layers=n_layers)
    return fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)


def _cluster(wl, n_nodes, boost, seed=5, straggler_node=0, caps=CAP,
             **cc_kw):
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=n_nodes, straggler_boost=boost,
                                  straggler_node=straggler_node, **cc_kw),
                    devices_per_node=8, seed=seed)
    if caps is not None:
        for n in range(n_nodes):
            cl.set_node_caps(n, np.full(8, caps))
    return cl


def scale_sweep() -> List[Row]:
    """Fleet throughput vs node count (straggler on node 0)."""
    wl = _workload()
    rows: List[Row] = []
    base = None
    for n_nodes in (1, 2, 4, 8):
        t0 = time.perf_counter()
        cl = _cluster(wl, n_nodes, boost=1.28)
        for _ in range(_iters(40)):
            cl.step()
        tput = cl.fleet_throughput(last=10)
        us = (time.perf_counter() - t0) * 1e6
        base = tput if base is None else base
        rows.append((f"cluster_scale_N{n_nodes}", us,
                     f"fleet_tput={tput:.3f};per_node_eff={tput / base:.3f};"
                     f"allreduce_ms={cl.allreduce_time() * 1e3:.1f}"))
    return rows


def straggler_placement() -> List[Row]:
    """One hot GPU vs healthy fleet, straggler on node 0 vs last node."""
    wl = _workload()
    rows: List[Row] = []
    cases = [("healthy", 1.0, 0), ("node0", 1.28, 0), ("node3", 1.28, 3)]
    tputs = {}
    for label, boost, where in cases:
        t0 = time.perf_counter()
        cl = _cluster(wl, 4, boost=boost, straggler_node=where)
        for _ in range(_iters(60)):
            cl.step()
        tputs[label] = cl.fleet_throughput()
        us = (time.perf_counter() - t0) * 1e6
        slow = [h["slowest_node"] for h in cl.history[-10:]]
        rows.append((f"cluster_straggler_{label}", us,
                     f"fleet_tput={tputs[label]:.4f};"
                     f"slowest_node_mode={int(np.bincount(slow).argmax())}"))
    gap = (tputs["healthy"] - tputs["node0"]) / tputs["healthy"]
    rows.append(("cluster_straggler_gap", 0.0, f"gap={gap:+.3%}"))
    return rows


def fleet_manager_recovery() -> List[Row]:
    """FleetPowerManager under a fixed cluster budget of N*G*700 W."""
    wl = _workload()
    t0 = time.perf_counter()
    healthy = _cluster(wl, 4, boost=1.0)
    strag = _cluster(wl, 4, boost=1.28)
    for _ in range(60):
        healthy.step()
        strag.step()
    managed = _cluster(wl, 4, boost=1.28)
    # the closed loop needs its full horizon to converge — not trimmed in
    # smoke mode (it is cheap under the batched engine)
    mgr = run_fleet_closed_loop(
        ClusterSimBackend(managed),
        FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=CAP, cluster_power_budget=4 * 8 * CAP),
        120, tune_after=20)
    us = (time.perf_counter() - t0) * 1e6
    tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()
    tp_m = managed.fleet_throughput()
    rec = (tp_m - tp_s) / max(tp_h - tp_s, 1e-12)
    return [("cluster_fleet_manager", us,
             f"healthy={tp_h:.4f};straggler={tp_s:.4f};managed={tp_m:.4f};"
             f"recovered={rec:.2f};"
             f"node0_budget={mgr.node_budgets[0]:.0f}W")]


def engine_speedup() -> List[Row]:
    """Batched fast path vs the event-loop reference engine."""
    node = make_node()
    freq = node.state.freq
    reps = 2 if SMOKE else 5
    out = []
    for engine in ("event", "batched"):
        t0 = time.perf_counter()
        for _ in range(reps):
            node.sim.run_iteration(freq, engine=engine)
        out.append((time.perf_counter() - t0) / reps * 1e6)
    ev, ba = out
    return [("c3_engine_speedup", ba,
             f"event_us={ev:.0f};batched_us={ba:.0f};"
             f"speedup={ev / ba:.1f}x")]


def topology_coupling() -> List[Row]:
    """Coupling strength per parallelism topology: one hot GPU's relative
    fleet-throughput cost under dp / pp / tp (fast DP fabric so the
    all-reduce constant does not drown the coupling term)."""
    wl = _workload()
    rows: List[Row] = []
    gaps = {}
    for topo in ("dp", "pp", "tp"):
        t0 = time.perf_counter()
        healthy = _cluster(wl, 4, boost=1.0, topology=topo,
                           inter_node_gbps=100.0)
        hot = _cluster(wl, 4, boost=1.28, topology=topo,
                       inter_node_gbps=100.0)
        # thermal settling needs the full horizon (tau >> t_iter) — cheap
        # under the batched engine, so not trimmed in smoke mode
        for _ in range(50):
            healthy.step()
            hot.step()
        tp_h, tp_s = healthy.fleet_throughput(), hot.fleet_throughput()
        gaps[topo] = (tp_h - tp_s) / tp_h
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"cluster_topology_{topo}", us,
                     f"healthy_tput={tp_h:.4f};hot_tput={tp_s:.4f};"
                     f"coupling={gaps[topo]:.5f}"))
    order_ok = gaps["tp"] >= gaps["dp"] >= gaps["pp"]
    rows.append(("cluster_topology_order", 0.0,
                 f"tp={gaps['tp']:.5f};dp={gaps['dp']:.5f};"
                 f"pp={gaps['pp']:.5f};tp_ge_dp_ge_pp={int(order_ok)}"))
    return rows


def hetero_fleet() -> List[Row]:
    """Mixed air-/liquid-cooled fleet: the preset, not a boosted device,
    creates the straggler."""
    wl = _workload()
    t0 = time.perf_counter()
    cl = _cluster(wl, 4, boost=1.0, inter_node_gbps=100.0,
                  node_presets=["mi300x", "mi300x-air", "mi300x", "mi300x"])
    for _ in range(_iters(50)):
        cl.step()
    us = (time.perf_counter() - t0) * 1e6
    slow = [h["slowest_node"] for h in cl.history[-10:]]
    return [("cluster_hetero", us,
             f"fleet_tput={cl.fleet_throughput():.4f};"
             f"slowest_node_mode={int(np.bincount(slow).argmax())}")]


def churn_migration() -> List[Row]:
    """Cooling churn: a straggler emerges on node 0, then migrates to
    node 2 when a harder degradation lands there mid-run."""
    wl = _workload()
    t0 = time.perf_counter()
    probe = _cluster(wl, 4, boost=1.0, inter_node_gbps=100.0)
    probe.step()
    t1 = probe.history[0]["t_fleet"]
    # churn dynamics ride the thermal time constant — full horizon always
    iters = 80
    churn = {0: ChurnModel(events=[ChurnEvent(0.0, 3, 1.35)]),
             2: ChurnModel(events=[ChurnEvent(0.4 * iters * t1, 5, 1.8)])}
    cl = _cluster(wl, 4, boost=1.0, inter_node_gbps=100.0, churn=churn)
    for _ in range(iters):
        cl.step()
    us = (time.perf_counter() - t0) * 1e6
    slow = np.array([h["slowest_node"] for h in cl.history])
    early = int(np.bincount(slow[5:iters // 3]).argmax())
    late = int(np.bincount(slow[-iters // 4:]).argmax())
    return [("cluster_churn", us,
             f"early_straggler=node{early};late_straggler=node{late};"
             f"migrated={int(early != late)}")]


def vector_speedup() -> List[Row]:
    """Vectorized all-lanes cluster engine vs per-node batched runs at
    sweep scale (the ROADMAP per-window device-loop item)."""
    wl = _workload()
    n_nodes = 8 if SMOKE else 16
    reps = _iters(12)
    out = {}
    for engine in ("batched", "vector"):
        cl = _cluster(wl, n_nodes, boost=1.28, engine=engine)
        t0 = time.perf_counter()
        for _ in range(reps):
            cl.step()
        out[engine] = (time.perf_counter() - t0) / reps * 1e6
    return [("cluster_vector_speedup", out["vector"],
             f"nodes={n_nodes};batched_us={out['batched']:.0f};"
             f"vector_us={out['vector']:.0f};"
             f"speedup={out['batched'] / out['vector']:.2f}x")]


def run() -> List[Row]:
    rows: List[Row] = []
    for fn in (engine_speedup, vector_speedup, scale_sweep,
               straggler_placement, topology_coupling, hetero_fleet,
               churn_migration, fleet_manager_recovery):
        rows.extend(fn())
    return rows
