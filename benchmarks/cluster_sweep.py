"""Cluster-scale sweep: fleet throughput vs node count, straggler
placement, and parallelism topology, plus the hierarchical manager's
recovery — the datacenter-scale aggregation of the paper's node-level claim.

All fleets are built through the scenario API (`repro.api`): each row is a
`Scenario` — either a registered one (``cluster/dp``,
``cluster/hetero-cooling``) or a programmatic variant — run through the
same `run_scenario`/`build_scenario` driver the CLI uses, with the derived
metrics bit-identical to the pre-API hand-wired builders (equivalence is
pinned in tests/test_scenario_api.py).

Rows:
  * cluster_scale_N{n}       — fleet throughput per node as the fleet grows
                               (barrier + slower inter-node all-reduce)
  * cluster_straggler_*      — healthy vs one hot GPU, by placement
  * cluster_topology_{t}     — coupling strength per topology (dp/pp/tp)
  * cluster_hetero           — preset-driven straggler (air-cooled node)
  * cluster_churn            — straggler migration under cooling churn
  * cluster_fleet_manager    — FleetPowerManager recovery under a fixed
                               cluster power budget
  * cluster_fault_recovery   — goodput of detect→drain→elastic restart vs
                               ignoring the fault vs hair-trigger draining
                               (the registered ``cluster/fault-heal`` /
                               ``cluster/fault-ignored`` scenarios)
  * c3_engine_speedup        — batched fast path vs event-loop reference
  * cluster_vector_speedup   — vectorized all-lanes engine vs per-node
                               batched at sweep scale
"""
from __future__ import annotations

import time
from typing import List, Optional

import numpy as np

from benchmarks.common import Row, make_node
from repro.api import (NodeSpec, Scenario, WorkloadSpec, build_scenario,
                       get_scenario, run_scenario)
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig
from repro.core.thermal import ChurnEvent, ChurnModel

CAP = 700.0
SMOKE = False           # run.py --smoke trims iterations for CI


def _iters(full: int) -> int:
    return max(10, full // 4) if SMOKE else full


def _scenario(n_nodes: int, boost: float, iterations: int, seed: int = 5,
              straggler_node: int = 0, caps: Optional[float] = CAP,
              **cc_kw) -> Scenario:
    """A fleet scenario with the sweep's shared defaults (8-layer Llama,
    calibrated sim knobs, 700 W initial caps) — the spec-level analogue of
    the old hand-wired ``_cluster`` builder."""
    return Scenario(
        workload=WorkloadSpec(arch="llama3.1-8b", n_layers=8),
        sim=SimConfig(seed=1, comm_gbps=40.0, engine="batched"),
        node=NodeSpec(caps_w=caps),
        fleet=ClusterConfig(n_nodes=n_nodes, straggler_boost=boost,
                            straggler_node=straggler_node, **cc_kw),
        iterations=iterations, seed=seed)


def scale_sweep() -> List[Row]:
    """Fleet throughput vs node count (straggler on node 0)."""
    rows: List[Row] = []
    base = None
    for n_nodes in (1, 2, 4, 8):
        t0 = time.perf_counter()
        res = run_scenario(_scenario(n_nodes, 1.28, _iters(40)))
        tput = res.cluster.fleet_throughput(last=10)
        us = (time.perf_counter() - t0) * 1e6
        base = tput if base is None else base
        rows.append((f"cluster_scale_N{n_nodes}", us,
                     f"fleet_tput={tput:.3f};per_node_eff={tput / base:.3f};"
                     f"allreduce_ms={res.cluster.allreduce_time() * 1e3:.1f}"))
    return rows


def straggler_placement() -> List[Row]:
    """One hot GPU vs healthy fleet, straggler on node 0 vs last node."""
    rows: List[Row] = []
    cases = [("healthy", 1.0, 0), ("node0", 1.28, 0), ("node3", 1.28, 3)]
    tputs = {}
    for label, boost, where in cases:
        t0 = time.perf_counter()
        res = run_scenario(_scenario(4, boost, _iters(60),
                                     straggler_node=where))
        tputs[label] = res.cluster.fleet_throughput()
        us = (time.perf_counter() - t0) * 1e6
        slow = [h["slowest_node"] for h in res.cluster.history[-10:]]
        rows.append((f"cluster_straggler_{label}", us,
                     f"fleet_tput={tputs[label]:.4f};"
                     f"slowest_node_mode={int(np.bincount(slow).argmax())}"))
    gap = (tputs["healthy"] - tputs["node0"]) / tputs["healthy"]
    rows.append(("cluster_straggler_gap", 0.0, f"gap={gap:+.3%}"))
    return rows


def fleet_manager_recovery() -> List[Row]:
    """FleetPowerManager under a fixed cluster budget of N*G*700 W: the
    registered ``cluster/dp`` scenario is the managed leg."""
    t0 = time.perf_counter()
    healthy = run_scenario(_scenario(4, 1.0, 60))
    strag = run_scenario(_scenario(4, 1.28, 60))
    # the closed loop needs its full horizon to converge — not trimmed in
    # smoke mode (it is cheap under the batched engine)
    managed = run_scenario(get_scenario("cluster/dp"))
    us = (time.perf_counter() - t0) * 1e6
    tp_h = healthy.metrics["fleet_tput"]
    tp_s = strag.metrics["fleet_tput"]
    tp_m = managed.metrics["fleet_tput"]
    rec = (tp_m - tp_s) / max(tp_h - tp_s, 1e-12)
    return [("cluster_fleet_manager", us,
             f"healthy={tp_h:.4f};straggler={tp_s:.4f};managed={tp_m:.4f};"
             f"recovered={rec:.2f};"
             f"node0_budget={managed.manager.node_budgets[0]:.0f}W")]


def fault_recovery() -> List[Row]:
    """The escalation layer's acceptance ordering, as gated metrics:
    healing (detect → drain → elastic restart) must out-goodput both
    ignoring the fault and draining on the first blip.  The fault schedule
    is pinned in simulated seconds, so the full horizon always runs (the
    runs are cheap under the batched engine)."""
    from repro.api import with_overrides
    t0 = time.perf_counter()
    heal = run_scenario(get_scenario("cluster/fault-heal"))
    ignored = run_scenario(get_scenario("cluster/fault-ignored"))
    immediate = run_scenario(with_overrides(
        get_scenario("cluster/fault-heal"),
        {"escalation.drain_mode": "immediate"}))
    us = (time.perf_counter() - t0) * 1e6
    g_heal = heal.metrics["goodput"]
    g_ign = ignored.metrics["goodput"]
    g_imm = immediate.metrics["goodput"]
    return [("cluster_fault_recovery", us,
             f"heal_goodput={g_heal:.4f};ignored_goodput={g_ign:.4f};"
             f"immediate_goodput={g_imm:.4f};"
             f"heal_over_ignored={g_heal / g_ign:.2f};"
             f"detect_s={heal.metrics['time_to_detect_s']:.2f};"
             f"false_drains={heal.metrics['false_drains']};"
             f"immediate_false_drains={immediate.metrics['false_drains']}")]


def engine_speedup() -> List[Row]:
    """Batched fast path vs the event-loop reference engine (kernel-level
    micro-benchmark: times `C3Sim.run_iteration` itself, below the
    scenario layer)."""
    node = make_node()
    freq = node.state.freq
    reps = 2 if SMOKE else 5
    out = []
    for engine in ("event", "batched"):
        t0 = time.perf_counter()
        for _ in range(reps):
            node.sim.run_iteration(freq, engine=engine)
        out.append((time.perf_counter() - t0) / reps * 1e6)
    ev, ba = out
    return [("c3_engine_speedup", ba,
             f"event_us={ev:.0f};batched_us={ba:.0f};"
             f"speedup={ev / ba:.1f}x")]


def topology_coupling() -> List[Row]:
    """Coupling strength per parallelism topology: one hot GPU's relative
    fleet-throughput cost under dp / pp / tp (fast DP fabric so the
    all-reduce constant does not drown the coupling term)."""
    rows: List[Row] = []
    gaps = {}
    for topo in ("dp", "pp", "tp"):
        t0 = time.perf_counter()
        # thermal settling needs the full horizon (tau >> t_iter) — cheap
        # under the batched engine, so not trimmed in smoke mode
        healthy = run_scenario(_scenario(4, 1.0, 50, topology=topo,
                                         inter_node_gbps=100.0))
        hot = run_scenario(_scenario(4, 1.28, 50, topology=topo,
                                     inter_node_gbps=100.0))
        tp_h = healthy.metrics["fleet_tput"]
        tp_s = hot.metrics["fleet_tput"]
        gaps[topo] = (tp_h - tp_s) / tp_h
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"cluster_topology_{topo}", us,
                     f"healthy_tput={tp_h:.4f};hot_tput={tp_s:.4f};"
                     f"coupling={gaps[topo]:.5f}"))
    order_ok = gaps["tp"] >= gaps["dp"] >= gaps["pp"]
    rows.append(("cluster_topology_order", 0.0,
                 f"tp={gaps['tp']:.5f};dp={gaps['dp']:.5f};"
                 f"pp={gaps['pp']:.5f};tp_ge_dp_ge_pp={int(order_ok)}"))
    return rows


def hetero_fleet() -> List[Row]:
    """Mixed air-/liquid-cooled fleet: the preset, not a boosted device,
    creates the straggler (the registered ``cluster/hetero-cooling``)."""
    t0 = time.perf_counter()
    res = run_scenario(get_scenario("cluster/hetero-cooling"),
                       iterations=_iters(50))
    us = (time.perf_counter() - t0) * 1e6
    slow = [h["slowest_node"] for h in res.cluster.history[-10:]]
    return [("cluster_hetero", us,
             f"fleet_tput={res.metrics['fleet_tput']:.4f};"
             f"slowest_node_mode={int(np.bincount(slow).argmax())}")]


def churn_migration() -> List[Row]:
    """Cooling churn: a straggler emerges on node 0, then migrates to
    node 2 when a harder degradation lands there mid-run."""
    t0 = time.perf_counter()
    probe = run_scenario(_scenario(4, 1.0, 1, inter_node_gbps=100.0))
    t1 = probe.cluster.history[0]["t_fleet"]
    # churn dynamics ride the thermal time constant — full horizon always
    iters = 80
    churn = {0: ChurnModel(events=[ChurnEvent(0.0, 3, 1.35)]),
             2: ChurnModel(events=[ChurnEvent(0.4 * iters * t1, 5, 1.8)])}
    res = run_scenario(_scenario(4, 1.0, iters, inter_node_gbps=100.0,
                                 churn=churn))
    us = (time.perf_counter() - t0) * 1e6
    slow = np.array([h["slowest_node"] for h in res.cluster.history])
    early = int(np.bincount(slow[5:iters // 3]).argmax())
    late = int(np.bincount(slow[-iters // 4:]).argmax())
    return [("cluster_churn", us,
             f"early_straggler=node{early};late_straggler=node{late};"
             f"migrated={int(early != late)}")]


def vector_speedup() -> List[Row]:
    """Vectorized all-lanes cluster engine vs per-node batched runs at
    sweep scale (the ROADMAP per-window device-loop item)."""
    n_nodes = 8 if SMOKE else 16
    reps = _iters(12)
    out = {}
    for engine in ("batched", "vector"):
        built = build_scenario(_scenario(n_nodes, 1.28, reps,
                                         engine=engine))
        t0 = time.perf_counter()
        for _ in range(reps):
            built.cluster.step()
        out[engine] = (time.perf_counter() - t0) / reps * 1e6
    return [("cluster_vector_speedup", out["vector"],
             f"nodes={n_nodes};batched_us={out['batched']:.0f};"
             f"vector_us={out['vector']:.0f};"
             f"speedup={out['batched'] / out['vector']:.2f}x")]


def jax_speedup() -> List[Row]:
    """End-to-end jitted fleet scan (`run_fleet_scan`, the engine="jax"
    whole-run program behind Monte-Carlo sweeps) vs the vectorized numpy
    engine, stepping a 256-node fleet.

    Both legs are timed end-to-end from construction: the ClusterSim leg
    pays its per-node 30-iteration thermal warmup at build time, the scan
    leg runs the same warmup inside the program — so each leg is charged
    the identical physics.  Compile time is excluded (the program caches
    per workload plan / fleet shape, which is how sweeps use it)."""
    from repro.core.jax_engine import HAS_JAX
    if not HAS_JAX:
        return [("cluster_jax_speedup", 0.0,
                 "nodes=0;skipped=jax_unavailable")]
    from repro.core.jax_engine import (build_fleet_arrays, fleet_scan_spec,
                                      run_fleet_scan)
    n_nodes = 256
    reps = _iters(12)
    sc = _scenario(n_nodes, 1.28, reps, engine="vector")
    t0 = time.perf_counter()
    built = build_scenario(sc)
    for _ in range(reps):
        built.cluster.step()
    vector_s = time.perf_counter() - t0
    wl = sc.workload.build()
    spec = fleet_scan_spec(wl, sc.sim, sc.fleet, reps, collect="summary")
    warm = build_fleet_arrays(wl, sc.node.build_preset(), sc.sim,
                              sc.fleet, sc.node.caps_w, sc.seed)
    run_fleet_scan(spec, warm)              # compile once (cached program)
    t0 = time.perf_counter()
    arrays = build_fleet_arrays(wl, sc.node.build_preset(), sc.sim,
                                sc.fleet, sc.node.caps_w, sc.seed)
    run_fleet_scan(spec, arrays)
    scan_s = time.perf_counter() - t0
    # bare float (no cosmetic "x" suffix) so compare.py can gate it
    return [("cluster_jax_speedup", scan_s / reps * 1e6,
             f"nodes={n_nodes};iters={reps};vector_ms={vector_s * 1e3:.0f};"
             f"scan_ms={scan_s * 1e3:.0f};"
             f"speedup={vector_s / scan_s:.2f}")]


def run() -> List[Row]:
    rows: List[Row] = []
    for fn in (engine_speedup, vector_speedup, jax_speedup, scale_sweep,
               straggler_placement, topology_coupling, hetero_fleet,
               churn_migration, fleet_manager_recovery, fault_recovery):
        rows.extend(fn())
    return rows
