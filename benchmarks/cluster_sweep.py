"""Cluster-scale sweep: fleet throughput vs node count and straggler
placement, plus the hierarchical manager's recovery — the datacenter-scale
aggregation of the paper's node-level claim.

Rows:
  * cluster_scale_N{n}       — fleet throughput per node as the fleet grows
                               (barrier + slower inter-node all-reduce)
  * cluster_straggler_*      — healthy vs one hot GPU, by placement
  * cluster_fleet_manager    — FleetPowerManager recovery under a fixed
                               cluster power budget
  * c3_engine_speedup        — batched fast path vs event-loop reference
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, make_node
from repro.configs import get_config
from repro.core.backends import ClusterSimBackend
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.manager import FleetManagerConfig, run_fleet_closed_loop
from repro.core.thermal import MI300X_PRESET
from repro.core.workload import fsdp_llm_iteration

CAP = 700.0
SMOKE = False           # run.py --smoke trims iterations for CI


def _iters(full: int) -> int:
    return max(10, full // 4) if SMOKE else full


def _workload(n_layers: int = 8):
    cfg = get_config("llama3.1-8b").replace(n_layers=n_layers)
    return fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)


def _cluster(wl, n_nodes, boost, seed=5, straggler_node=0, caps=CAP):
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=n_nodes, straggler_boost=boost,
                                  straggler_node=straggler_node),
                    devices_per_node=8, seed=seed)
    if caps is not None:
        for n in range(n_nodes):
            cl.set_node_caps(n, np.full(8, caps))
    return cl


def scale_sweep() -> List[Row]:
    """Fleet throughput vs node count (straggler on node 0)."""
    wl = _workload()
    rows: List[Row] = []
    base = None
    for n_nodes in (1, 2, 4, 8):
        t0 = time.perf_counter()
        cl = _cluster(wl, n_nodes, boost=1.28)
        for _ in range(_iters(40)):
            cl.step()
        tput = cl.fleet_throughput(last=10)
        us = (time.perf_counter() - t0) * 1e6
        base = tput if base is None else base
        rows.append((f"cluster_scale_N{n_nodes}", us,
                     f"fleet_tput={tput:.3f};per_node_eff={tput / base:.3f};"
                     f"allreduce_ms={cl.allreduce_time() * 1e3:.1f}"))
    return rows


def straggler_placement() -> List[Row]:
    """One hot GPU vs healthy fleet, straggler on node 0 vs last node."""
    wl = _workload()
    rows: List[Row] = []
    cases = [("healthy", 1.0, 0), ("node0", 1.28, 0), ("node3", 1.28, 3)]
    tputs = {}
    for label, boost, where in cases:
        t0 = time.perf_counter()
        cl = _cluster(wl, 4, boost=boost, straggler_node=where)
        for _ in range(_iters(60)):
            cl.step()
        tputs[label] = cl.fleet_throughput()
        us = (time.perf_counter() - t0) * 1e6
        slow = [h["slowest_node"] for h in cl.history[-10:]]
        rows.append((f"cluster_straggler_{label}", us,
                     f"fleet_tput={tputs[label]:.4f};"
                     f"slowest_node_mode={int(np.bincount(slow).argmax())}"))
    gap = (tputs["healthy"] - tputs["node0"]) / tputs["healthy"]
    rows.append(("cluster_straggler_gap", 0.0, f"gap={gap:+.3%}"))
    return rows


def fleet_manager_recovery() -> List[Row]:
    """FleetPowerManager under a fixed cluster budget of N*G*700 W."""
    wl = _workload()
    t0 = time.perf_counter()
    healthy = _cluster(wl, 4, boost=1.0)
    strag = _cluster(wl, 4, boost=1.28)
    for _ in range(60):
        healthy.step()
        strag.step()
    managed = _cluster(wl, 4, boost=1.28)
    # the closed loop needs its full horizon to converge — not trimmed in
    # smoke mode (it is cheap under the batched engine)
    mgr = run_fleet_closed_loop(
        ClusterSimBackend(managed),
        FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=CAP, cluster_power_budget=4 * 8 * CAP),
        120, tune_after=20)
    us = (time.perf_counter() - t0) * 1e6
    tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()
    tp_m = managed.fleet_throughput()
    rec = (tp_m - tp_s) / max(tp_h - tp_s, 1e-12)
    return [("cluster_fleet_manager", us,
             f"healthy={tp_h:.4f};straggler={tp_s:.4f};managed={tp_m:.4f};"
             f"recovered={rec:.2f};"
             f"node0_budget={mgr.node_budgets[0]:.0f}W")]


def engine_speedup() -> List[Row]:
    """Batched fast path vs the event-loop reference engine."""
    node = make_node()
    freq = node.state.freq
    reps = 2 if SMOKE else 5
    out = []
    for engine in ("event", "batched"):
        t0 = time.perf_counter()
        for _ in range(reps):
            node.sim.run_iteration(freq, engine=engine)
        out.append((time.perf_counter() - t0) / reps * 1e6)
    ev, ba = out
    return [("c3_engine_speedup", ba,
             f"event_us={ev:.0f};batched_us={ba:.0f};"
             f"speedup={ev / ba:.1f}x")]


def run() -> List[Row]:
    rows: List[Row] = []
    for fn in (engine_speedup, scale_sweep, straggler_placement,
               fleet_manager_recovery):
        rows.extend(fn())
    return rows
