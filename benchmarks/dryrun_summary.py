"""Summarize the dry-run/roofline cache (results/dryrun/*.json) as benchmark
rows — the §Dry-run / §Roofline data source."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load_cells(tag: str = "sp"):
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*-{tag}.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def run() -> List[Row]:
    rows: List[Row] = []
    for tag, label in (("sp", "single_pod"), ("mp", "multi_pod")):
        cells = load_cells(tag)
        ran = [c for c in cells if not c.get("skipped")]
        skipped = [c for c in cells if c.get("skipped")]
        if not ran:
            rows.append((f"dryrun_{label}", 0.0, "missing=run dryrun --all"))
            continue
        fracs = [(c["roofline"]["roofline_fraction"], c["arch"], c["shape"])
                 for c in ran]
        fits = sum(1 for c in ran if c.get("fits_hbm"))
        doms = {}
        for c in ran:
            doms[c["roofline"]["dominant"]] = \
                doms.get(c["roofline"]["dominant"], 0) + 1
        best = max(fracs)
        worst = min(f for f in fracs if f[2].startswith("train"))
        compile_s = sum(c["t_compile_s"] for c in ran)
        rows.append((
            f"dryrun_{label}", compile_s * 1e6 / max(len(ran), 1),
            f"cells={len(ran)};skipped={len(skipped)};fits_hbm={fits};"
            f"dominant={'/'.join(f'{k}:{v}' for k, v in doms.items())};"
            f"best_frac={best[0]:.3f}({best[1]}|{best[2]});"
            f"worst_train_frac={worst[0]:.3f}({worst[1]}|{worst[2]})"))
    return rows
