"""One benchmark per paper table/figure (§III characterization + §VII eval).

Each function returns rows of (name, us_per_call, derived) where derived is
a ';'-separated key=value summary matching the figure's claim.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import (ITERS, Row, cached_case, closed_loop_stats,
                               make_node, settled_baseline)
from repro.core.detect import (classify_overlap, cosine, lead_value_detect,
                               overlap_duration_correlation, pearson,
                               straggler_index)
from repro.core.perf_model import predict_speedup
from repro.core.power_model import predict_power


def _weighted_overlap(tr):
    w = tr.comp_dur
    return (tr.overlap_ratio * w).sum(1) / w.sum(1)


def fig3_overlap_and_duration() -> List[Row]:
    """Fig 3: overlap ratio + comm duration, straggler vs leaders."""
    t0 = time.perf_counter()
    node, tr = settled_baseline()
    s = straggler_index(tr.comp_start)
    # forward-phase kernels (paper Fig 3a layers view): leaders wait at the
    # fwd AGs while the straggler streams through
    fwd = np.array([n.startswith("f_") for n in tr.comp_names])
    w = tr.comp_dur[:, fwd]
    ov = (tr.overlap_ratio[:, fwd] * w).sum(1) / w.sum(1)
    leaders = np.delete(ov, s)
    comm = np.nanmean(tr.comm_dur, axis=1)
    comm_norm = comm / comm.min()
    us = (time.perf_counter() - t0) * 1e6
    return [("fig3_overlap", us,
             f"straggler_overlap={ov[s]:.3f};leader_max={leaders.max():.3f};"
             f"leader_to_straggler={leaders.max() / ov[s]:.2f}x;"
             f"comm_dur_spread={comm_norm.max():.3f}")]


def fig4_correlation() -> List[Row]:
    """Fig 4: Pearson/cosine correlation of overlap ratio vs duration."""
    node, _ = settled_baseline()
    t0 = time.perf_counter()
    ovs, durs = [], []
    for _ in range(8):
        tr = node.step()
        ovs.append(tr.overlap_ratio)
        durs.append(tr.comp_dur)
    rows = []
    names = tr.comp_names
    for kname in ("f_qkv_ip", "f_attn_op", "b_mlp_dp", "f_attn_fa"):
        idx = [i for i, n in enumerate(names) if n == kname]
        o = np.stack([o_[:, idx] for o_ in ovs]).ravel()
        d = np.stack([d_[:, idx] for d_ in durs]).ravel()
        p, c = pearson(o, d), cosine(o, d)
        rows.append((f"fig4_corr_{kname}",
                     (time.perf_counter() - t0) * 1e6 / 4,
                     f"pearson={p:.3f};cosine={c:.3f}"))
    return rows


def fig5_thermal_profile() -> List[Row]:
    """Fig 5: temperature & frequency ratios (paper: 1.155x / 1.062x)."""
    t0 = time.perf_counter()
    node, tr = settled_baseline()
    st = node.state
    us = (time.perf_counter() - t0) * 1e6
    t_ratio = st.temp.max() / st.temp.min()
    f_ratio = st.freq.max() / st.freq.min()
    # temperature and frequency orders roughly inverse (§III-B)
    corr = pearson(st.temp, -st.freq)
    return [("fig5_thermal", us,
             f"temp_ratio={t_ratio:.3f};freq_ratio={f_ratio:.3f};"
             f"temp_vs_negfreq_pearson={corr:.3f}")]


def fig7_lead_waves() -> List[Row]:
    """Fig 7: lead-value waves on two nodes (one clear straggler vs mixed)."""
    rows = []
    for label, seed in (("node1", 1), ("node0", 3)):
        t0 = time.perf_counter()
        node, tr = settled_baseline(seed=seed)
        lead = lead_value_detect(tr.comp_start)
        s = straggler_index(tr.comp_start)
        # equilibrium: leader lead in last quarter ~ flat
        leader = int(np.argmax(lead))
        lk = tr.comp_start[s] - tr.comp_start[leader]
        K = len(lk)
        # equilibrium indicator: leads collapse after the forward phase
        late_over_peak = lk[3 * K // 4:].mean() / max(lk.max(), 1e-9)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig7_leads_{label}", us,
                     f"straggler=gpu{s};max_lead_ms={lead.max()*1e3:.1f};"
                     f"late_lead_over_peak={late_over_peak:.3f}"))
    return rows


def fig9_convergence() -> List[Row]:
    """Fig 9: closed-loop dynamics for the three use cases."""
    rows = []
    for uc, key in (("gpu-red", "power"), ("gpu-realloc", "tput"),
                    ("cpu-slosh", "tput")):
        t0 = time.perf_counter()
        r = cached_case(uc)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig9_{uc}", us,
                     f"throughput={r['tput'] - 1:+.3%};"
                     f"node_power={r['power'] - 1:+.3%};"
                     f"conv_samples={r['conv_samples']}"))
    return rows


def table3_model_vs_measured() -> List[Row]:
    """Table III: analytic §IV predictions vs closed-loop measurements."""
    node, tr = settled_baseline()
    dur, orat = tr.comp_dur, tr.overlap_ratio
    p_base = float(np.mean(node.state.power))
    p_idle = node.thermal.preset.p_idle
    rows = []
    for uc, agg in (("gpu-red", "max"), ("gpu-realloc", "med"),
                    ("cpu-slosh", "min")):
        t0 = time.perf_counter()
        sp = predict_speedup(dur, orat, agg=agg)
        pw = predict_power(dur, orat, p_base, p_idle, agg=agg)
        meas = cached_case(uc)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table3_{uc}", us,
                     f"pred_tput={sp.s_iter:.3f};meas_tput={meas['tput']:.3f};"
                     f"pred_power={pw.improvement:.3f};"
                     f"meas_power={1 / meas['power']:.3f}"))
    return rows


def fig11_warmup_sweep() -> List[Row]:
    """Fig 11: converged throughput is warmup-independent."""
    rows = []
    finals = []
    for wu in (3, 12, 25):
        t0 = time.perf_counter()
        r = closed_loop_stats("gpu-realloc", warmup=wu)
        us = (time.perf_counter() - t0) * 1e6
        finals.append(r["tput"])
        rows.append((f"fig11_warmup_{wu}", us, f"tput={r['tput'] - 1:+.3%}"))
    spread = max(finals) - min(finals)
    rows.append(("fig11_warmup_spread", 0.0, f"spread={spread:.4f}"))
    return rows


def fig12_final_caps() -> List[Row]:
    """Fig 12: final cap distributions similar across initial caps."""
    rows = []
    finals = []
    for cap in (600.0, 650.0, 700.0):
        t0 = time.perf_counter()
        r = closed_loop_stats("gpu-realloc", power_cap=cap)
        us = (time.perf_counter() - t0) * 1e6
        # normalize: cap deltas from the node mean (shape of distribution)
        delta = r["caps"] - r["caps"].mean()
        finals.append(delta)
        rows.append((f"fig12_cap_{int(cap)}", us,
                     f"straggler_boost={delta.max():.1f}W"))
    sim = cosine(finals[0], finals[-1])
    rows.append(("fig12_distribution_similarity", 0.0,
                 f"cosine_600_vs_700={sim:.3f}"))
    return rows


def fig13_red_sensitivity() -> List[Row]:
    """Fig 13: GPU-Red power saving across knobs."""
    rows = []
    knobs = [("agg_sum", {"aggregation": "sum"}),
             ("agg_max", {"aggregation": "max"}),
             ("agg_last", {"aggregation": "last"}),
             ("maxadj_5", {"max_adjustment": 5.0}),
             ("maxadj_30", {"max_adjustment": 30.0}),
             ("window_1", {"window_size": 1}),
             ("scale_local", {"scale": "local"})]
    for name, kw in knobs:
        t0 = time.perf_counter()
        r = closed_loop_stats("gpu-red", **kw)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig13_red_{name}", us,
                     f"power={r['power'] - 1:+.3%};tput={r['tput'] - 1:+.3%};"
                     f"cv={r['cv']:.4f}"))
    return rows


def fig14_realloc_sensitivity() -> List[Row]:
    rows = []
    for cap in (500.0, 600.0, 700.0):
        t0 = time.perf_counter()
        r = closed_loop_stats("gpu-realloc", power_cap=cap)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig14_realloc_cap{int(cap)}", us,
                     f"tput={r['tput'] - 1:+.3%};power={r['power'] - 1:+.3%};"
                     f"conv={r['conv_samples']}"))
    return rows


def fig15_slosh_sensitivity() -> List[Row]:
    rows = []
    for budget in (10.0, 20.0, 50.0):
        t0 = time.perf_counter()
        r = closed_loop_stats("cpu-slosh", cpu_budget=budget)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig15_slosh_budget{int(budget)}", us,
                     f"tput={r['tput'] - 1:+.3%};power={r['power'] - 1:+.3%}"))
    return rows


def fig16_moe_vs_dense() -> List[Row]:
    """Fig 16: DeepSeek MoE (blocking a2a + spikes) vs dense Llama."""
    rows = []
    t0 = time.perf_counter()
    r_moe = closed_loop_stats("gpu-red", arch="deepseek-v3-16b")
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("fig16_moe_gpu_red", us,
                 f"power={r_moe['power'] - 1:+.3%};"
                 f"tput={r_moe['tput'] - 1:+.3%}"))
    r_dense = cached_case("gpu-red")
    rows.append(("fig16_dense_gpu_red", 0.0,
                 f"power={r_dense['power'] - 1:+.3%}"))
    # lead-scale comparison (per-kernel leads shrink under per-layer a2a sync)
    node_m = make_node("deepseek-v3-16b", comm_spike_p=0.02)
    node_d, tr_d = settled_baseline()
    for _ in range(12):
        tr_m = node_m.step()
    lead_m = np.median(np.nanmax(tr_m.comp_start.max(0) - tr_m.comp_start, 0))
    lead_d = np.median(np.nanmax(tr_d.comp_start.max(0) - tr_d.comp_start, 0))
    rows.append(("fig16_lead_scale", 0.0,
                 f"moe_over_dense={lead_m / lead_d:.3f};"
                 f"moe_still_tunable={abs(r_moe['power'] - 1) > 0.005}"))
    return rows


ALL = [fig3_overlap_and_duration, fig4_correlation, fig5_thermal_profile,
       fig7_lead_waves, fig9_convergence, table3_model_vs_measured,
       fig11_warmup_sweep, fig12_final_caps, fig13_red_sensitivity,
       fig14_realloc_sensitivity, fig15_slosh_sensitivity,
       fig16_moe_vs_dense]
