"""Serving SLO rows: the ``serve_slo`` family BENCH_serve.json pins.

Runs the registered ``serve/straggler-slo`` scenario (pinned hot-node
preset) three ways on the same trace and seed — unmanaged, throughput
objective, tail-latency objective — and reports the SLO surface of each
plus the gated comparison row:

  * ``serve_slo:p99_gain_vs_throughput`` — fractional p99-TTFT reduction
    the tail objective buys over the throughput objective (the headline
    the CI smoke also asserts as a strict ordering);
  * ``serve_slo:p99_gain_vs_unmanaged`` — same vs no manager at all;
  * ``serve_slo:ttft_p99_inv`` / ``goodput_rps`` / ``slo_attainment`` /
    ``tokens_per_s`` — the tail-objective run's own SLO surface, in
    higher-is-better form (compare.py's gate is one-sided).

Everything is deterministic (seeded trace, seeded sim), so the pinned
baselines are exact reproductions, with tolerance only as insulation
against numeric-stack drift.  SMOKE mode runs the identical
configuration — three 450-round serve runs take ~8 s, well inside the
CI budget, and trimming rounds would change the pinned values.
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row

SMOKE = False   # same config either way; the flag exists for symmetry

_SLO_KEYS = ("ttft_p50", "ttft_p99", "tpot_p99", "queue_wait_p99",
             "goodput_rps", "slo_attainment", "tokens_per_s")


def _fmt(metrics, keys=_SLO_KEYS) -> str:
    return ";".join(f"{k}={metrics[k]:.6g}" for k in keys)


def serve_slo_rows() -> List[Row]:
    from repro.api import get_scenario, run_scenario, with_overrides

    base = get_scenario("serve/straggler-slo")
    rows: List[Row] = []
    results = {}
    for name, sc in (
            ("serve_slo_unmanaged", with_overrides(base, {"manager": None})),
            ("serve_slo_throughput", with_overrides(
                base, {"manager.config.objective": "throughput"})),
            ("serve_slo_tail", base)):
        t0 = time.perf_counter()
        res = run_scenario(sc)
        dt_us = (time.perf_counter() - t0) * 1e6
        results[name] = res.metrics
        rows.append((name, dt_us / base.iterations, _fmt(res.metrics)))

    tail = results["serve_slo_tail"]
    p_tail = tail["ttft_p99"]
    p_tput = results["serve_slo_throughput"]["ttft_p99"]
    p_none = results["serve_slo_unmanaged"]["ttft_p99"]
    derived = ";".join(
        f"{k}={v:.6g}" for k, v in (
            ("p99_gain_vs_throughput", (p_tput - p_tail) / p_tput),
            ("p99_gain_vs_unmanaged", (p_none - p_tail) / p_none),
            ("ttft_p99_inv", 1.0 / p_tail),
            ("goodput_rps", tail["goodput_rps"]),
            ("slo_attainment", tail["slo_attainment"]),
            ("tokens_per_s", tail["tokens_per_s"]),
        ))
    rows.append(("serve_slo", 0.0, derived))

    # the steady-traffic scenario the CI scenario-smoke step also runs via
    # `python -m repro run serve/poisson --json` — same registry entry,
    # same seed, so both gates pin the same deterministic value
    pois = get_scenario("serve/poisson")
    t0 = time.perf_counter()
    res = run_scenario(pois)
    dt_us = (time.perf_counter() - t0) * 1e6
    rows.append(("serve_poisson", dt_us / pois.iterations,
                 _fmt(res.metrics)))
    return rows


def run() -> List[Row]:
    return serve_slo_rows()
