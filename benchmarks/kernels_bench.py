"""Kernel microbenchmarks: oracle (jit'd XLA) wall time per call +
interpret-mode kernel max-abs error vs the oracle as the derived check.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock belongs to the XLA oracle; the kernels' contribution is verified
numerically and their roofline comes from the dry-run analysis.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[Row]:
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    from repro.kernels.moe_gemm import moe_gemm, moe_gemm_ref
    from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
    from repro.kernels.rwkv6_wkv import wkv6, wkv6_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    rows: List[Row] = []

    # flash attention (B=1, S=512, H=4, D=64)
    B, S, H, D = 1, 512, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ref = jax.jit(lambda a, b, c: flash_attention_ref(a, b, c, causal=True))
    us = _time(ref, qf, kf, vf)
    out = flash_attention(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(
        out.transpose(0, 2, 1, 3).reshape(B * H, S, D) - ref(qf, kf, vf))))
    rows.append(("kernel_flash_attention", us,
                 f"S={S};allclose_err={err:.2e}"))

    # rmsnorm (4096 x 4096)
    x = jax.random.normal(ks[3], (4096, 4096), jnp.bfloat16)
    w = jax.random.normal(ks[4], (4096,), jnp.float32)
    ref = jax.jit(rmsnorm_ref)
    us = _time(ref, x, w)
    err = float(jnp.max(jnp.abs(
        (rmsnorm(x, w) - ref(x, w)).astype(jnp.float32))))
    rows.append(("kernel_rmsnorm", us, f"rows=4096;allclose_err={err:.2e}"))

    # moe grouped gemm (E=8, C=256, d=512, h=512)
    xg = jax.random.normal(ks[5], (8, 256, 512), jnp.bfloat16)
    wg = jax.random.normal(ks[6], (8, 512, 512), jnp.bfloat16)
    ref = jax.jit(moe_gemm_ref)
    us = _time(ref, xg, wg)
    err = float(jnp.max(jnp.abs(
        (moe_gemm(xg, wg) - ref(xg, wg)).astype(jnp.float32))))
    rows.append(("kernel_moe_gemm", us, f"ExCxdxh=8x256x512x512;"
                 f"allclose_err={err:.2e}"))

    # rwkv6 wkv (B=2, S=256, H=4, D=32)
    shape = (2, 256, 4, 32)
    r_ = jax.random.normal(ks[7], shape) * 0.5
    k_ = jax.random.normal(ks[0], shape) * 0.5
    v_ = jax.random.normal(ks[1], shape) * 0.5
    wl = -jnp.exp(jax.random.normal(ks[2], shape))
    u = jax.random.normal(ks[3], (4, 32))
    ref = jax.jit(wkv6_ref)
    us = _time(ref, r_, k_, v_, wl, u)
    y1, s1 = wkv6(r_, k_, v_, wl, u)
    y2, s2 = ref(r_, k_, v_, wl, u)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    rows.append(("kernel_rwkv6_wkv", us, f"S=256;allclose_err={err:.2e}"))
    return rows
