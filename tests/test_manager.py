"""Closed-loop power-manager tests: the three Table-I use cases land in the
paper's measured bands (Table III / §VII-A)."""
import os
import tempfile

import numpy as np
import pytest

from conftest import small_node
from repro.core.backends import SimBackend
from repro.core.manager import ManagerConfig, PowerManager, run_closed_loop

ITERS = 160


def run_case(use_case, **kw):
    node = small_node(seed=1)
    mc = ManagerConfig(use_case=use_case, sampling_period=2, warmup=3,
                       window_size=2, power_cap=700.0, cpu_budget=20.0, **kw)
    mgr = run_closed_loop(SimBackend(node), mc, ITERS)
    h = node.history
    pre = h[ITERS // 2 - 30: ITERS // 2]
    post = h[-30:]
    tp = (np.mean([x["throughput"] for x in post])
          / np.mean([x["throughput"] for x in pre]))
    pw = (np.mean([np.sum(x["power"]) for x in post])
          / np.mean([np.sum(x["power"]) for x in pre]))
    return node, mgr, tp, pw


@pytest.fixture(scope="module")
def red():
    return run_case("gpu-red")


@pytest.fixture(scope="module")
def realloc():
    return run_case("gpu-realloc")


@pytest.fixture(scope="module")
def slosh():
    return run_case("cpu-slosh")


def test_gpu_red_saves_power_keeps_throughput(red):
    node, mgr, tp, pw = red
    assert pw < 0.985                      # >=1.5% node power saved
    assert tp > 0.99                       # throughput preserved
    # the slowest device keeps the highest cap (paper §V-C)
    s = int(np.argmin(node.history[75]["freq_used"]))
    caps = node.history[-1]["cap"]
    assert caps[s] == caps.max()
    assert caps.max() <= node.thermal.preset.tdp + 1e-6


def test_gpu_realloc_improves_throughput_flat_power(realloc):
    node, mgr, tp, pw = realloc
    assert tp > 1.01                       # throughput up
    assert abs(pw - 1.0) < 0.02            # node power ~unchanged
    caps = node.history[-1]["cap"]
    node_cap = 8 * 700.0
    assert caps.sum() <= node_cap + 1e-6


def test_cpu_slosh_best_throughput_more_power(slosh):
    node, mgr, tp, pw = slosh
    assert tp > 1.015
    assert pw > 1.0                        # sloshed CPU watts consumed
    caps = node.history[-1]["cap"]
    assert caps.sum() <= 8 * 720.0 + 1e-6  # node cap + budget respected


def test_slosh_beats_realloc(realloc, slosh):
    assert slosh[2] >= realloc[2] - 0.01   # paper: slosh >= realloc tput


def test_convergence_freeze(red):
    node, mgr, tp, pw = red
    assert not mgr.enabled                 # one-time profiling completed
    assert len(mgr.adjust_log) >= 2


def test_caps_export_import(red):
    node, mgr, *_ = red
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "caps.json")
        mgr.export_caps(path)
        node2 = small_node(seed=1)
        mgr2 = PowerManager(SimBackend(node2),
                            ManagerConfig(use_case="gpu-red"))
        mgr2.import_caps(path)
        np.testing.assert_allclose(node2.state.cap,
                                   node.history[-1]["cap"])
        assert not mgr2.enabled


def test_caps_roundtrip_warm_start_skips_redetection(red):
    """Paper Fig 12: imported caps amortize the one-time profiling cost —
    the warm-started manager must run with detection off (no further cap
    adjustments), and export->import->export must be byte-identical."""
    node, mgr, *_ = red
    with tempfile.TemporaryDirectory() as d:
        p1 = os.path.join(d, "caps1.json")
        p2 = os.path.join(d, "caps2.json")
        mgr.export_caps(p1)
        node2 = small_node(seed=1)
        backend2 = SimBackend(node2)
        mgr2 = PowerManager(backend2, ManagerConfig(use_case="gpu-red",
                                                    sampling_period=2,
                                                    warmup=0, window_size=1))
        mgr2.import_caps(p1)
        caps_before = backend2.get_power_caps()
        for i in range(12):                # live traces offered — ignored
            mgr2.on_iteration(i, backend2.run_iteration())
        assert mgr2.adjust_log == []       # re-detection skipped
        assert mgr2.lead_log == []
        np.testing.assert_array_equal(backend2.get_power_caps(), caps_before)
        mgr2.export_caps(p2)
        with open(p1) as f1, open(p2) as f2:
            assert f1.read() == f2.read()  # lossless round trip
