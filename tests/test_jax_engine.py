"""engine="jax": the XLA port of the C3 window arithmetic, plus the
whole-run fleet scan behind Monte-Carlo sweeps.

Two equivalence tiers (docs/engines.md):

  * ``jax_iteration`` consumes the *same numpy noise stream* as the vector
    engine, so per-iteration traces line up float-for-float (tolerance for
    accumulation order) — property-tested across topologies, heterogeneous
    presets, and churn.
  * ``run_fleet_scan`` keeps the whole warmup/churn/iteration loop inside
    one jitted scan with jax-PRNG noise: identical thermal lotteries and
    physics, a different noise stream — so the check is statistical
    (tail-mean fleet metrics), driven through the sweep module against its
    own per-sample ``ClusterSim`` fallback.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import small_workload
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.jax_engine import HAS_JAX, window_plan
from repro.core.thermal import MI300X_PRESET, ChurnEvent, ChurnModel

pytestmark = pytest.mark.skipif(not HAS_JAX, reason="jax not installed")

HETERO = ["mi300x", "mi300x-air", "mi300x", "v5e"]


def _cluster(engine, topo="dp", seed=5, hetero=False, churn=False,
             noise=None):
    kw = {}
    if hetero:
        kw["node_presets"] = HETERO
    if churn:
        # fresh ChurnModel per sim — the model is stateless but keep the
        # two engines' configs independent anyway
        kw["churn"] = {0: ChurnModel(events=[ChurnEvent(0.0, 3, 1.4)])}
    sim_kw = dict(seed=1, comm_gbps=40.0)
    if noise is not None:
        sim_kw["noise"] = noise
    return ClusterSim(small_workload(n_layers=8), MI300X_PRESET,
                      SimConfig(**sim_kw),
                      ClusterConfig(n_nodes=4, straggler_boost=1.28,
                                    topology=topo, engine=engine, **kw),
                      devices_per_node=8, seed=seed)


def _assert_traces_close(ta, tb):
    for field in ("comp_start", "comp_end", "comp_overlap",
                  "comm_start", "comm_end", "util"):
        a, b = getattr(ta, field), getattr(tb, field)
        np.testing.assert_array_equal(np.isnan(a), np.isnan(b),
                                      err_msg=f"{field}: NaN pattern")
        np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12,
                                   equal_nan=True, err_msg=field)
    assert ta.t_iter == pytest.approx(tb.t_iter, rel=1e-9)


# --------------------------------------------------------------------------- #
# per-iteration equivalence: jax vs vector
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("topo", ["dp", "pp", "tp"])
def test_cluster_jax_engine_matches_vector(topo):
    """engine='jax' steps all N*G lanes as one XLA program and must emit
    the vector engine's traces (same RNG stream, float tolerance only for
    accumulation order) — the cluster layer on top cannot tell them
    apart."""
    cv, cj = _cluster("vector", topo), _cluster("jax", topo)
    for _ in range(3):
        tv, tj = cv.step(), cj.step()
        for a, b in zip(tv, tj):
            _assert_traces_close(a, b)
    assert cv.history[-1]["t_fleet"] == pytest.approx(
        cj.history[-1]["t_fleet"], rel=1e-9)
    np.testing.assert_allclose(cv.history[-1]["lead"],
                               cj.history[-1]["lead"],
                               rtol=1e-6, atol=1e-12)


@settings(deadline=None, max_examples=12)
@given(seed=st.integers(0, 2 ** 16),
       topo=st.sampled_from(["dp", "pp", "tp"]),
       hetero=st.booleans(), churn=st.booleans())
def test_jax_engine_matches_vector_property(seed, topo, hetero, churn):
    """Property: for any thermal-lottery seed, topology, fleet mix, and
    churn setting, the jax engine's iteration is the vector engine's."""
    cv = _cluster("vector", topo, seed=seed, hetero=hetero, churn=churn)
    cj = _cluster("jax", topo, seed=seed, hetero=hetero, churn=churn)
    for _ in range(2):
        tv, tj = cv.step(), cj.step()
    for a, b in zip(tv, tj):
        np.testing.assert_array_equal(np.isnan(a.comp_end),
                                      np.isnan(b.comp_end))
        np.testing.assert_allclose(a.comp_end, b.comp_end,
                                   rtol=1e-9, atol=1e-12, equal_nan=True)
        np.testing.assert_allclose(a.comm_end, b.comm_end,
                                   rtol=1e-9, atol=1e-12, equal_nan=True)
    assert cv.history[-1]["t_fleet"] == pytest.approx(
        cj.history[-1]["t_fleet"], rel=1e-9)


def test_window_plan_caches_on_workload():
    wl = small_workload(n_layers=8)
    assert window_plan(wl) is window_plan(wl)


# --------------------------------------------------------------------------- #
# whole-run fleet scan: statistical equivalence via the sweep module
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_fleet_scan_sweep_matches_python_fallback(monkeypatch):
    """The same SweepSpec through both execution paths — one vmapped
    run_fleet_scan program vs per-sample ClusterSim stepping.  Thermal
    lotteries are shared; only the iteration-noise stream differs, so
    tail-mean fleet metrics must agree to well under a percent."""
    from repro.api.sweep import SweepSpec, run_sweep

    spec = SweepSpec(scenario="cluster/dp", samples=3, seed=0,
                     iterations=40)
    jax_art = run_sweep(spec)
    assert jax_art["engine"] == "jax-scan"

    import repro.core.jax_engine as je
    monkeypatch.setattr(je, "HAS_JAX", False)
    py_art = run_sweep(spec)
    assert py_art["engine"] == "python"

    for a, b in zip(jax_art["samples"], py_art["samples"]):
        assert a["label"] == b["label"]
        assert a["thermal_seed"] == b["thermal_seed"]
        for key in ("t_fleet_s", "throughput", "fleet_power_w"):
            assert a[key] == pytest.approx(b[key], rel=5e-3), key
        assert a["recovery"] == pytest.approx(b["recovery"], rel=5e-3)


@pytest.mark.slow
def test_fleet_scan_handles_churn_and_hetero(monkeypatch):
    """Churn event tables and per-node preset constants ride the scan as
    data: the churn scenario's population matches the python fallback."""
    from repro.api.sweep import SweepSpec, run_sweep

    spec = SweepSpec(scenario="cluster/churn", samples=2, seed=1,
                     iterations=40, node_preset_pool=["mi300x",
                                                      "mi300x-air"])
    jax_art = run_sweep(spec)
    assert jax_art["engine"] == "jax-scan"

    import repro.core.jax_engine as je
    monkeypatch.setattr(je, "HAS_JAX", False)
    py_art = run_sweep(spec)
    for a, b in zip(jax_art["samples"], py_art["samples"]):
        assert a["overrides"] == b["overrides"]
        assert a["t_fleet_s"] == pytest.approx(b["t_fleet_s"], rel=1e-2)


def test_sweep_artifact_schema(tmp_path):
    """The artifact validates against the docs/sweeps.md schema and is
    valid strict JSON (no NaN/Inf literals)."""
    from repro.api.sweep import SWEEP_FORMAT, SweepSpec, run_sweep

    art = run_sweep(SweepSpec(scenario="cluster/dp", samples=2,
                              iterations=30))
    assert art["format"] == SWEEP_FORMAT and art["version"] == 1
    assert art["mode"] == "mc" and art["n_samples"] == 2
    names = {"t_fleet_s", "throughput", "lead_max_s", "fleet_power_w"}
    assert set(art["reference"]) == names
    for s in art["samples"]:
        assert names | {"sample", "label", "overrides", "thermal_seed",
                        "recovery"} == set(s)
        assert s["recovery"] > 0
    assert set(art["summary"]) == names | {"recovery"}
    for q in art["summary"].values():
        assert set(q) == {"mean", "p10", "p50", "p90"}
        assert q["p10"] <= q["p50"] <= q["p90"]
    text = json.dumps(art, allow_nan=False)      # raises on NaN/Inf
    assert json.loads(text) == art


def test_sweep_rejects_node_scenarios():
    from repro.api.sweep import SweepSpec, run_sweep
    with pytest.raises(ValueError, match="fleet"):
        run_sweep(SweepSpec(scenario="paper/node-cap", samples=2))
