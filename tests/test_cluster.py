"""Cluster-scale Lit Silicon: N-node data parallelism, barrier coupling,
hierarchical power management, and the batched C3 engine fast path."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import small_workload
from repro.core.backends import ClusterSimBackend
from repro.core.c3sim import C3Sim, SimConfig, workload_arrays
from repro.core.cluster import ClusterConfig, ClusterSim, ring_allreduce_time
from repro.core.detect import lead_value_detect
from repro.core.manager import (FleetManagerConfig, FleetPowerManager,
                                run_fleet_closed_loop)
from repro.core.thermal import MI300X_PRESET

CAP = 700.0
N_NODES = 4


def make_cluster(boost, seed=5, n_nodes=N_NODES, caps=CAP, **cc_kw):
    wl = small_workload(n_layers=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=n_nodes, straggler_boost=boost,
                                  **cc_kw),
                    devices_per_node=8, seed=seed)
    if caps is not None:
        for n in range(n_nodes):
            cl.set_node_caps(n, np.full(8, float(caps)))
    return cl


@pytest.fixture(scope="module")
def fleet_abc():
    """(healthy, straggler-unmanaged, straggler-managed) fleets, all under
    the same provisioned cluster power budget of N*G*700 W."""
    healthy = make_cluster(1.0)
    strag = make_cluster(1.28)
    for _ in range(60):
        healthy.step()
        strag.step()
    managed = make_cluster(1.28)
    mgr = run_fleet_closed_loop(
        ClusterSimBackend(managed),
        FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=CAP,
                           cluster_power_budget=N_NODES * 8 * CAP),
        120, tune_after=20)
    return healthy, strag, managed, mgr


# --------------------------------------------------------------- semantics
def test_barrier_and_allreduce_stretch_iterations():
    cl = make_cluster(1.28, caps=None)
    traces = cl.step()
    h = cl.history[-1]
    t_ar = cl.allreduce_time()
    assert t_ar > 0
    assert h["t_fleet"] == pytest.approx(h["t_local"].max() + t_ar)
    # every node's committed interval is the fleet interval
    for node in cl.nodes:
        assert node.history[-1]["t_iter"] == pytest.approx(h["t_fleet"])
    # barrier-bound nodes idle: utilization scales down by t_local/t_fleet
    for tr, t_loc in zip(traces, h["t_local"]):
        assert tr.t_iter == pytest.approx(t_loc)


def test_ring_allreduce_time_scaling():
    assert ring_allreduce_time(1e9, 1, 10.0) == 0.0
    t2 = ring_allreduce_time(1e9, 2, 10.0)
    t8 = ring_allreduce_time(1e9, 8, 10.0)
    assert t2 == pytest.approx(1e9 / (10.0 * 1e9))          # 2*(1/2)*B/bw
    assert t8 > t2                                          # 2*(7/8) > 1
    assert t8 < 2 * t2


def test_single_node_cluster_matches_nodesim_shape():
    cl = make_cluster(1.28, n_nodes=1, caps=None)
    cl.step()
    assert cl.allreduce_time() == 0.0
    assert cl.history[-1]["t_fleet"] == pytest.approx(
        cl.history[-1]["t_local"].max())


# ------------------------------------------------------- the paper's claim
def test_straggler_lowers_fleet_throughput(fleet_abc):
    healthy, strag, _, _ = fleet_abc
    tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()
    # a single hot GPU on node 0 drags all 4 nodes down measurably
    assert (tp_h - tp_s) / tp_h > 0.003
    # and node 0 is the one everyone waits for
    slowest = [h["slowest_node"] for h in strag.history[-20:]]
    assert np.mean(np.array(slowest) == 0) > 0.8


def test_fleet_manager_recovers_half_the_gap(fleet_abc):
    healthy, strag, managed, mgr = fleet_abc
    tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()
    tp_m = managed.fleet_throughput()
    assert tp_h > tp_s
    recovery = (tp_m - tp_s) / (tp_h - tp_s)
    assert recovery >= 0.5
    # the straggler node won budget from the barrier-idling leaders
    budgets = mgr.node_budgets
    assert budgets[0] == budgets.max()
    assert budgets.sum() <= N_NODES * 8 * CAP + 1e-6
    # cluster power budget respected after tuning engaged
    peak = max(np.sum(h["node_power"]) for h in managed.history[60:])
    assert peak <= N_NODES * 8 * CAP


def test_fleet_budgets_respect_tight_cluster_budget():
    """Regression: the post-projection budget floor must not push the sum
    of node budgets above a tight (power-constrained) cluster budget."""
    cl = make_cluster(1.28, caps=None)
    be = ClusterSimBackend(cl)
    tight = N_NODES * 8 * 280.0                  # well below provisioned
    mgr = FleetPowerManager(
        be, FleetManagerConfig(use_case="gpu-realloc", power_cap=CAP,
                               cluster_power_budget=tight,
                               max_node_adjustment=120.0))
    t_local = np.array([2.0, 1.0, 1.0, 1.0])     # persistent straggler
    for _ in range(60):
        budgets = mgr.adjust_node_budgets(t_local)
        assert budgets.sum() <= tight + 1e-6
    assert budgets[0] == budgets.max()


def test_fleet_manager_requires_cluster_backend():
    with pytest.raises(TypeError):
        FleetPowerManager(object(), FleetManagerConfig())


# ------------------------------------------------------------ backend API
def test_cluster_backend_cap_roundtrip():
    cl = make_cluster(1.28, caps=None)
    be = ClusterSimBackend(cl)
    caps = be.get_power_caps()
    assert caps.shape == (N_NODES, 8)
    new = np.full((N_NODES, 8), 640.0)
    be.set_power_caps(new)
    np.testing.assert_allclose(be.get_power_caps(), new)
    np.testing.assert_allclose(be.node_views[2].get_power_caps(), new[2])
    be.node_views[1].set_power_caps(np.full(8, 710.0))
    np.testing.assert_allclose(cl.get_node_caps(1), 710.0)
    tel = be.telemetry()
    assert len(tel["nodes"]) == N_NODES


# ----------------------------------------------- batched-engine fast path
def _trace_pair(n_layers=4, seed=3, freq_lo=1.5, spike_p=0.0):
    wl = small_workload(n_layers=n_layers)
    freq = np.linspace(freq_lo, 2.1, 8)
    kw = dict(seed=seed, comm_gbps=40.0, comm_spike_p=spike_p)
    t_e = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="event")
    t_b = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="batched")
    return t_e, t_b


def test_batched_engine_identical_leads():
    t_e, t_b = _trace_pair()
    np.testing.assert_allclose(lead_value_detect(t_e.comp_start),
                               lead_value_detect(t_b.comp_start),
                               rtol=1e-9, atol=1e-12)
    for field in ("comp_start", "comp_end", "comp_overlap",
                  "comm_start", "comm_end", "util"):
        np.testing.assert_allclose(getattr(t_e, field), getattr(t_b, field),
                                   rtol=1e-9, atol=1e-12, err_msg=field)
    assert t_e.t_iter == pytest.approx(t_b.t_iter, rel=1e-12)


@settings(deadline=None, max_examples=12)
@given(seed=st.integers(0, 2 ** 16), freq_lo=st.floats(1.0, 2.05),
       spike_p=st.sampled_from([0.0, 0.05]))
def test_batched_engine_identical_leads_property(seed, freq_lo, spike_p):
    """Property: for any seed, frequency spread, and spike setting the two
    engines consume the same RNG stream and produce identical lead vectors
    (the Algorithm-1 input), so detection is engine-independent."""
    t_e, t_b = _trace_pair(seed=seed, freq_lo=freq_lo, spike_p=spike_p)
    np.testing.assert_allclose(
        lead_value_detect(t_e.comp_start),
        lead_value_detect(t_b.comp_start), rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(t_e.comp_end, t_b.comp_end,
                               rtol=1e-9, atol=1e-12)


def test_batched_engine_moe_blocking_identical():
    """MoE workload: blocking all-to-alls exercise gated-compute windows."""
    from repro.configs import get_config
    from repro.core.workload import fsdp_llm_iteration

    cfg = get_config("deepseek-v3-16b").replace(n_layers=4)
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    freq = np.linspace(1.4, 2.1, 8)
    kw = dict(seed=7, comm_gbps=40.0)
    t_e = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="event")
    t_b = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="batched")
    for field in ("comp_start", "comp_end", "comm_end"):
        np.testing.assert_allclose(getattr(t_e, field), getattr(t_b, field),
                                   rtol=1e-9, atol=1e-12, err_msg=field)


def test_workload_arrays_cached_per_workload():
    wl = small_workload(n_layers=4)
    a1 = workload_arrays(wl)
    a2 = workload_arrays(wl)
    assert a1 is a2
    s1 = C3Sim(wl, MI300X_PRESET, SimConfig(seed=0), 8)
    s2 = C3Sim(wl, MI300X_PRESET, SimConfig(seed=1), 8)
    assert s1.producers is s2.producers          # maps shared, not rebuilt
