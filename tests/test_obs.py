"""Observability layer: metrics registry, alert rules with hysteresis,
live-vs-replay alert parity, incident scoring, and the fidelity story.

The load-bearing contract is the replay one: alert firings are a pure
function of the recorded telemetry stream and the rule set, so offline
rule evaluation over a lossless trace must reproduce the live transitions
bit-for-bit — same iterations, same timestamps, same signal values — on
every engine.  Everything else (bucket arithmetic, flap suppression,
incident grouping) feeds that guarantee.
"""
import copy
import json
import math

import numpy as np
import pytest

from repro.api import ObservabilitySpec, Scenario, get_scenario, \
    run_scenario, with_overrides
from repro.core.escalate import EscalationConfig, EscalationPolicy
from repro.obs import (DEFAULT_BUCKETS, AlertEngine, AlertRule,
                       MetricsRegistry, alert_replay_matches,
                       build_incidents, build_timeline, default_rules,
                       render_dashboard, replay_alerts, save_incidents,
                       score_alerts, terminal_summary,
                       transitions_to_records)
from repro.telemetry import ROCM_SMI_LIKE, SensorConfig, SensorModel, \
    degrade, load_trace, save_trace
from repro.telemetry.collector import FaultRecord
from repro.telemetry.trace_io import TelemetryTrace, export_chrome_trace


# --------------------------------------------------------------------------- #
# shared recorded run (module-scoped: many tests read the same trace)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def heal_result():
    """cluster/fault-heal long enough to cover the transient hang, the
    thermal runaway firing (onset t=12, fires ~t=15.9), the drain and the
    elastic restart."""
    return run_scenario(get_scenario("cluster/fault-heal"), iterations=60)


@pytest.fixture(scope="module")
def heal_trace(heal_result):
    return TelemetryTrace.from_collector(heal_result.collector)


# --------------------------------------------------------------------------- #
# metrics registry
# --------------------------------------------------------------------------- #
def test_counter_inc_and_negative_rejected():
    reg = MetricsRegistry()
    c = reg.counter("alerts_total")
    c.inc({"rule": "r", "state": "firing"})
    c.inc({"rule": "r", "state": "firing"}, 2.0)
    assert c.value({"rule": "r", "state": "firing"}) == 3.0
    assert c.total() == 3.0
    with pytest.raises(ValueError):
        c.inc({"rule": "r", "state": "firing"}, -1.0)


def test_registry_rejects_unknown_and_mistyped_metrics():
    reg = MetricsRegistry()
    with pytest.raises(KeyError):
        reg.gauge("not_a_metric")
    with pytest.raises(TypeError):
        reg.counter("device_temp_celsius")     # it's a gauge


def test_histogram_empty_window_quantile_is_nan():
    reg = MetricsRegistry()
    child = reg.histogram("iteration_seconds").child({})
    assert math.isnan(child.quantile(0.5))
    assert child.count == 0


def test_histogram_single_sample_every_quantile():
    reg = MetricsRegistry()
    child = reg.histogram("iteration_seconds").child({})
    child.observe(0.25)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert child.quantile(q) == 0.25
    with pytest.raises(ValueError):
        child.quantile(1.5)


def test_histogram_nan_bearing_window():
    """NaN observations never enter the quantile window or the buckets;
    they are tallied separately so the data loss is still visible."""
    reg = MetricsRegistry()
    child = reg.histogram("iteration_seconds").child({})
    for v in (0.1, math.nan, 0.3, math.nan):
        child.observe(v)
    assert child.count == 2 and child.nan_count == 2
    assert child.quantile(1.0) == 0.3
    assert not math.isnan(child.sum)


def test_histogram_buckets_cumulative_and_windowed_eviction():
    reg = MetricsRegistry(hist_window=4)
    child = reg.histogram("iteration_seconds").child({})
    for v in (0.002, 0.02, 0.2, 2.0, 20.0):
        child.observe(v)
    # buckets are cumulative over *all* observations…
    cum = child.cumulative()
    assert cum[-1] == 5                        # +Inf bucket sees everything
    assert all(a <= b for a, b in zip(cum, cum[1:]))
    # …while quantiles only see the trailing window (0.002 evicted)
    assert child.quantile(0.0) == 0.02


def test_exposition_format_and_nan_encoding():
    reg = MetricsRegistry()
    reg.gauge("device_temp_celsius").set(math.nan, {"node": 0, "gpu": 1})
    reg.histogram("iteration_seconds").observe(0.05)
    text = reg.exposition()
    assert "# TYPE device_temp_celsius gauge" in text
    assert 'device_temp_celsius{gpu="1",node="0"} NaN' in text
    assert 'iteration_seconds_bucket{le="+Inf"} 1' in text
    assert "iteration_seconds_count 1" in text


def test_snapshot_jsonl_versioned(tmp_path):
    reg = MetricsRegistry()
    reg.counter("sim_iterations_total").inc()
    p = tmp_path / "m.jsonl"
    n = reg.snapshot_jsonl(str(p))
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert len(lines) == n
    assert lines[0]["format"] == "lit-silicon-metrics"
    assert lines[0]["version"] == 1
    assert any(r.get("metric") == "sim_iterations_total" for r in lines[1:])


def test_default_buckets_strictly_increasing():
    assert all(a < b for a, b in zip(DEFAULT_BUCKETS, DEFAULT_BUCKETS[1:]))


# --------------------------------------------------------------------------- #
# alert rules: hysteresis, flap suppression, grace
# --------------------------------------------------------------------------- #
def _temp_rule(**kw):
    base = dict(name="hot", kind="threshold", metric="device_temp_celsius",
                threshold=100.0)
    base.update(kw)
    return AlertRule(**base)


def _feed(engine, series, dt=1.0):
    """Drive one gauge series through the engine; returns transitions."""
    reg = MetricsRegistry()
    out = []
    for i, v in enumerate(series):
        reg.gauge("device_temp_celsius").set(v, {"node": 0, "gpu": 0})
        out.extend(engine.evaluate(i, i * dt, reg))
    return out


def test_for_hysteresis_suppresses_flaps():
    eng = AlertEngine([_temp_rule(for_s=3.0)])
    # two-sample blip: pending, then silent reset — never fires
    trs = _feed(eng, [90, 105, 105, 90, 90, 90])
    assert [t.state for t in trs] == ["pending"]
    # sustained past for_s: pending at the first breach, firing once the
    # window elapses, resolved when it clears
    eng2 = AlertEngine([_temp_rule(for_s=3.0)])
    trs2 = _feed(eng2, [90, 105, 105, 105, 105, 105, 90])
    assert [t.state for t in trs2] == ["pending", "firing", "resolved"]
    fire = [t for t in trs2 if t.state == "firing"][0]
    assert fire.t - trs2[0].t >= 3.0


def test_for_zero_fires_immediately():
    eng = AlertEngine([_temp_rule(for_s=0.0)])
    trs = _feed(eng, [90, 105])
    assert [t.state for t in trs] == ["firing"]


def test_grace_suppresses_boot_transient():
    eng = AlertEngine([_temp_rule(for_s=0.0, grace_s=3.5)])
    trs = _feed(eng, [105, 105, 105, 105, 105])   # t = 0..4
    assert [t.state for t in trs] == ["firing"]
    assert trs[0].t >= 3.5


def test_fleet_ratio_is_against_median_of_others():
    rule = AlertRule("lag", "fleet_ratio", "node_time_obs_seconds",
                     threshold=1.25, for_s=0.0)
    eng = AlertEngine([rule])
    reg = MetricsRegistry()
    for n, v in enumerate([0.4, 0.4, 0.4, 0.6]):
        reg.gauge("node_time_obs_seconds").set(v, {"node": n})
    trs = eng.evaluate(0, 0.0, reg)
    assert len(trs) == 1 and trs[0].node == 3
    assert trs[0].state == "firing"
    assert trs[0].value == pytest.approx(1.5)


def test_vanished_series_resolves_firing_alert():
    eng = AlertEngine([_temp_rule(for_s=0.0)])
    reg = MetricsRegistry()
    g = reg.gauge("device_temp_celsius")
    g.set(120.0, {"node": 0, "gpu": 0})
    trs = eng.evaluate(0, 0.0, reg)
    assert [t.state for t in trs] == ["firing"]
    # the node is drained: its gauge child disappears from the registry
    g.children.clear()
    trs2 = eng.evaluate(1, 1.0, reg)
    assert [t.state for t in trs2] == ["resolved"]
    assert math.isnan(trs2[0].value) and trs2[0].node == 0
    assert not eng.firing_nodes()


def test_alert_rule_validation_and_round_trip():
    with pytest.raises(ValueError, match="kind"):
        AlertRule("x", "nope", "device_temp_celsius", 1.0).validate()
    with pytest.raises(ValueError, match="for_s"):
        AlertRule("x", "threshold", "device_temp_celsius", 1.0,
                  for_s=-1).validate()
    with pytest.raises(ValueError, match="grace_s"):
        AlertRule("x", "threshold", "device_temp_celsius", 1.0,
                  grace_s=-1).validate()
    r = default_rules()[0]
    assert AlertRule.from_dict(r.to_dict()) == r
    with pytest.raises(ValueError, match="bogus"):
        AlertRule.from_dict({**r.to_dict(), "bogus": 1})


def test_engine_rejects_duplicate_rule_names():
    with pytest.raises(ValueError, match="duplicate"):
        AlertEngine([_temp_rule(), _temp_rule()])


# --------------------------------------------------------------------------- #
# scenario spec integration
# --------------------------------------------------------------------------- #
def test_observability_spec_round_trips_through_scenario_json():
    sc = get_scenario("cluster/fault-heal")
    assert sc.observability is not None
    back = Scenario.from_json(sc.to_json())
    assert back.to_dict() == sc.to_dict()
    # a custom rule list survives too
    sc2 = sc.replace(observability=ObservabilitySpec(
        rules=[AlertRule("only", "threshold", "device_temp_celsius", 90.0,
                         for_s=1.0)]))
    back2 = Scenario.from_json(sc2.to_json())
    assert back2.observability.rule_objects()[0].name == "only"


def test_observability_spec_rejects_unknown_rule_keys():
    d = get_scenario("cluster/fault-heal").to_dict()
    d["observability"]["rules"] = [{"name": "x", "kind": "threshold",
                                    "metric": "device_temp_celsius",
                                    "threshold": 1.0, "bogus": 2}]
    with pytest.raises((ValueError, TypeError), match="bogus"):
        Scenario.from_dict(d)


def test_rocm_smi_like_preset_pinned():
    """The calibrated rocm-smi sensor stack (see sensors.py for the
    rationale).  A drive-by change to any constant silently re-scores
    every fidelity study — fail loudly instead."""
    assert ROCM_SMI_LIKE == SensorConfig(
        noise_time_s=2e-5, noise_power_w=2.0, noise_temp_c=1.0,
        quant_time_s=1e-6, quant_power_w=1.0, quant_temp_c=1.0,
        sample_period=3, phase_jitter=1, dropout_p=0.001)


# --------------------------------------------------------------------------- #
# live pipeline on the pinned fault scenario
# --------------------------------------------------------------------------- #
def test_fault_heal_alerts_beat_patience_with_zero_false_positives(
        heal_result):
    m = heal_result.metrics
    assert m["obs_false_alerts"] == 0.0
    patience = heal_result.scenario.escalation.patience_s
    assert 0.0 < m["obs_time_to_alert_s"] <= patience
    # the runaway precursor is the first rule to fire, on the right device
    firing = [t for t in heal_result.obs.transitions if t.state == "firing"]
    assert firing[0].rule == "runaway-slope"
    assert (firing[0].node, firing[0].device) == (2, 3)
    # the transient kernel hang went pending but never fired (flap ridden
    # out by for_s, same philosophy as the escalation patience window)
    hang = [t for t in heal_result.obs.transitions if t.node == 1]
    assert {t.state for t in hang} == {"pending"}


def test_alert_transitions_recorded_in_trace(heal_trace):
    rows = [e for e in heal_trace.events if e.source == "alert"]
    assert rows and all("/" in e.kind for e in rows)
    states = {e.kind.rpartition("/")[2] for e in rows}
    assert "firing" in states and "pending" in states


def test_trace_meta_carries_observability_spec(heal_trace):
    spec = ObservabilitySpec.from_dict(heal_trace.meta["observability"])
    assert [r.name for r in spec.rule_objects()] == \
        [r.name for r in default_rules()]


def test_obs_pipeline_trims_drained_node_gauges(heal_result):
    """After the elastic restart the fleet is 3 nodes: the pipeline must
    not keep evaluating rules against the drained node's last reading."""
    reg = heal_result.obs.registry
    nodes = {lb["node"] for lb, _ in reg.series("node_time_obs_seconds")}
    assert nodes <= {"0", "1", "2"}


# --------------------------------------------------------------------------- #
# live vs replay: the bit-for-bit contract, across engines
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("engine", ["event", "batched", "vector"])
def test_alert_replay_bit_for_bit_across_engines(engine):
    sc = with_overrides(get_scenario("cluster/fault-heal"),
                        {"fleet.engine": engine})
    res = run_scenario(sc, iterations=45)
    trace = TelemetryTrace.from_collector(res.collector)
    assert any(e.source == "alert" for e in trace.events)
    log = []
    assert alert_replay_matches(trace, log=log), "\n".join(log)


def test_alert_replay_survives_jsonl_round_trip(heal_trace, tmp_path):
    p = str(tmp_path / "t.jsonl")
    save_trace(heal_trace, p)
    back = load_trace(p)
    log = []
    assert alert_replay_matches(back, log=log), "\n".join(log)


def test_replay_detects_tampered_recording(heal_trace):
    import dataclasses
    tampered = copy.copy(heal_trace)
    tampered.events = [
        dataclasses.replace(e, t_sim=e.t_sim + 1.0)
        if e.source == "alert" and e.kind.endswith("/firing") else e
        for e in heal_trace.events]
    assert not alert_replay_matches(tampered)


# --------------------------------------------------------------------------- #
# serve scope: tail rows, slo-burn, parity
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def serve_result():
    """serve/straggler-slo shortened, with the slo-burn rule tightened so
    the backlog alert actually fires inside the shortened horizon."""
    sc = get_scenario("serve/straggler-slo")
    sc = sc.replace(observability=ObservabilitySpec(rules=[
        AlertRule("slo-burn", "slo_burn", "serve_tail_seconds",
                  threshold=0.5, target=2.0, for_s=2.0, severity="page"),
    ]))
    return run_scenario(sc, iterations=150)


def test_serve_fleet_rows_carry_tail_signal(serve_result, tmp_path):
    trace = TelemetryTrace.from_collector(serve_result.collector)
    tails = [fs.tail for fs in trace.fleet if fs.tail is not None]
    assert len(tails) == len(trace.fleet)
    assert all(len(t) == trace.n_nodes for t in tails)
    p = str(tmp_path / "serve.jsonl")
    save_trace(trace, p)
    back = load_trace(p)
    np.testing.assert_array_equal(back.fleet[-1].tail, trace.fleet[-1].tail)


def test_serve_slo_burn_fires_and_replays(serve_result):
    trace = TelemetryTrace.from_collector(serve_result.collector)
    firing = [e for e in trace.events
              if e.source == "alert" and e.kind == "slo-burn/firing"]
    assert firing, "tightened slo-burn rule should fire on the backlog"
    log = []
    assert alert_replay_matches(trace, log=log), "\n".join(log)


# --------------------------------------------------------------------------- #
# fidelity: detection quality degrades monotonically with sensor noise
# --------------------------------------------------------------------------- #
def test_false_positives_monotone_in_sensor_noise(heal_trace):
    fps = []
    for noise in (0.0, 0.5, 1.0, 2.0):
        if noise == 0.0:
            deg = heal_trace
        else:
            cfg = SensorConfig(noise_temp_c=noise, noise_time_s=noise * 1e-3,
                               seed=3)
            deg = degrade(heal_trace, SensorModel(cfg))
        pipe = replay_alerts(deg)
        scored = copy.copy(deg)
        scored.events = sorted(
            [e for e in deg.events if e.source != "alert"]
            + transitions_to_records(pipe.transitions),
            key=lambda e: e.iteration)
        s = score_alerts(scored, patience_s=4.0)
        fps.append(s["false_positives"])
    assert fps[0] == 0.0
    assert all(a <= b for a, b in zip(fps, fps[1:])), fps
    assert fps[-1] > 0.0


# --------------------------------------------------------------------------- #
# incidents + scoring
# --------------------------------------------------------------------------- #
def test_timeline_is_ordered_and_multi_source(heal_trace):
    tl = build_timeline(heal_trace)
    ts = [e.t for e in tl if e.t == e.t]
    assert ts == sorted(ts)
    assert {"fault", "alert", "escalation"} <= {e.source for e in tl}


def test_incidents_group_the_runaway_into_a_drained_incident(heal_trace):
    incidents = build_incidents(build_timeline(heal_trace))
    node2 = [i for i in incidents if i.node == 2]
    assert node2
    assert "thermal_runaway" in node2[0].fault_kinds
    assert "runaway-slope" in node2[0].alert_rules
    assert node2[0].drained and not node2[0].open


def test_score_alerts_counts_unmatched_firing_as_false_positive(heal_trace):
    doctored = copy.copy(heal_trace)
    doctored.events = heal_trace.events + [FaultRecord(
        iteration=5, t_sim=2.0, kind="ghost/firing", node=3, device=-1,
        value=9.9, source="alert")]
    s = score_alerts(doctored, patience_s=4.0)
    base = score_alerts(heal_trace, patience_s=4.0)
    assert s["false_positives"] == base["false_positives"] + 1


def test_score_alerts_reports_per_fault_and_patience(heal_trace):
    s = score_alerts(heal_trace, patience_s=4.0)
    assert s["detected"] == 1.0 and s["within_patience"] == 1.0
    assert s["time_to_alert_s"] == pytest.approx(3.943, abs=0.1)
    kinds = {f["kind"] for f in s["per_fault"]}
    assert "thermal_runaway" in kinds


def test_save_incidents_versioned_jsonl(heal_trace, tmp_path):
    p = tmp_path / "inc.jsonl"
    n = save_incidents(heal_trace, str(p))
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert len(lines) == n
    assert lines[0]["format"] == "lit-silicon-incidents"
    types = {l.get("type") for l in lines[1:]}
    assert types == {"timeline", "incident"}


# --------------------------------------------------------------------------- #
# dashboard + chrome trace
# --------------------------------------------------------------------------- #
def test_dashboard_renders_self_contained_html(heal_trace, tmp_path):
    p = tmp_path / "dash.html"
    n = render_dashboard(heal_trace, str(p))
    html = p.read_text()
    assert n == len(html.encode())
    assert "<svg" in html and "node2" in html
    assert "<script" not in html and "https://" not in html
    txt = terminal_summary(heal_trace, patience_s=4.0)
    assert "time-to-alert" in txt and "within patience" in txt


def test_chrome_trace_carries_fleet_counters_and_alert_instants(
        heal_trace, tmp_path):
    p = tmp_path / "chrome.json"
    export_chrome_trace(heal_trace, str(p))
    with open(p) as f:
        doc = json.load(f)
    evs = doc["traceEvents"] if isinstance(doc, dict) else doc
    counters = {e["name"] for e in evs if e.get("ph") == "C"}
    assert {"lead_s", "t_obs_s", "node_power_w"} <= counters
    instants = [e for e in evs if e.get("ph") == "i"]
    assert any(e["name"].startswith("alert:") for e in instants)
    assert any(e["name"].startswith("fault:") for e in instants)


# --------------------------------------------------------------------------- #
# monitor CLI (offline mode; obs_smoke.py covers the live path in CI)
# --------------------------------------------------------------------------- #
def test_cli_monitor_offline_check_replay(heal_trace, tmp_path, capsys):
    from repro.api.cli import main
    trace_path = str(tmp_path / "t.jsonl")
    save_trace(heal_trace, trace_path)
    dash = str(tmp_path / "d.html")
    rc = main(["monitor", "--trace", trace_path, "--check-replay",
               "--dashboard", dash, "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert out["replay_matches"] is True
    assert out["alerts"]["false_positives"] == 0
    assert "<svg" in open(dash).read()


def test_cli_monitor_refuses_check_without_recorded_alerts(
        heal_trace, tmp_path, capsys):
    from repro.api.cli import main
    bare = copy.copy(heal_trace)
    bare.events = [e for e in heal_trace.events if e.source != "alert"]
    trace_path = str(tmp_path / "bare.jsonl")
    save_trace(bare, trace_path)
    rc = main(["monitor", "--trace", trace_path, "--check-replay"])
    assert rc == 2
    capsys.readouterr()


# --------------------------------------------------------------------------- #
# escalation corroboration
# --------------------------------------------------------------------------- #
def test_alert_corroboration_unlocks_the_drain():
    """A steady straggler that never spikes gives the watchdog nothing to
    corroborate with — only the observability alert clears the drain."""
    def drive(policy, alert_node=None):
        decision = None
        for step in range(12):
            if alert_node is not None:
                policy.note_alerts({alert_node})
            t = np.array([0.4, 0.4, 0.4, 0.6])
            d = policy.observe(step, t, t_sim=step * 0.4)
            decision = decision or d
        return decision

    base = EscalationPolicy(EscalationConfig(patience_s=1.0),
                            nodes=[0, 1, 2, 3])
    assert drive(base) is None
    cor = EscalationPolicy(
        EscalationConfig(patience_s=1.0, alert_corroborate=True),
        nodes=[0, 1, 2, 3])
    d = drive(cor, alert_node=3)
    assert d is not None and d.global_node == 3
    assert d.reason == "straggle"
