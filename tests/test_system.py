"""End-to-end behaviour: the paper's claim — detect thermally induced
straggling in a multi-device node running identical FSDP workloads and
mitigate it by tuning per-device power caps — holds on the full system."""
import numpy as np

from conftest import small_node
from repro.core.backends import SimBackend
from repro.core.detect import straggler_index
from repro.core.manager import ManagerConfig, run_closed_loop


def test_lit_silicon_end_to_end():
    # 1) the effect exists: a hot straggler throttles and is detected
    node = small_node(seed=1)
    for _ in range(35):
        tr = node.step()
    s = int(np.argmin(node.history[-1]["freq_used"]))
    assert straggler_index(tr.comp_start) == s
    f_gap = node.state.freq.max() / node.state.freq.min()
    assert f_gap > 1.03

    # 2) the mitigation works: GPU-Red removes the gap at equal throughput
    node2 = small_node(seed=1)
    run_closed_loop(SimBackend(node2),
                    ManagerConfig(use_case="gpu-red", sampling_period=2,
                                  warmup=3, window_size=2), 160)
    h = node2.history
    f_gap_after = h[-1]["freq"].max() / h[-1]["freq"].min()
    assert f_gap_after < f_gap - 0.01          # frequencies aligned
    tp_pre = np.mean([x["throughput"] for x in h[50:80]])
    tp_post = np.mean([x["throughput"] for x in h[-30:]])
    pw_pre = np.mean([np.sum(x["power"]) for x in h[50:80]])
    pw_post = np.mean([np.sum(x["power"]) for x in h[-30:]])
    assert tp_post / tp_pre > 0.99             # throughput unchanged
    assert pw_post / pw_pre < 0.985            # node power saved
