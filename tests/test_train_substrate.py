"""Optimizer, data pipeline, checkpointing, compression, fault tolerance."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import TrainConfig, get_reduced_config
from repro.parallel.compression import (compress_with_feedback,
                                        dequantize_int8, quantize_int8)
from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, SyntheticTokens
from repro.train.fault import ElasticPlan, Watchdog
from repro.train.optimizer import (adamw_update, global_norm, init_state,
                                   lr_schedule)


# ----------------------------------------------------------------- optimizer
def test_adamw_matches_numpy_reference():
    cfg = TrainConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.array([1.0, -2.0]), "b": jnp.array([[0.5]])}
    g = {"w": jnp.array([0.1, 0.2]), "b": jnp.array([[0.3]])}
    st_ = init_state(p)
    new_p, st2, m = adamw_update(cfg, p, g, st_)
    # numpy reference, step 1
    lr = float(lr_schedule(cfg, jnp.asarray(1)))
    for key in p:
        gg = np.asarray(g[key], np.float64)
        mm = 0.1 * gg
        vv = 0.05 * gg ** 2
        mh = mm / (1 - 0.9)
        vh = vv / (1 - 0.95)
        ref = np.asarray(p[key]) - lr * mh / (np.sqrt(vh) + cfg.eps)
        np.testing.assert_allclose(np.asarray(new_p[key]), ref, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_grad_clip_applied():
    cfg = TrainConfig(grad_clip=1.0, warmup_steps=0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(cfg, p, g, init_state(p))
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ---------------------------------------------------------------------- data
def test_data_deterministic_and_restartable():
    cfg = DataConfig(global_batch=4, seq_len=32, seed=9)
    mc = get_reduced_config("llama3.1-8b")
    ds1 = SyntheticTokens(cfg, mc)
    ds2 = SyntheticTokens(cfg, mc)
    b1 = ds1.batch_at(17)
    b2 = ds2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted with masked tail
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -100).all()


def test_data_host_sharding_disjoint():
    mc = get_reduced_config("llama3.1-8b")
    a = SyntheticTokens(DataConfig(global_batch=8, seq_len=16, n_hosts=2,
                                   host_index=0), mc).batch_at(3)
    b = SyntheticTokens(DataConfig(global_batch=8, seq_len=16, n_hosts=2,
                                   host_index=1), mc).batch_at(3)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_retention():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "s": jnp.asarray(3, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2, async_write=False)
        for step in (10, 20, 30):
            cm.save(step, tree)
        assert cm.latest_step() == 30
        dirs = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(dirs) == 2                      # retention
        like = jax.tree_util.tree_map(jnp.zeros_like, tree)
        out, manifest = cm.restore(like)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert manifest["step"] == 30
        assert out["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_async_waits():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=1, async_write=True)
        cm.save(1, {"x": jnp.ones(1000)})
        cm.wait()
        assert cm.latest_step() == 1


def test_checkpoint_crash_mid_write_preserves_previous():
    """A partial ``.tmp-step_*`` from a crashed writer must neither shadow
    the good checkpoint nor survive the next save."""
    tree = {"x": jnp.arange(4, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2, async_write=False)
        cm.save(10, tree)
        # simulate a crash mid-save of step 20: tmp dir with partial files
        stale = os.path.join(d, ".tmp-step_00000020")
        os.makedirs(stale)
        with open(os.path.join(stale, "manifest.json"), "w") as f:
            f.write("{ truncated")
        assert cm.latest_step() == 10           # LATEST untouched
        out, man = cm.restore(jax.tree_util.tree_map(jnp.zeros_like, tree))
        assert man["step"] == 10
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.asarray(tree["x"]))
        cm.save(30, tree)                       # next save sweeps the wreck
        leftovers = [x for x in os.listdir(d) if x.startswith(".tmp-step_")]
        assert leftovers == []
        assert cm.latest_step() == 30


def test_checkpoint_latest_pointer_ignores_missing_dir():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=1, async_write=False)
        assert cm.latest_step() is None
        with open(os.path.join(d, "LATEST"), "w") as f:
            f.write("step_00000099")            # dangling pointer
        assert cm.latest_step() is None
        with pytest.raises(FileNotFoundError):
            cm.restore({"x": jnp.zeros(1)})


# --------------------------------------------------------------- compression
@pytest.mark.slow
@settings(deadline=None, max_examples=40)
@given(st.lists(st.floats(-100, 100, allow_nan=False, allow_infinity=False),
                min_size=2, max_size=64))
def test_quantize_error_bound(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert (err <= np.asarray(s) * 0.5 + 1e-6).all()


def test_error_feedback_compensates():
    """With feedback, accumulated dequantized sums track the true sums."""
    rng = np.random.default_rng(0)
    g = rng.normal(0, 1, (100,)).astype(np.float32)
    err = jnp.zeros(100)
    total_q = np.zeros(100)
    for i in range(50):
        q, s, err = compress_with_feedback(jnp.asarray(g), err)
        total_q += np.asarray(dequantize_int8(q, s))
    # average transmitted value converges to g (bias-free)
    np.testing.assert_allclose(total_q / 50, g, atol=np.abs(g).max() / 120)


# -------------------------------------------------------------------- fault
def test_watchdog_rollback_on_nan():
    w = Watchdog()
    w.start_step()
    assert w.end_step(1.0, 1.0, dt=1.0) == "ok"
    w.start_step()
    assert w.end_step(float("nan"), 1.0, dt=1.0) == "rollback"
    w.start_step()
    assert w.end_step(1.0, float("inf"), dt=1.0) == "rollback"


def test_watchdog_budget_exhaustion():
    w = Watchdog()
    with pytest.raises(RuntimeError):
        for _ in range(10):
            w.start_step()
            w.end_step(float("nan"), 1.0, dt=1.0)


def test_watchdog_requires_clock_or_dt():
    w = Watchdog()                      # no clock injected
    w.start_step()
    with pytest.raises(ValueError):
        w.end_step(1.0, 1.0)            # ... and no dt: must refuse


def test_elastic_plan():
    p = ElasticPlan.after_failure(n_devices=256, failed=3, model_parallel=16,
                                  global_batch=256)
    assert p.mesh_shape() == (15, 16)              # dropped one TP group
    assert p.batch_per_replica() * 15 >= 256
    with pytest.raises(RuntimeError):
        ElasticPlan.after_failure(16, 16, 16, 64)


def test_elastic_plan_batch_padding():
    # 256 does not divide by 15 replicas: round up, report the pad
    p = ElasticPlan(n_devices=240, model_parallel=16, global_batch=256)
    assert p.batch_per_replica() == 18             # ceil(256 / 15)
    assert p.batch_padding() == 18 * 15 - 256
    # even split: no padding
    q = ElasticPlan(n_devices=256, model_parallel=16, global_batch=256)
    assert q.batch_per_replica() == 16 and q.batch_padding() == 0


def test_elastic_plan_rejects_non_divisible_tp():
    with pytest.raises(ValueError, match="TP extent"):
        ElasticPlan(n_devices=10, model_parallel=4,
                    global_batch=64).mesh_shape()


def test_watchdog_explicit_dt_and_injectable_clock():
    from repro.train.fault import WatchdogConfig
    # explicit dt: no wall clock involved, stall at stall_factor x median
    w = Watchdog(WatchdogConfig(stall_factor=2.0, window=10))
    for _ in range(5):
        assert w.end_step(1.0, 1.0, dt=1.0) == "ok"
    assert w.end_step(1.0, 1.0, dt=2.5) == "stall"
    assert w.stalls == 1
    assert w.end_step(1.0, 1.0, dt=1.0) == "ok"
    # injectable clock: a simulated timeline drives start/end measurement
    t = {"now": 0.0}
    w2 = Watchdog(WatchdogConfig(stall_factor=2.0, window=10),
                  clock=lambda: t["now"])
    for _ in range(5):
        w2.start_step()
        t["now"] += 1.0
        assert w2.end_step(1.0, 1.0) == "ok"
    w2.start_step()
    t["now"] += 10.0
    assert w2.end_step(1.0, 1.0) == "stall"
    # a NaN loss on a stalled step still reports the rollback (severity)
    assert w2.end_step(float("nan"), 1.0, dt=1.0) == "rollback"
