"""End-to-end integration: Trainer + checkpoint restart + Lit Silicon hook,
analytic-model vs simulator (Table III), serving loop."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import small_node
from repro.configs import (ParallelConfig, TrainConfig, get_config,
                           get_reduced_config)
from repro.core.backends import SimBackend
from repro.core.detect import classify_overlap
from repro.core.manager import ManagerConfig, run_closed_loop
from repro.core.perf_model import predict_speedup
from repro.core.power_model import predict_power
from repro.train.data import DataConfig


def test_trainer_loss_decreases_and_restarts():
    from repro.train.train_loop import Trainer, TrainerConfig
    cfg = get_reduced_config("llama3.1-8b")
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(
            model=cfg,
            train=TrainConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                              checkpoint_every=15,
                              checkpoint_dir=os.path.join(d, "ck")),
            parallel=ParallelConfig(),
            data=DataConfig(global_batch=8, seq_len=64))
        tr = Trainer(tc)
        log = tr.run(30)
        assert log[-1]["loss"] < log[0]["loss"] - 0.2
        tr.ckpt.wait()
        tr2 = Trainer(tc)
        tr2.init_or_restore()
        assert tr2.step == 30
        log2 = tr2.run(3)
        assert np.isfinite(log2[-1]["loss"])


def test_trainer_with_lit_silicon_hook():
    from repro.core.c3sim import SimConfig
    from repro.train.train_loop import (LitSiliconHook, Trainer,
                                        TrainerConfig)
    cfg = get_reduced_config("llama3.1-8b")
    hook = LitSiliconHook(
        get_config("llama3.1-8b").replace(n_layers=8),
        ManagerConfig(use_case="gpu-red", sampling_period=2, warmup=1,
                      window_size=1),
        preset="mi300x", seed=1)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(
            model=cfg,
            train=TrainConfig(checkpoint_every=0,
                              checkpoint_dir=os.path.join(d, "ck")),
            data=DataConfig(global_batch=4, seq_len=32))
        tr = Trainer(tc, hooks=[hook])
        log = tr.run(30)
    assert "sim/node_power" in log[-1]
    # the manager adjusted caps at least once
    assert len(hook.manager.adjust_log) >= 1
    caps = hook.backend.get_power_caps()
    assert caps.max() <= hook.backend.tdp + 1e-6


def test_table3_analytic_vs_measured():
    """§VII-A: predicted power within ~1-2% of measured; throughput trend
    (predicted >= measured, diminishing Red->Realloc->Slosh) holds."""
    node = small_node(seed=1)
    for _ in range(35):
        tr = node.step()
    dur, orat = tr.comp_dur, tr.overlap_ratio
    p_base = float(np.mean(node.state.power))
    p_idle = node.thermal.preset.p_idle

    # GPU-Red: align C to the straggler (max) -> power ratio ~ measured
    pw = predict_power(dur, orat, p_base, p_idle, agg="max")
    def run_case(uc):
        n = small_node(seed=1)
        mc = ManagerConfig(use_case=uc, sampling_period=2, warmup=3,
                           window_size=2, power_cap=700.0)
        run_closed_loop(SimBackend(n), mc, 160)
        h = n.history
        pre = h[50:80]
        post = h[-30:]
        tp = (np.mean([x["throughput"] for x in post])
              / np.mean([x["throughput"] for x in pre]))
        pwm = (np.mean([np.sum(x["power"]) for x in post])
               / np.mean([np.sum(x["power"]) for x in pre]))
        return tp, pwm

    tp_red, pw_red = run_case("gpu-red")
    assert abs(pw.ratio - pw_red) < 0.04       # power model ~measured
    # throughput: predicted (frequency-only, Eq 6) upper-bounds measured
    sp_med = predict_speedup(dur, orat, agg="med").s_iter
    tp_re, _ = run_case("gpu-realloc")
    assert sp_med >= tp_re - 0.02
    assert sp_med >= 1.0


def test_serving_loop_greedy():
    from repro.models import build_model
    from repro.models.common import init_params
    from repro.serve.decode import ServeConfig, ServingLoop
    cfg = get_reduced_config("qwen3-4b")
    model = build_model(cfg, max_cache_len=24)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    loop = ServingLoop(model, params, batch_size=4, prompt_len=8,
                       cfg=ServeConfig(max_new_tokens=6))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (3, 8)).astype(np.int32)
    out = loop.serve(prompts)
    assert out.shape == (3, 6)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    # greedy decode is deterministic
    out2 = loop.serve(prompts)
    np.testing.assert_array_equal(out, out2)


def test_moe_capacity_drops_tokens_gracefully():
    import dataclasses
    from repro.models import build_model, make_batch
    from repro.models.common import init_params
    cfg = get_reduced_config("deepseek-moe-16b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    loss, m = jax.jit(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
