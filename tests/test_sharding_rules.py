"""Partition-rule unit tests (mesh built abstractly on 1 CPU device is not
possible for 16x16, so we use jax.sharding.Mesh over a device-id array via
AbstractMesh-free spec checks on a small host mesh + pure spec logic)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ParallelConfig, get_config
from repro.models.common import ParamSpec
from repro.parallel.sharding import ShardingRules


class FakeMesh:
    """Duck-typed mesh: ShardingRules only reads .shape (sizes)."""

    def __init__(self, **shape):
        self.shape = shape


def rules_for(arch, **mesh_shape):
    cfg = get_config(arch)
    parallel = ParallelConfig(multi_pod="pod" in mesh_shape)
    return ShardingRules(FakeMesh(**mesh_shape), cfg, parallel), cfg


def test_dense_2d_sharding():
    r, cfg = rules_for("llama3.1-8b", data=16, model=16)
    spec = r.spec_for(("embed", "heads"), (4096, 4096))
    assert spec == P("data", "model")
    spec = r.spec_for(("vocab", "embed"), (128256, 4096))
    assert spec == P("model", "data")


def test_qwen_heads_not_divisible_fallback():
    # 40 heads don't divide a 16-way model axis -> heads replicated
    r, cfg = rules_for("qwen2.5-32b", data=16, model=16)
    assert cfg.n_heads == 40
    spec = r.spec_for(("embed", "heads"), (5120, 5120))
    assert spec == P("data", None)
    # ffn still TP
    spec = r.spec_for(("embed", "ffn"), (5120, 27648))
    assert spec == P("data", "model")


def test_grok_experts_fall_through_to_expert_ffn_tp():
    r, cfg = rules_for("grok-1-314b", data=16, model=16)
    # 8 experts don't divide 16 -> TP over the expert hidden dim instead
    spec = r.spec_for(("experts", "embed", "expert_ffn"), (8, 6144, 32768))
    assert spec == P(None, "data", "model")


def test_deepseek_expert_parallel():
    r, cfg = rules_for("deepseek-moe-16b", data=16, model=16)
    spec = r.spec_for(("experts", "embed", "expert_ffn"), (64, 2048, 1408))
    assert spec == P("model", "data", None)   # EP over model; no double-use


def test_multipod_fsdp_over_pod_for_huge_models():
    r, cfg = rules_for("grok-1-314b", pod=2, data=16, model=16)
    assert r.fsdp_axes == ("pod", "data")     # 314B -> shard optimizer wider
    spec = r.spec_for(("embed", "heads"), (6144, 6144))
    assert spec == P(("pod", "data"), "model")
    r2, _ = rules_for("qwen3-4b", pod=2, data=16, model=16)
    assert r2.fsdp_axes == ("data",)          # small model: DP across pods


def test_hymba_attention_data_parallel():
    r, cfg = rules_for("hymba-1.5b", data=16, model=16)
    assert cfg.n_heads == 25
    d = r.describe()
    assert not d["tp_heads"] and not d["tp_kv_heads"]
    assert d["sequence_parallel"]


def test_no_mesh_axis_used_twice():
    r, _ = rules_for("deepseek-moe-16b", data=16, model=16)
    for axes, shape in [(("experts", "expert_ffn", "embed"),
                         (64, 1408, 2048)),
                        (("vocab", "embed"), (102400, 2048))]:
        spec = r.spec_for(axes, shape)
        used = [a for s in spec if s for a in
                ((s,) if isinstance(s, str) else s)]
        assert len(used) == len(set(used))


def test_cache_shardings_kv_or_seq():
    import jax.numpy as jnp
    # build a real (tiny) mesh to construct NamedShardings
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1),
                             ("data", "model"))
    cfg = get_config("deepseek-7b")
    r = ShardingRules(mesh, cfg, ParallelConfig())
    cache = {"k": jax.ShapeDtypeStruct((30, 8, 128, 32, 128), jnp.bfloat16),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    axes = {"k": ("layers", "act_batch", "window", "kv_heads", None),
            "pos": ()}
    shard = r.cache_shardings(cache, axes)
    assert shard["k"].spec is not None


def test_cache_shardings_vision_six_dim():
    import jax.numpy as jnp
    from repro.parallel.sharding import ShardingRules as SR
    cfg = get_config("llama-3.2-vision-90b")
    r, _ = rules_for("llama-3.2-vision-90b", data=16, model=16)
    cache = {"k": jax.ShapeDtypeStruct((20, 4, 128, 32768, 8, 128),
                                       jnp.bfloat16)}
    axes = {"k": ("layers", "layers", "act_batch", "window", "kv_heads",
                  None)}
    # FakeMesh lacks NamedSharding support; check the spec logic via one()
    # indirectly through a real 1x1 mesh with the same divisibility rules
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1),
                             ("data", "model"))
    rr = SR(mesh, cfg, ParallelConfig())
    shard = rr.cache_shardings(cache, axes)
    assert shard["k"].spec[2] is not None or mesh.shape["data"] == 1
