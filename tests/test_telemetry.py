"""Telemetry subsystem: sensor models, trace recording/persistence, and the
two replay guarantees — lossless traces replay the live cap schedule
bit-for-bit (all engines), and detection degrades measurably (monotonically
in expectation) as sensor fidelity drops."""
import json
import os

import numpy as np
import pytest

from benchmarks.telemetry_bench import fleet_cfg
from conftest import small_node, small_workload
from repro.core.backends import ClusterSimBackend, SimBackend
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.manager import (ManagerConfig, PowerManager,
                                run_closed_loop, run_fleet_closed_loop)
from repro.core.thermal import MI300X_PRESET
from repro.telemetry import (LOSSLESS, SensorConfig, SensorModel,
                             TelemetryCollector, TelemetryTrace, degrade,
                             detection_report, export_chrome_trace,
                             fleet_lead_report, load_trace, replay_fleet,
                             replay_node, save_trace)


def mgr_cfg(**kw):
    kw.setdefault("use_case", "gpu-red")
    kw.setdefault("sampling_period", 2)
    kw.setdefault("warmup", 3)
    kw.setdefault("window_size", 2)
    return ManagerConfig(**kw)


@pytest.fixture(scope="module")
def recorded_node():
    """A settled 8-GPU node recorded losslessly for 60 iterations — the
    shared source for the degradation studies."""
    node = small_node(seed=1)
    col = TelemetryCollector(max_samples=256).attach_node(node)
    for _ in range(60):
        node.step()
    return node, TelemetryTrace.from_collector(col)


# --------------------------------------------------------------------------- #
# sensors
# --------------------------------------------------------------------------- #
def test_lossless_sensor_is_identity():
    s = SensorModel(LOSSLESS)
    t = np.arange(24.0).reshape(4, 6)
    out = s.observe_starts(t)
    assert out is t                       # no copy, no RNG consumed
    assert all(s.take_sample(i) for i in range(10))
    assert not s.drop_mask(8).any()


def test_sensor_noise_and_quantization():
    s = SensorModel(SensorConfig(noise_time_s=1e-3, quant_time_s=1e-4,
                                 seed=0))
    t = np.linspace(0, 1, 50).reshape(5, 10)
    out = s.observe_starts(t)
    assert out.shape == t.shape
    assert not np.allclose(out, t)        # noise applied
    grid = np.round(out / 1e-4) * 1e-4
    np.testing.assert_allclose(out, grid, atol=1e-12)   # on the clock grid
    assert np.abs(out - t).max() < 6e-3   # bounded by ~5 sigma + quantum
    # power/temp counters quantize to their own steps
    q = SensorModel(SensorConfig(quant_power_w=1.0, quant_temp_c=1.0))
    assert np.array_equal(q.observe_power(np.array([700.4, 699.6])),
                          [700.0, 700.0])
    assert np.array_equal(q.observe_temp(np.array([61.2])), [61.0])


def test_sensor_dropout_marks_devices_nan():
    s = SensorModel(SensorConfig(dropout_p=0.5, seed=2))
    t = np.ones((8, 20))
    dropped_any = False
    for _ in range(10):
        out = s.observe_starts(t)
        rows = np.isnan(out).all(axis=1)
        # a device's sample is dropped whole, never partially
        assert (np.isnan(out).any(axis=1) == rows).all()
        dropped_any |= rows.any()
    assert dropped_any


def test_sensor_sampling_period_and_jitter():
    s = SensorModel(SensorConfig(sample_period=10, phase_jitter=2, seed=1))
    sampled = [i for i in range(200) if s.take_sample(i)]
    assert sampled[0] == 0
    gaps = np.diff(sampled)
    assert (gaps >= 8).all() and (gaps <= 12).all()
    assert len(set(gaps)) > 1             # jitter actually moves the phase
    # no jitter: exact period
    s2 = SensorModel(SensorConfig(sample_period=10))
    assert [i for i in range(50) if s2.take_sample(i)] == [0, 10, 20, 30, 40]


def test_sensor_reproducible():
    t = np.linspace(0, 1, 40).reshape(4, 10)
    cfg = SensorConfig(noise_time_s=1e-3, dropout_p=0.1, seed=7)
    a = SensorModel(cfg).observe_starts(t)
    b = SensorModel(cfg).observe_starts(t)
    np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------- #
# collector
# --------------------------------------------------------------------------- #
def test_collector_ring_buffer_bound():
    node = small_node(seed=2, n_layers=8)
    col = TelemetryCollector(max_samples=10).attach_node(node)
    for _ in range(25):
        node.step()
    assert len(col.samples) == 10         # bounded
    its = [s.iteration for s in col.samples]
    assert its == list(range(15, 25))     # most recent, recording-relative


def test_clear_resets_sensor_streams():
    """Recording after clear() must be bit-for-bit what a fresh collector
    records: the sensors' RNG streams restart."""
    cfg = SensorConfig(noise_time_s=1e-3, dropout_p=0.1, seed=5)
    col = TelemetryCollector(sensor_cfg=cfg)
    x = np.linspace(0, 1, 16).reshape(2, 8)
    a = col.sensor_for(0).observe_starts(x)     # consumes the stream
    col.clear()
    b = col.sensor_for(0).observe_starts(x)
    np.testing.assert_array_equal(a, b)


def test_cluster_ring_buffers_cover_same_window():
    """Node and fleet rings must retain the same iteration window even
    though a cluster writes N node samples per fleet sample."""
    wl = small_workload(n_layers=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=2, straggler_boost=1.28),
                    devices_per_node=8, seed=5)
    col = TelemetryCollector(max_samples=5).attach_cluster(cl)
    for _ in range(12):
        cl.step()
    assert len(col.fleet) == 5
    assert len(col.samples) == 10               # 2 nodes x 5 iterations
    assert ({s.iteration for s in col.samples}
            == {f.iteration for f in col.fleet} == set(range(7, 12)))


def test_collector_rebases_iterations_to_recording_start():
    node = small_node(seed=2, n_layers=8)
    assert node.iteration > 0             # warmup already consumed some
    col = TelemetryCollector().attach_node(node)
    node.step()
    assert col.samples[0].iteration == 0


def test_collector_does_not_perturb_execution():
    a = small_node(seed=3, n_layers=8)
    b = small_node(seed=3, n_layers=8)
    TelemetryCollector().attach_node(b)
    for _ in range(10):
        ta = a.step()
        tb = b.step()
    np.testing.assert_array_equal(ta.comp_start, tb.comp_start)
    np.testing.assert_array_equal(a.state.temp, b.state.temp)


def test_collector_records_node_state_and_meta(recorded_node):
    node, trace = recorded_node
    s = trace.samples[-1]
    assert s.comp_start.shape == (8, len(trace.meta["comp_names"]))
    assert s.power.shape == (8,) and s.cap.shape == (8,)
    np.testing.assert_array_equal(s.cap, node.state.cap)
    assert trace.meta["straggler_hint"][0] == node.thermal.straggler_hint
    assert trace.meta["tdp"] == node.preset.tdp


# --------------------------------------------------------------------------- #
# trace io
# --------------------------------------------------------------------------- #
def test_jsonl_roundtrip_is_exact(recorded_node, tmp_path):
    _, trace = recorded_node
    # poison one reading with NaN to exercise the null encoding
    trace.samples[0].comp_start[2, 5] = np.nan
    p = str(tmp_path / "trace.jsonl")
    save_trace(trace, p)
    back = load_trace(p)
    assert len(back.samples) == len(trace.samples)
    for a, b in zip(trace.samples, back.samples):
        assert a.iteration == b.iteration
        np.testing.assert_array_equal(a.comp_start, b.comp_start)
        np.testing.assert_array_equal(a.power, b.power)
        np.testing.assert_array_equal(a.cap, b.cap)
    assert back.meta["comp_names"] == trace.meta["comp_names"]
    trace.samples[0].comp_start[2, 5] = 0.0   # unpoison the shared fixture


def test_trace_format_guard(tmp_path):
    p = str(tmp_path / "bogus.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps({"format": "something-else", "version": 1}) + "\n")
    with pytest.raises(ValueError, match="not a lit-silicon-telemetry"):
        load_trace(p)
    with open(p, "w") as f:
        f.write(json.dumps({"format": "lit-silicon-telemetry",
                            "version": 99, "meta": {}}) + "\n")
    with pytest.raises(ValueError, match="newer than supported"):
        load_trace(p)
    with open(p, "w") as f:
        f.write(json.dumps({"format": "lit-silicon-telemetry",
                            "meta": {}}) + "\n")
    with pytest.raises(ValueError, match="no version"):
        load_trace(p)


def test_chrome_trace_export(recorded_node, tmp_path):
    _, trace = recorded_node
    p = str(tmp_path / "trace.chrome.json")
    n = export_chrome_trace(trace, p, max_samples=3)
    assert n > 0
    with open(p) as f:
        doc = json.load(f)
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "C", "M"} <= phases      # kernels, counters, names
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in xs)
    assert {e["tid"] for e in xs} == set(range(8))


# --------------------------------------------------------------------------- #
# replay: the bit-for-bit guarantee (acceptance criterion)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("engine", ["event", "batched", "vector"])
def test_replay_reproduces_live_caps_bit_for_bit(engine, tmp_path):
    node = small_node(seed=1, n_layers=8, engine=engine)
    col = TelemetryCollector(max_samples=4096)
    live = run_closed_loop(SimBackend(node, collector=col), mgr_cfg(),
                           80, tune_after=20, collector=col)
    p = str(tmp_path / "trace.jsonl")
    save_trace(col, p)                    # through disk: JSONL is lossless
    rp = replay_node(load_trace(p), mgr_cfg(), tune_after=20)
    assert len(rp.cap_schedule) == len(live.adjust_log) > 0
    for a, b in zip(rp.cap_schedule, live.adjust_log):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(rp.final_caps,
                                  live.backend.get_power_caps())
    # the recorded manager actions are that same schedule
    caps_actions = [a for a in col.actions if a.kind == "caps"]
    assert len(caps_actions) == len(live.adjust_log)
    for act, cap in zip(caps_actions, live.adjust_log):
        np.testing.assert_array_equal(act.values, cap)


@pytest.mark.parametrize("engine", ["batched", "vector"])
def test_fleet_replay_reproduces_live_caps_bit_for_bit(engine, tmp_path):
    wl = small_workload(n_layers=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=2, straggler_boost=1.28,
                                  engine=engine),
                    devices_per_node=8, seed=5)
    for n in range(2):
        cl.set_node_caps(n, np.full(8, 700.0))
    col = TelemetryCollector(max_samples=4096).attach_cluster(cl)
    live = run_fleet_closed_loop(ClusterSimBackend(cl), fleet_cfg(2),
                                 60, tune_after=10, collector=col)
    # the trace carries the mitigation decisions at both scopes, with the
    # per-node cap actions attributed to their node
    assert (sum(1 for a in col.actions if a.kind == "budgets")
            == len(live.budget_log))
    for n, lm in enumerate(live.managers):
        acts = [a for a in col.actions
                if a.kind == "caps" and a.node == n]
        assert len(acts) == len(lm.adjust_log)
        for act, cap in zip(acts, lm.adjust_log):
            np.testing.assert_array_equal(act.values, cap)
    p = str(tmp_path / "fleet.jsonl")
    save_trace(col, p)
    rp = replay_fleet(load_trace(p), fleet_cfg(2), tune_after=10)
    assert len(rp.budget_log) == len(live.budget_log) > 0
    for a, b in zip(rp.budget_log, live.budget_log):
        np.testing.assert_array_equal(a, b)
    for sched, lm in zip(rp.node_cap_schedules, live.managers):
        assert len(sched) == len(lm.adjust_log) > 0
        for a, b in zip(sched, lm.adjust_log):
            np.testing.assert_array_equal(a, b)
    live_caps = np.stack([cl.get_node_caps(n) for n in range(2)])
    np.testing.assert_array_equal(rp.final_caps, live_caps)


def test_fleet_replay_flags_truncated_iterations(tmp_path):
    """A fleet sample whose node samples were evicted must surface as a
    truncation diagnostic, not as a silent skip."""
    wl = small_workload(n_layers=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=2, straggler_boost=1.28),
                    devices_per_node=8, seed=5)
    col = TelemetryCollector(max_samples=64).attach_cluster(cl)
    for _ in range(12):
        cl.step()
    trace = TelemetryTrace.from_collector(col)
    cut = trace.fleet[0].iteration
    trace.samples = [s for s in trace.samples if s.iteration != cut]
    with pytest.warns(UserWarning, match="truncated"):
        rp = replay_fleet(trace, fleet_cfg(2))
    assert rp.skipped_iterations == [cut]


def test_replay_emits_live_caps_file_format(tmp_path):
    """Fig-12 workflow closure: a replayed schedule exports the same caps
    file a live manager writes, and a live manager can import it."""
    node = small_node(seed=1, n_layers=8)
    col = TelemetryCollector(max_samples=4096)
    live = run_closed_loop(SimBackend(node, collector=col), mgr_cfg(),
                           80, tune_after=20)
    rp = replay_node(TelemetryTrace.from_collector(col), mgr_cfg(),
                     tune_after=20)
    p_live = str(tmp_path / "caps_live.json")
    p_replay = str(tmp_path / "caps_replay.json")
    live.export_caps(p_live)
    rp.export_caps(p_replay)
    with open(p_live) as f:
        doc_live = json.load(f)
    with open(p_replay) as f:
        doc_replay = json.load(f)
    assert doc_live == doc_replay         # identical schedule, same format
    node2 = small_node(seed=1, n_layers=8)
    mgr2 = PowerManager(SimBackend(node2), mgr_cfg())
    mgr2.import_caps(p_replay)
    np.testing.assert_allclose(node2.state.cap, live.backend.get_power_caps())
    assert not mgr2.enabled               # warm-started: detection skipped


# --------------------------------------------------------------------------- #
# manager sensor path
# --------------------------------------------------------------------------- #
# tune_after=21 is deliberately misaligned with the period-2 grid: the
# sensor's poll grid must anchor to absolute iterations (like the oracle's
# modulo), not to whenever the manager happened to be enabled
@pytest.mark.parametrize("tune_after", [20, 21])
def test_lossless_sensor_path_matches_oracle_bit_for_bit(tune_after):
    oracle_node = small_node(seed=4, n_layers=8)
    oracle = run_closed_loop(SimBackend(oracle_node), mgr_cfg(),
                             80, tune_after=tune_after)
    sensed_node = small_node(seed=4, n_layers=8)
    sensor = SensorModel(SensorConfig(sample_period=2))
    sensed = run_closed_loop(SimBackend(sensed_node), mgr_cfg(),
                             80, tune_after=tune_after, sensor=sensor)
    assert len(sensed.adjust_log) == len(oracle.adjust_log) > 0
    for a, b in zip(sensed.adjust_log, oracle.adjust_log):
        np.testing.assert_array_equal(a, b)


def test_noisy_sensor_path_stays_within_bounds():
    node = small_node(seed=4, n_layers=8)
    sensor = SensorModel(SensorConfig(noise_time_s=2e-3, quant_time_s=1e-5,
                                      sample_period=2, dropout_p=0.01,
                                      seed=9))
    mgr = run_closed_loop(SimBackend(node), mgr_cfg(), 80, tune_after=20,
                          sensor=sensor)
    assert len(mgr.adjust_log) > 0        # noisy stream still drives caps
    caps = node.state.cap
    assert (caps <= node.preset.tdp + 1e-6).all()
    assert (caps > 0).all()


# --------------------------------------------------------------------------- #
# detection degradation (acceptance criterion)
# --------------------------------------------------------------------------- #
SIGMAS = (0.0, 0.002, 0.01, 0.05, 0.2, 0.8)


def test_detection_accuracy_degrades_monotonically_with_noise(recorded_node):
    _, trace = recorded_node
    accs, errs = [], []
    for sigma in SIGMAS:
        reports = [detection_report(degrade(trace, SensorModel(
            SensorConfig(noise_time_s=sigma, seed=s)))) for s in range(5)]
        accs.append(float(np.mean([r.accuracy for r in reports])))
        errs.append(float(np.mean([r.lead_rel_error for r in reports])))
    assert accs[0] == 1.0                 # lossless: perfect detection
    assert errs[0] == 0.0
    # monotone-in-expectation: averaged over sensor seeds, never improves
    # as noise grows (small slack for the finite-seed average)
    for lo, hi in zip(accs, accs[1:]):
        assert hi <= lo + 0.05
    for lo, hi in zip(errs, errs[1:]):
        assert hi >= lo - 1e-9            # lead error strictly noise-driven
    assert accs[-1] < 0.5                 # heavy noise genuinely breaks it


def test_detection_degrades_with_sampling_period(recorded_node):
    """At high noise, fewer samples -> less reliable majority vote."""
    _, trace = recorded_node
    rates = []
    for period in (1, 5, 15, 30):
        maj = [detection_report(degrade(trace, SensorModel(SensorConfig(
            noise_time_s=0.1, sample_period=period, seed=s))))
            .majority_correct for s in range(12)]
        rates.append(float(np.mean(maj)))
    for lo, hi in zip(rates, rates[1:]):
        assert hi <= lo + 0.1
    assert rates[0] == 1.0
    assert rates[-1] < rates[0]


def test_straggler_identified_at_paper_default_sampling(recorded_node):
    """Table-II sampling period (10) + moderate noise (10x the median
    kernel duration) + phase jitter: the straggler is still named."""
    node, trace = recorded_node
    for seed in range(5):
        d = degrade(trace, SensorModel(SensorConfig(
            noise_time_s=0.01, sample_period=10, phase_jitter=2,
            quant_time_s=1e-5, seed=seed)))
        rep = detection_report(d)
        assert rep.majority_correct
        assert rep.accuracy == 1.0
        assert rep.true_straggler == node.thermal.straggler_hint


def test_degrade_keeps_truth_for_error_accounting(recorded_node):
    _, trace = recorded_node
    d = degrade(trace, SensorModel(SensorConfig(noise_time_s=0.01, seed=0)))
    s = d.samples[0]
    assert s.truth_start is not None
    assert not np.allclose(s.comp_start, s.truth_start)
    src = trace.samples[0]
    np.testing.assert_array_equal(s.truth_start, src.comp_start)


# --------------------------------------------------------------------------- #
# dropout imputation (last-known-value fill — the ROADMAP shadowing fix)
# --------------------------------------------------------------------------- #
def test_impute_dropout_holds_last_known_row():
    cfg = SensorConfig(dropout_p=0.5, impute_dropout=True, seed=2)
    s = SensorModel(cfg)
    # same RNG stream as the non-imputing sensor: the knob changes what is
    # reported, never what is drawn
    ref = SensorModel(SensorConfig(dropout_p=0.5, seed=2))
    t0 = np.arange(24.0).reshape(8, 3)
    outs, drops = [], []
    for k in range(12):
        t = t0 + k                         # starts drift between samples
        out = s.observe_starts(t)
        drops.append(np.isnan(ref.observe_starts(t)).all(axis=1))
        outs.append(out)
    drops = np.stack(drops)
    assert drops.any(), "seed must produce at least one dropped row"
    for k in range(1, 12):
        for g in range(8):
            if drops[k, g] and not drops[:k, g].all():
                # dropped after at least one observation: held value, not NaN
                assert not np.isnan(outs[k][g]).any()
                last_seen = max(j for j in range(k) if not drops[j, g])
                np.testing.assert_array_equal(outs[k][g], outs[last_seen][g])
            elif not drops[k, g]:
                np.testing.assert_array_equal(outs[k][g], t0[g] + k)
    # a device dropped before it was ever observed still reads NaN
    first = SensorModel(cfg)
    out = first.observe_starts(t0)
    gone = np.isnan(out).all(axis=1)
    if gone.any():
        assert np.isnan(out[gone]).all()


def test_impute_dropout_off_is_byte_identical_to_before():
    a = SensorModel(SensorConfig(dropout_p=0.3, noise_time_s=1e-3, seed=5))
    b = SensorModel(SensorConfig(dropout_p=0.3, noise_time_s=1e-3, seed=5,
                                 impute_dropout=True))
    t = np.linspace(0, 1, 40).reshape(8, 5)
    for _ in range(6):
        oa, ob = a.observe_starts(t), b.observe_starts(t)
        keep = ~np.isnan(oa)
        np.testing.assert_array_equal(oa[keep], ob[keep])


def test_detection_report_shows_recovered_accuracy(recorded_node):
    """Regression for the dropped-row-shadowing failure: a dropped device
    reads as zero lead and steals argmin from the straggler; last-known-
    value imputation recovers the detection."""
    node, trace = recorded_node
    accs, accs_imp = [], []
    for seed in range(6):
        d = degrade(trace, SensorModel(SensorConfig(dropout_p=0.4,
                                                    seed=seed)))
        rep = detection_report(d)
        assert rep.dropped_samples > 0
        assert rep.accuracy_imputed is not None
        accs.append(rep.accuracy)
        accs_imp.append(rep.accuracy_imputed)
        assert f"acc_imputed={rep.accuracy_imputed:.3f}" in rep.row()
    # shadowing really bites on the raw stream...
    assert np.mean(accs) < 0.8
    # ...and the imputed stream recovers (near-)full accuracy
    assert np.mean(accs_imp) > np.mean(accs) + 0.2
    assert np.mean(accs_imp) > 0.9


def test_detection_report_no_dropout_reports_none(recorded_node):
    _, trace = recorded_node
    rep = detection_report(trace)
    assert rep.dropped_samples == 0
    assert rep.accuracy_imputed is None
    assert "acc_imputed" not in rep.row()


def test_degrade_through_imputing_sensor_leaves_no_nan_rows(recorded_node):
    """An imputing sensor in the degrade path fills dropped rows inline
    (after the device was first observed), so downstream consumers see a
    dense stream — what a live PowerManager(sensor=...) receives."""
    _, trace = recorded_node
    d = degrade(trace, SensorModel(SensorConfig(dropout_p=0.4,
                                                impute_dropout=True,
                                                seed=3)))
    first = d.samples[0]
    dense = [s for s in d.samples[1:]]
    seen = ~np.isnan(first.comp_start).all(axis=1)
    for s in dense:
        rows = np.isnan(s.comp_start).all(axis=1)
        assert not (rows & seen).any()     # once observed, never NaN again
        seen |= ~rows


# --------------------------------------------------------------------------- #
# fleet lead sensor (FleetSample.lead_obs + fleet_lead_report)
# --------------------------------------------------------------------------- #
def _recorded_cluster(topology="dp", noise_time_s=0.0, iters=12,
                      straggler_boost=1.28):
    wl = small_workload(n_layers=8)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=4, straggler_boost=straggler_boost,
                                  topology=topology),
                    devices_per_node=8, seed=5)
    col = TelemetryCollector(
        sensor_cfg=SensorConfig(noise_time_s=noise_time_s),
        max_samples=64, with_kernels=False).attach_cluster(cl)
    for _ in range(iters):
        cl.step()
    return TelemetryTrace.from_collector(col)


def test_fleet_lead_estimate_exact_for_lossless_dp():
    """DP lead *is* the barrier wait max(t) - t, so a lossless fleet
    sensor's estimate matches the topology signal float for float."""
    trace = _recorded_cluster("dp")
    for fs in trace.fleet:
        np.testing.assert_array_equal(fs.lead_obs, fs.lead)
    rep = fleet_lead_report(trace)
    assert rep.accuracy == 1.0 and rep.majority_correct
    assert rep.lead_rel_error == 0.0
    assert "fleet_lead_err=0.0000" in rep.row()


def test_fleet_lead_estimate_exact_for_lossless_pp():
    """PP's bubble structure is deterministic given the stage times, so
    the topology-aware estimator (telemetry/lead.py) mirrors the 1F1B
    arithmetic bit-for-bit from a lossless sensor: the barrier
    estimator's PP model bias is gone, not just reduced."""
    trace = _recorded_cluster("pp")
    assert trace.meta["topology_params"]["kind"] == "pp"
    for fs in trace.fleet:
        np.testing.assert_array_equal(fs.lead_obs, fs.lead)
    rep = fleet_lead_report(trace)
    assert rep.lead_rel_error == 0.0
    assert rep.majority_correct


def test_fleet_lead_estimator_tp_beats_barrier():
    """TP's per-sync jitter makes even *tied* nodes wait on each other
    (sum of per-segment maxima > max of sums): a plain barrier estimate
    reads ~0 lead for a uniform fleet, while the true exposed wait is
    positive.  The jitter-aware correction closes most of that gap and
    never does worse."""
    trace = _recorded_cluster("tp", straggler_boost=1.0)
    params = trace.meta["topology_params"]
    assert params["kind"] == "tp" and params["jitter"] > 0
    err_est = err_barrier = 0.0
    for fs in trace.fleet:
        barrier = np.max(fs.t_obs) - fs.t_obs
        err_est += float(np.abs(fs.lead_obs - fs.lead).sum())
        err_barrier += float(np.abs(barrier - fs.lead).sum())
    assert err_est < err_barrier


def test_fleet_lead_estimator_tp_straggler_ranking_survives():
    """With a real straggler the TP correction collapses (n_tied = 1,
    the straggler alone sets the rendezvous) and the estimate stays a
    barrier wait — the ranking a fleet manager acts on is preserved."""
    rep = fleet_lead_report(_recorded_cluster("tp"))
    assert rep.majority_correct


def test_fleet_lead_error_grows_with_sensor_noise():
    clean = fleet_lead_report(_recorded_cluster("dp", noise_time_s=0.0))
    noisy = fleet_lead_report(_recorded_cluster("dp", noise_time_s=0.01))
    assert noisy.lead_rel_error > clean.lead_rel_error
    assert noisy.accuracy <= clean.accuracy


def test_fleet_sensor_does_not_perturb_node_streams():
    """The fleet sensor draws from its own stream (FLEET_SENSOR_OFFSET):
    per-node observations are bit-identical to a node-only recording under
    the same noisy config."""
    cfg = SensorConfig(noise_time_s=1e-3, seed=7)
    wl = small_workload(n_layers=8)

    def run(attach_fleet):
        cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                        ClusterConfig(n_nodes=2, straggler_boost=1.28),
                        devices_per_node=8, seed=5)
        col = TelemetryCollector(sensor_cfg=cfg, max_samples=16)
        if attach_fleet:
            col.attach_cluster(cl)
        else:
            for n, node in enumerate(cl.nodes):
                col.attach_node(node, n)
            cl._telemetry_iter0 = cl.iteration
        for _ in range(6):
            cl.step()
        return col
    a, b = run(True), run(False)
    for sa, sb in zip(a.samples, b.samples):
        np.testing.assert_array_equal(sa.comp_start, sb.comp_start)
        np.testing.assert_array_equal(sa.power, sb.power)


def test_fleet_lead_obs_jsonl_roundtrip(tmp_path):
    trace = _recorded_cluster("dp", noise_time_s=1e-3, iters=6)
    p = str(tmp_path / "fleet.jsonl")
    save_trace(trace, p)
    back = load_trace(p)
    for a, b in zip(trace.fleet, back.fleet):
        np.testing.assert_array_equal(a.lead_obs, b.lead_obs)


def test_fleet_lead_report_rejects_pre_sensor_traces(tmp_path):
    """Traces written before lead_obs existed load fine (None) but the
    report refuses to score them rather than guessing."""
    trace = _recorded_cluster("dp", iters=4)
    p = str(tmp_path / "old.jsonl")
    save_trace(trace, p)
    with open(p) as f:
        lines = [json.loads(x) for x in f]
    for r in lines:
        r.pop("lead_obs", None)
    with open(p, "w") as f:
        f.writelines(json.dumps(r) + "\n" for r in lines)
    back = load_trace(p)
    assert all(fs.lead_obs is None for fs in back.fleet)
    with pytest.raises(ValueError, match="lead_obs"):
        fleet_lead_report(back)
