"""Scenario API: spec serialization, registry, CLI, and the equivalence
guarantee — `run_scenario` composes the existing layers without touching
their arithmetic, so a spec-driven run is bit-for-bit the hand-wired glue
it replaced (checked against the pre-API builders across all three
engines)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (SCENARIOS, Scenario, build_scenario, get_scenario,
                       list_scenarios, run_scenario, scenario_names,
                       variants, with_overrides)
from repro.api.cli import main as cli_main
from repro.api.spec import (SPEC_FORMAT, SPEC_VERSION, ManagerSpec,
                            NodeSpec, TelemetrySpec, WorkloadSpec)
from repro.configs import get_config
from repro.core.backends import ClusterSimBackend, SimBackend
from repro.core.c3sim import NodeSim, SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.manager import (FleetManagerConfig, ManagerConfig,
                                run_closed_loop, run_fleet_closed_loop)
from repro.core.thermal import MI300X_PRESET, ChurnEvent, ChurnModel
from repro.core.workload import fsdp_llm_iteration
from repro.telemetry import TelemetryCollector


# --------------------------------------------------------------------------- #
# spec serialization
# --------------------------------------------------------------------------- #
def _odd_scenario() -> Scenario:
    """A scenario exercising the tricky serialization corners: non-repr-
    friendly floats, NaN/Inf, nested churn models, int-keyed dicts."""
    return Scenario(
        name="test/odd",
        workload=WorkloadSpec(arch="llama3.1-8b", n_layers=4),
        sim=SimConfig(seed=3, noise=0.1 + 0.2, comm_gbps=1e9 / 3.0),
        node=NodeSpec(caps_w=float("nan")),
        fleet=ClusterConfig(
            n_nodes=2, tp_gbps=float("inf"),
            churn={1: ChurnModel(drift_rate=0.125,
                                 events=[ChurnEvent(2.5, 3, 1.0 / 3.0)])}),
        manager=ManagerSpec(scope="fleet", tune_after=7,
                            config=FleetManagerConfig(
                                max_adjustment=1.0 / 7.0)),
        telemetry=TelemetrySpec(max_samples=17, keep_truth=True),
        iterations=9, seed=11)


def test_json_round_trip_is_exact():
    sc = _odd_scenario()
    text = sc.to_json()
    sc2 = Scenario.from_json(text)
    # dict-level identity covers every float bit pattern (NaN encoded as
    # {"$float": "nan"}, so == is well-defined)
    assert sc.to_dict() == sc2.to_dict()
    assert sc2.to_json() == text
    # spot-check the decoded values really came back as the same doubles
    assert sc2.sim.noise == 0.1 + 0.2
    assert sc2.sim.comm_gbps == 1e9 / 3.0
    assert np.isnan(sc2.node.caps_w)
    assert np.isinf(sc2.fleet.tp_gbps)
    assert sc2.fleet.churn[1].events[0].factor == 1.0 / 3.0
    assert isinstance(sc2.manager.config, FleetManagerConfig)
    assert sc2.manager.config.max_adjustment == 1.0 / 7.0


def test_json_is_valid_strict_json():
    # NaN/Inf must never leak as bare tokens (json.dumps allow_nan=False)
    text = _odd_scenario().to_json()
    json.loads(text)                      # strict parse
    assert "NaN" not in text and "Infinity" not in text


def test_save_load_file(tmp_path):
    p = str(tmp_path / "sc.json")
    sc = _odd_scenario()
    sc.save(p)
    assert Scenario.load(p).to_dict() == sc.to_dict()


def test_version_and_format_guards():
    sc = Scenario()
    doc = json.loads(sc.to_json())
    assert doc["format"] == SPEC_FORMAT and doc["version"] == SPEC_VERSION
    newer = dict(doc, version=SPEC_VERSION + 1)
    with pytest.raises(ValueError, match="newer than supported"):
        Scenario.from_json(json.dumps(newer))
    unversioned = {k: v for k, v in doc.items() if k != "version"}
    with pytest.raises(ValueError, match="no version"):
        Scenario.from_json(json.dumps(unversioned))
    with pytest.raises(ValueError, match="not a lit-silicon-scenario"):
        Scenario.from_json(json.dumps({"format": "something-else",
                                       "version": 1}))


def test_unknown_keys_rejected_at_every_level():
    good = Scenario().to_dict()
    bad_top = dict(good, bogus_knob=1)
    with pytest.raises(ValueError, match="bogus_knob"):
        Scenario.from_dict(bad_top)
    bad_nested = json.loads(json.dumps(good))
    bad_nested["sim"]["kappa_typo"] = 0.5
    with pytest.raises(ValueError, match=r"scenario\.sim.*kappa_typo"):
        Scenario.from_dict(bad_nested)
    bad_fleet = _odd_scenario().to_dict()
    bad_fleet["fleet"]["churn"]["1"]["events"][0]["when"] = 3
    with pytest.raises(ValueError, match="when"):
        Scenario.from_dict(bad_fleet)


def test_omitted_keys_take_defaults():
    sc = Scenario.from_dict({"workload": {"arch": "mistral-7b"}})
    assert sc.workload.arch == "mistral-7b"
    assert sc.workload.batch == WorkloadSpec().batch
    assert sc.fleet is None and sc.manager is None


def test_scope_validation():
    with pytest.raises(ValueError, match="requires a fleet"):
        Scenario(manager=ManagerSpec(scope="fleet",
                                     config=FleetManagerConfig())).validate()
    with pytest.raises(ValueError, match="scope='fleet'"):
        Scenario(fleet=ClusterConfig(n_nodes=2),
                 manager=ManagerSpec(scope="node")).validate()
    with pytest.raises(ValueError, match="unknown device preset"):
        Scenario(node=NodeSpec(preset="h100")).validate()


def test_with_overrides_and_variants():
    sc = get_scenario("cluster/dp")
    sc2 = with_overrides(sc, {"fleet.n_nodes": 8, "sim.noise": 0.004,
                              "manager.tune_after": 3})
    assert sc2.fleet.n_nodes == 8 and sc2.sim.noise == 0.004
    assert sc2.manager.tune_after == 3
    assert sc.fleet.n_nodes == 4                 # base untouched
    with pytest.raises((KeyError, ValueError)):
        with_overrides(sc, {"fleet.n_knobs": 8})
    grid = variants("cluster/dp", {"fleet.n_nodes": [1, 2],
                                   "fleet.topology": ["dp", "pp"]})
    assert len(grid) == 4
    labels = [lbl for lbl, _ in grid]
    assert labels[0] == "fleet.n_nodes=1,fleet.topology=dp"
    assert {s.fleet.n_nodes for _, s in grid} == {1, 2}


# --------------------------------------------------------------------------- #
# registry completeness
# --------------------------------------------------------------------------- #
def test_registry_lists_the_issue_scenarios():
    names = scenario_names()
    for required in ("paper/table1-tdp", "paper/node-cap", "paper/cpu-slosh",
                     "cluster/dp", "cluster/pp", "cluster/tp",
                     "cluster/hetero-cooling", "cluster/churn",
                     "telemetry/rocm-smi-like", "telemetry/replay"):
        assert required in names
    rows = list_scenarios()
    assert len(rows) == len(names)
    assert all(desc for _, _, desc in rows)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_registry_scenario_round_trips_and_smoke_runs(name):
    sc = get_scenario(name)
    assert sc.name == name
    assert Scenario.from_json(sc.to_json()).to_dict() == sc.to_dict()
    res = run_scenario(sc, iterations=2)
    assert res.iterations == 2
    tput = res.metrics.get(
        "throughput", res.metrics.get(
            "fleet_tput", res.metrics.get("tokens_per_s")))
    assert np.isfinite(tput)
    if sc.telemetry is not None:
        assert res.metrics["telemetry_samples"] >= 1


def test_get_scenario_returns_fresh_instances():
    a, b = get_scenario("cluster/dp"), get_scenario("cluster/dp")
    a.fleet.n_nodes = 99
    assert b.fleet.n_nodes == 4


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #
def test_cli_list_and_show(capsys):
    assert cli_main(["list"]) == 0
    out = capsys.readouterr().out
    assert "cluster/dp" in out and "paper/table1-tdp" in out
    assert cli_main(["show", "cluster/dp"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["format"] == SPEC_FORMAT


def test_cli_unknown_scenario_exits_2(capsys):
    assert cli_main(["show", "no/such-scenario"]) == 2
    assert cli_main(["run", "no/such-scenario"]) == 2
    err = capsys.readouterr().err
    assert "available:" in err


def test_cli_run_json(capsys, tmp_path):
    # the acceptance-criteria invocation
    out_file = str(tmp_path / "res.json")
    assert cli_main(["run", "cluster/dp", "--iterations", "2", "--json",
                     "--out", out_file]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["scenario"] == "cluster/dp" and doc["iterations"] == 2
    assert np.isfinite(doc["metrics"]["fleet_tput"])
    with open(out_file) as f:
        assert json.load(f)["metrics"] == doc["metrics"]


def test_cli_run_spec_file_and_overrides(capsys, tmp_path):
    p = str(tmp_path / "sc.json")
    get_scenario("paper/characterization").save(p)
    assert cli_main(["run", "--spec", p, "--iterations", "2",
                     "--set", "workload.n_layers=2", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["iterations"] == 2
    # a bad override is a usage error, not a crash
    assert cli_main(["run", "--spec", p, "--set", "sim.bogus=1"]) == 2


def test_cli_sweep(capsys):
    assert cli_main(["sweep", "paper/characterization", "--iterations", "2",
                     "--grid", "workload.n_layers=2,4", "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)
    assert len(rows) == 2
    assert rows[0]["variant"] == "workload.n_layers=2"


def test_sweep_spec_json_round_trip():
    """SweepSpec survives JSON exactly — the `{"$float": ...}` discipline
    the scenario codec uses covers the sweep document too."""
    from repro.api.sweep import SWEEP_SPEC_FORMAT, Dist, SweepSpec
    spec = SweepSpec(
        scenario="cluster/dp", samples=7, seed=3, iterations=None,
        dists={"fleet.straggler_boost": Dist(kind="uniform", low=0.1 + 0.2,
                                             high=1e9 / 3.0),
               "sim.noise": Dist(kind="choice",
                                 choices=[0.002, float("inf"), None])},
        node_preset_pool=["mi300x", "mi300x-air"],
        grid=None)
    text = spec.to_json()
    json.loads(text)                          # strict JSON, no NaN/Inf tokens
    assert "Infinity" not in text
    back = SweepSpec.from_json(text)
    assert back == spec
    assert back.dists["fleet.straggler_boost"].low == 0.1 + 0.2
    assert np.isinf(back.dists["sim.noise"].choices[1])
    doc = json.loads(text)
    assert doc["format"] == SWEEP_SPEC_FORMAT
    # unknown keys are rejected loudly, at both levels
    with pytest.raises(ValueError, match="bogus"):
        SweepSpec.from_dict(dict(spec.to_dict(), bogus=1))
    bad = json.loads(json.dumps(spec.to_dict()))
    bad["dists"]["sim.noise"]["width"] = 2
    with pytest.raises(ValueError, match="width"):
        SweepSpec.from_dict(bad)


def test_sweep_samples_are_prefix_stable():
    """Sample k of an N-sample sweep equals sample k of an M-sample sweep
    (per-sample child generators) — growing a population never reshuffles
    the part already run."""
    from repro.api.sweep import Dist, SweepSpec, _sample_overrides
    base = get_scenario("cluster/dp")
    kw = dict(scenario="cluster/dp", seed=9,
              dists={"fleet.straggler_boost": Dist(low=1.1, high=1.5)},
              node_preset_pool=["mi300x", "mi300x-air"])
    big = _sample_overrides(SweepSpec(samples=8, **kw), base)
    small = _sample_overrides(SweepSpec(samples=4, **kw), base)
    assert big[:4] == small


def test_cli_sweep_mc(capsys, tmp_path):
    # the acceptance-criteria invocation (scaled down)
    out_file = str(tmp_path / "sweep.json")
    assert cli_main(["sweep", "cluster/dp", "--samples", "3",
                     "--iterations", "30", "--json", "--out",
                     out_file]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["format"] == "lit-silicon-sweep" and doc["n_samples"] == 3
    assert doc["mode"] == "mc"
    assert {"samples", "summary", "reference",
            "sweep_spec"} <= set(doc)
    with open(out_file) as f:
        assert json.load(f) == doc
    # a sweep spec file drives the same path; --samples still overrides
    from repro.api.sweep import SweepSpec
    spec_file = str(tmp_path / "spec.json")
    SweepSpec.from_dict(doc["sweep_spec"]).save(spec_file)
    assert cli_main(["sweep", "--sweep-spec", spec_file, "--samples", "2",
                     "--json"]) == 0
    doc2 = json.loads(capsys.readouterr().out)
    assert doc2["n_samples"] == 2
    # prefix stability end to end: shrinking the population keeps sample 0
    assert doc2["samples"][0]["label"] == doc["samples"][0]["label"]
    # node-scoped scenarios are a usage error, not a crash
    assert cli_main(["sweep", "paper/node-cap", "--samples", "2"]) == 2
    # naming a different scenario than the spec file is a usage error
    assert cli_main(["sweep", "cluster/tp", "--sweep-spec",
                     spec_file]) == 2


def test_cli_replay(capsys, tmp_path):
    p = str(tmp_path / "trace.jsonl")
    sc = get_scenario("telemetry/rocm-smi-like")
    run_scenario(sc, iterations=12, save_trace_path=p)
    assert cli_main(["replay", p, "--json", "--use-case", "gpu-red"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["scope"] == "node" and "final_caps" in doc
    assert cli_main(["replay", str(tmp_path / "missing.jsonl")]) == 2


# --------------------------------------------------------------------------- #
# equivalence guards: the facade adds no arithmetic
# --------------------------------------------------------------------------- #
def _wl8():
    cfg = get_config("llama3.1-8b").replace(n_layers=8)
    return fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)


@pytest.mark.parametrize("engine", [
    "event", "batched", "vector",
    pytest.param("jax", marks=pytest.mark.skipif(
        not __import__("repro.core.jax_engine",
                       fromlist=["HAS_JAX"]).HAS_JAX,
        reason="jax not installed")),
])
def test_cluster_dp_scenario_matches_hand_wired_bit_for_bit(engine):
    """`run_scenario` on ``cluster/dp`` == the pre-API ClusterSim +
    FleetPowerManager composition, float for float, per engine."""
    iters, tune = (12, 4) if engine == "event" else (24, 6)
    sc = get_scenario("cluster/dp")
    sc.fleet.engine = engine
    sc.manager.tune_after = tune
    res = run_scenario(sc, iterations=iters)

    cl = ClusterSim(_wl8(), MI300X_PRESET,
                    SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=4, straggler_boost=1.28,
                                  engine=engine),
                    devices_per_node=8, seed=5)
    for n in range(4):
        cl.set_node_caps(n, np.full(8, 700.0))
    mgr = run_fleet_closed_loop(
        ClusterSimBackend(cl),
        FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=700.0,
                           cluster_power_budget=4 * 8 * 700.0),
        iters, tune_after=tune)

    assert len(cl.history) == len(res.cluster.history) == iters
    for a, b in zip(cl.history, res.cluster.history):
        assert a["t_fleet"] == b["t_fleet"]
        assert np.array_equal(a["t_local"], b["t_local"])
        assert np.array_equal(a["lead"], b["lead"])
        assert np.array_equal(a["node_power"], b["node_power"])
    assert len(mgr.budget_log) == len(res.manager.budget_log)
    assert all(np.array_equal(x, y) for x, y in
               zip(mgr.budget_log, res.manager.budget_log))
    assert np.array_equal(mgr.node_budgets, res.manager.node_budgets)
    for n in range(4):
        assert np.array_equal(cl.get_node_caps(n),
                              res.cluster.get_node_caps(n))
        assert all(np.array_equal(x, y) for x, y in
                   zip(mgr.managers[n].adjust_log,
                       res.manager.managers[n].adjust_log))
    # the managed loop must actually have adjusted something, or the
    # equality above is vacuous
    assert len(mgr.budget_log) > 0


def test_node_manager_scenario_matches_hand_wired_bit_for_bit():
    """``paper/table1-tdp`` (trimmed) == the pre-API NodeSim +
    run_closed_loop composition from examples/power_management.py."""
    iters = 60
    sc = get_scenario("paper/table1-tdp")
    res = run_scenario(sc, iterations=iters)

    cfg = get_config("llama3.1-8b")
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    node = NodeSim(wl, MI300X_PRESET,
                   SimConfig(seed=1, comm_gbps=40.0, engine="batched"),
                   8, seed=1)
    mgr = run_closed_loop(
        SimBackend(node),
        ManagerConfig(use_case="gpu-red", sampling_period=2, warmup=3,
                      window_size=2, power_cap=700.0, cpu_budget=20.0),
        iters)

    assert len(node.history) == len(res.node.history) == iters
    for a, b in zip(node.history, res.node.history):
        assert a["t_iter"] == b["t_iter"]
        assert np.array_equal(a["power"], b["power"])
        assert np.array_equal(a["cap"], b["cap"])
    assert np.array_equal(mgr.backend.get_power_caps(),
                          res.manager.backend.get_power_caps())
    assert all(np.array_equal(x, y) for x, y in
               zip(mgr.adjust_log, res.manager.adjust_log))


def test_telemetry_scenario_records_identically_to_hand_wired():
    """A telemetry-attached fleet scenario records the same samples the
    pre-API examples/telemetry_study.py glue produced."""
    iters = 10
    sc = with_overrides(get_scenario("cluster/dp"),
                        {"manager": None, "telemetry": {},
                         "fleet.n_nodes": 2})
    res = run_scenario(sc, iterations=iters)

    cl = ClusterSim(_wl8(), MI300X_PRESET,
                    SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=2, straggler_boost=1.28),
                    devices_per_node=8, seed=5)
    for n in range(2):
        cl.set_node_caps(n, np.full(8, 700.0))
    col = TelemetryCollector(max_samples=2 * iters + 1)
    col.attach_cluster(cl)
    for _ in range(iters):
        cl.step()

    a, b = list(col.samples), list(res.collector.samples)
    assert len(a) == len(b) == 2 * iters
    for sa, sb in zip(a, b):
        assert (sa.iteration, sa.node) == (sb.iteration, sb.node)
        assert np.array_equal(sa.comp_start, sb.comp_start)
        assert np.array_equal(sa.power, sb.power)
        assert sa.t_wall == sb.t_wall
    fa, fb = list(col.fleet), list(res.collector.fleet)
    assert len(fa) == len(fb) == iters
    for x, y in zip(fa, fb):
        assert x.t_fleet == y.t_fleet
        assert np.array_equal(x.lead, y.lead)


def test_build_scenario_exposes_handles():
    built = build_scenario(get_scenario("paper/characterization"))
    assert built.node is not None and built.cluster is None
    built.node.step()
    assert len(built.node.history) == 1


# --------------------------------------------------------------------------- #
# review regressions
# --------------------------------------------------------------------------- #
def test_envelope_typo_is_rejected_not_defaulted():
    """A typo'd envelope must never silently load an all-defaults spec."""
    with pytest.raises(ValueError, match="unknown envelope"):
        Scenario.from_json(json.dumps({"format": SPEC_FORMAT, "version": 1,
                                       "scenarios": {"iterations": 999}}))
    with pytest.raises(ValueError, match="no 'scenario' body"):
        Scenario.from_json(json.dumps({"format": SPEC_FORMAT,
                                       "version": 1}))


def test_override_deep_under_null_section_materializes_defaults():
    sc = get_scenario("paper/characterization")      # telemetry is None
    sc2 = with_overrides(sc, {"telemetry.sensor.dropout_p": 0.1})
    assert sc2.telemetry is not None
    assert sc2.telemetry.sensor.dropout_p == 0.1
    assert sc2.telemetry.keep_truth is TelemetrySpec().keep_truth
    sc3 = with_overrides(sc, {"manager.config.power_cap": 650.0})
    assert sc3.manager.config.power_cap == 650.0


def test_cli_chrome_trace_alone_enables_telemetry(capsys, tmp_path):
    p = str(tmp_path / "out.chrome.json")
    assert cli_main(["run", "paper/characterization", "--iterations", "2",
                     "--chrome-trace", p, "--json"]) == 0
    capsys.readouterr()
    with open(p) as f:
        assert json.load(f)["traceEvents"]
