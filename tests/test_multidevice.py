"""Multi-device semantics via subprocesses (8 host devices).

conftest must NOT set XLA_FLAGS (smoke tests see 1 device), so each test
spawns a fresh interpreter with the flag and runs a self-contained script.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# every case spawns a fresh interpreter and compiles jax programs
pytestmark = pytest.mark.slow

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_ring_all_gather_matches_allgather():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.collectives import ring_all_gather
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
        f = shard_map(lambda s: ring_all_gather(s, "data"),
                      mesh=mesh, in_specs=P("data", None),
                      out_specs=P("data", None, None), check_rep=False)
        out = f(x)   # (8*8//8? -> (8, 1, 4) stacked chunks per shard
        out = np.asarray(out).reshape(8, 8, 1, 4)
        for r in range(8):
            np.testing.assert_allclose(out[r].reshape(8, 4), np.asarray(x))
        print("ring ok")
    """))


def test_compressed_psum_close_to_exact():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.compression import compressed_psum
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        f = shard_map(lambda s: compressed_psum(s, "data"), mesh=mesh,
                      in_specs=P("data", None), out_specs=P("data", None),
                      check_rep=False)
        approx = np.asarray(f(x))[0]
        exact = np.asarray(x.sum(0))
        scale = np.abs(np.asarray(x)).max() / 127.0
        assert np.abs(approx - exact).max() <= 8 * scale * 0.5 + 1e-6
        print("psum ok")
    """))


def test_sharded_train_matches_single_device():
    """2x4 mesh FSDP+TP step produces the same loss as 1-device."""
    code_t = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced_config, TrainConfig, ParallelConfig
        from repro.models import build_model, make_batch
        from repro.parallel.fsdp import build_train_step, init_train_state
        from repro.parallel.sharding import ShardingRules
        import numpy as onp
        cfg = get_reduced_config("llama3.1-8b").replace(
            d_model=64, n_heads=4, n_kv_heads=4, d_head=16, n_layers=2,
            vocab_size=512, d_ff=128)
        mesh = jax.sharding.Mesh(
            onp.array(jax.devices()).reshape(%s), ("data", "model"))
        parallel = ParallelConfig()
        model = build_model(cfg, max_cache_len=32)
        rules = ShardingRules(mesh, cfg, parallel)
        step, _ = build_train_step(model, TrainConfig(warmup_steps=1),
                                   rules, parallel)
        with mesh:
            state = init_train_state(model, rules, parallel, seed=3)
            batch = make_batch(cfg, 8, 16)
            for _ in range(3):
                state, m = step(state, batch)
        print("LOSS=%%.6f" %% float(m["loss"]))
    """
    o1 = run_py(code_t % "(2, 4)", devices=8)
    o2 = run_py(code_t % "(1, 1)", devices=1)
    l1 = float(o1.split("LOSS=")[1])
    l2 = float(o2.split("LOSS=")[1])
    assert abs(l1 - l2) < 5e-2, (l1, l2)


def test_fsdp_prefetch_chain():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.parallel.collectives import make_fsdp_prefetch_fn
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        f = jax.jit(make_fsdp_prefetch_fn(mesh))
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 32)) * 0.1
        out = f(x, w.reshape(3, 8, 4, 32).transpose(0, 1, 2, 3).reshape(3, 32, 32))
        # reference: plain chain
        ref = x
        for i in range(3):
            ref = jax.nn.relu(ref @ w[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4)
        print("prefetch ok")
    """))


def test_moe_shard_map_matches_scatter():
    """grok-style TP experts: forced-local shard_map dispatch == pjit scatter
    (big capacity -> no drops; tolerance = bf16 partial-sum reordering)."""
    print(run_py("""
        import jax, numpy as np, jax.numpy as jnp, dataclasses
        from repro.configs import get_reduced_config, ParallelConfig
        from repro.models import build_model, make_batch
        from repro.models.common import init_params
        from repro.parallel.act import activation_sharding
        from repro.parallel.sharding import ShardingRules
        from repro.parallel.moe_shard_map import set_moe_dispatch
        mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(2, 8),
                                 ("data", "model"))
        cfg = get_reduced_config("grok-1-314b").replace(d_ff=64)
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, d_expert=64, capacity_factor=8.0))
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        batch = make_batch(cfg, 4, 16)
        rules = ShardingRules(mesh, cfg, ParallelConfig())
        with mesh:
            with activation_sharding(mesh, rules.activation_rules()):
                l1, _ = jax.jit(model.loss)(params, batch)
                set_moe_dispatch("shard_map")
                l2, _ = jax.jit(model.loss)(params, batch)
        d = abs(float(l1 - l2))
        assert d < 2e-2, (float(l1), float(l2))
        print("moe shard_map ok", d)
    """, devices=16))
