"""Parallelism topologies: DP/PP/TP coupling structure, heterogeneous
fleets, cooling churn, and the vectorized cluster engine.

Acceptance (ISSUE 3): for each of DP/PP/TP — (a) one hot GPU measurably
stretches fleet iteration time, (b) coupling strength orders TP >= DP >= PP
for the same workload, (c) `FleetPowerManager` recovers >= 50% of the
straggler gap; plus edge cases (1-node cluster, PP depth 1 == DP without
all-reduce, preset-driven straggler, churn-driven straggler migration) and
the vector engine's trace identity with the event reference.
"""
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import small_workload
from repro.core.backends import ClusterSimBackend
from repro.core.c3sim import C3Sim, SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.manager import FleetManagerConfig, run_fleet_closed_loop
from repro.core.thermal import (MI300X_PRESET, ChurnEvent, ChurnModel,
                                PRESETS, ThermalModel, derated_preset)
from repro.core.topology import (DataParallel, PipelineParallel,
                                 TensorParallel, make_topology)

CAP = 700.0
N_NODES = 4
TOPOLOGIES = ("dp", "pp", "tp")


def make_cluster(topo, boost, seed=5, n_nodes=N_NODES, caps=CAP, **cc_kw):
    """Fleet over a fast-ish DP fabric so the all-reduce constant does not
    drown the coupling term (the quantity under test)."""
    wl = small_workload(n_layers=8)
    cc_kw.setdefault("inter_node_gbps", 100.0)
    cl = ClusterSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                    ClusterConfig(n_nodes=n_nodes, straggler_boost=boost,
                                  topology=topo, **cc_kw),
                    devices_per_node=8, seed=seed)
    if caps is not None:
        for n in range(n_nodes):
            cl.set_node_caps(n, np.full(8, float(caps)))
    return cl


@pytest.fixture(scope="module")
def topo_fleets():
    """Per topology: (healthy, straggler-unmanaged, straggler-managed)."""
    out = {}
    for topo in TOPOLOGIES:
        healthy = make_cluster(topo, 1.0)
        strag = make_cluster(topo, 1.28)
        for _ in range(60):
            healthy.step()
            strag.step()
        managed = make_cluster(topo, 1.28)
        mgr = run_fleet_closed_loop(
            ClusterSimBackend(managed),
            FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                               warmup=2, window_size=2, node_window_size=2,
                               power_cap=CAP,
                               cluster_power_budget=N_NODES * 8 * CAP),
            120, tune_after=20)
        out[topo] = (healthy, strag, managed, mgr)
    return out


# ----------------------------------------------------------- DP invariants
def test_dp_preserves_barrier_allreduce_arithmetic():
    """The refactor routes DP through `Topology` but the arithmetic is the
    original ClusterSim's, bit for bit."""
    cl = make_cluster("dp", 1.28, caps=None, inter_node_gbps=12.5)
    cl.step()
    h = cl.history[-1]
    assert h["t_fleet"] == float(h["t_local"].max()) + cl.allreduce_time()
    np.testing.assert_array_equal(h["lead"], h["t_local"].max() - h["t_local"])
    assert h["topology"] == "dp"
    assert not cl.topology.wait_active          # barrier waits idle and cool


# --------------------------------------------------------------- edge cases
@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_single_node_cluster_is_uncoupled(topo):
    cl = make_cluster(topo, 1.28, n_nodes=1, caps=None)
    cl.step()
    h = cl.history[-1]
    # no peers: fleet time is the node's own time (TP comm is 0 at N=1)
    assert h["t_fleet"] == pytest.approx(float(h["t_local"].max()))
    np.testing.assert_allclose(h["lead"], 0.0, atol=1e-12)


def test_pp_depth_1_equals_dp_without_allreduce():
    """A 1-stage pipeline is just the node itself — identical, step for
    step, to data parallelism with no gradient all-reduce."""
    pp = make_cluster("pp", 1.28, n_nodes=1, caps=None)
    dp = make_cluster("dp", 1.28, n_nodes=1, caps=None)
    assert dp.allreduce_time() == 0.0
    for _ in range(5):
        pp.step()
        dp.step()
    t_pp = [h["t_fleet"] for h in pp.history]
    t_dp = [h["t_fleet"] for h in dp.history]
    np.testing.assert_allclose(t_pp, t_dp, rtol=1e-12)


def test_pp_fleet_time_bounds():
    """Pipeline fleet time is at least the slowest stage (throughput bound)
    and carries the fill/drain bubble on top."""
    cl = make_cluster("pp", 1.28)
    cl.step()
    h = cl.history[-1]
    assert h["t_fleet"] >= h["t_local"].max()
    assert (h["lead"] >= 0).all()
    # the straggling stage has the least bubble
    assert int(np.argmin(h["lead"])) == int(np.argmax(h["t_local"]))


def test_tp_exposes_skew_and_waits_active():
    cl = make_cluster("tp", 1.28)
    cl.step()
    h = cl.history[-1]
    assert cl.topology.wait_active              # waits burn near-peak power
    assert h["t_fleet"] >= h["t_local"].max()
    assert (h["lead"] >= -1e-12).all()
    assert int(np.argmin(h["lead"])) == int(np.argmax(h["t_local"]))


def test_make_topology_rejects_unknown():
    wl = small_workload(n_layers=4)
    with pytest.raises(ValueError):
        make_topology(ClusterConfig(topology="ring-of-fire"), 4, wl, 1e9)


def test_topology_classes_direct():
    dp = DataParallel(4, grad_bytes=1e9, gbps=100.0)
    pp = PipelineParallel(4, act_bytes=1e8, gbps=100.0, microbatches=8)
    tp = TensorParallel(4, sync_bytes=1e8, gbps=300.0, n_syncs=16,
                        jitter=0.0)
    t_local = np.array([1.1, 1.0, 1.0, 1.0])
    s_dp, s_pp, s_tp = dp.step(t_local), pp.step(t_local), tp.step(t_local)
    assert s_dp.t_fleet == pytest.approx(1.1 + dp.comm_time())
    # PP: sum/M + (M-1)/M * max + fill/drain p2p
    assert s_pp.t_fleet == pytest.approx(4.1 / 8 + 7 / 8 * 1.1
                                         + pp.comm_time())
    # TP, jitter 0: max + skew_cost * (max - min) + per-layer collectives
    assert s_tp.t_fleet == pytest.approx(1.1 + 0.1 + tp.comm_time())
    for s in (s_dp, s_pp, s_tp):
        assert int(np.argmin(s.lead)) == 0      # straggler leads by ~0


# ------------------------------------------------- the paper's claim, per topo
@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_hot_gpu_stretches_fleet(topo, topo_fleets):
    healthy, strag, _, _ = topo_fleets[topo]
    tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()
    assert (tp_h - tp_s) / tp_h > 0.002         # (a) measurable stretch
    slowest = [h["slowest_node"] for h in strag.history[-20:]]
    assert np.mean(np.array(slowest) == 0) > 0.8


def test_coupling_strength_orders_tp_dp_pp(topo_fleets):
    """(b) per-layer sync on the fast link couples tighter than the global
    barrier, which upper-bounds the pipeline's point-to-point bubbles."""
    coupling = {}
    for topo in TOPOLOGIES:
        healthy, strag, _, _ = topo_fleets[topo]
        tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()
        coupling[topo] = (tp_h - tp_s) / tp_h
    assert coupling["tp"] >= coupling["dp"] >= coupling["pp"]


@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_fleet_manager_recovers_half_the_gap(topo, topo_fleets):
    """(c) the hierarchical manager, fed the topology's own lead signal,
    recovers at least half the straggler gap under every topology."""
    healthy, strag, managed, mgr = topo_fleets[topo]
    tp_h, tp_s = healthy.fleet_throughput(), strag.fleet_throughput()
    tp_m = managed.fleet_throughput()
    assert tp_h > tp_s
    recovery = (tp_m - tp_s) / (tp_h - tp_s)
    assert recovery >= 0.5
    # the straggler node won budget from the waiting peers
    assert mgr.node_budgets[0] == mgr.node_budgets.max()
    assert mgr.node_budgets.sum() <= N_NODES * 8 * CAP + 1e-6


def test_manager_consumes_topology_lead(topo_fleets):
    _, strag, _, _ = topo_fleets["tp"]
    be = ClusterSimBackend(strag)
    lead = be.node_leads()
    np.testing.assert_array_equal(lead, strag.history[-1]["lead"])
    assert int(np.argmin(lead)) == 0            # straggler waits least


# ------------------------------------------------------ heterogeneous fleets
def test_preset_creates_the_straggler():
    """No boosted device anywhere: the straggler is the air-cooled *node*
    (its preset, not its thermal draw, is the root cause)."""
    cl = make_cluster("dp", 1.0, node_presets=["mi300x", "mi300x-air",
                                               "mi300x", "mi300x"])
    for _ in range(50):
        cl.step()
    slowest = np.array([h["slowest_node"] for h in cl.history[-30:]])
    assert np.mean(slowest == 1) > 0.8
    homo = make_cluster("dp", 1.0)
    for _ in range(50):
        homo.step()
    assert cl.fleet_throughput() < homo.fleet_throughput()


def test_hetero_backend_and_budget_bounds():
    presets = ["mi300x", "mi300x-air", "mi300x", "v5e"]
    cl = make_cluster("dp", 1.0, node_presets=presets, caps=None)
    be = ClusterSimBackend(cl)
    np.testing.assert_array_equal(
        be.node_tdps, [PRESETS[p].tdp for p in presets])
    mgr = run_fleet_closed_loop(
        ClusterSimBackend(cl),
        FleetManagerConfig(use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=240.0), 40, tune_after=4)
    # every node's budget respects its own silicon and floor
    assert (mgr.node_budgets <= 8 * mgr.node_tdps + 1e-6).all()
    assert (mgr.node_budgets >= 8 * mgr.node_tdps * 0.25 - 1e-6).all()
    assert mgr.node_budgets.sum() <= mgr.cluster_budget + 1e-6


def test_node_presets_length_mismatch():
    with pytest.raises(ValueError):
        make_cluster("dp", 1.0, node_presets=["mi300x"])


def test_derated_preset():
    air = derated_preset(MI300X_PRESET, 1.22)
    assert air.r_th_mean == pytest.approx(MI300X_PRESET.r_th_mean * 1.22)
    assert air.tdp == MI300X_PRESET.tdp         # same silicon, worse cooling


# ------------------------------------------------------------- cooling churn
def test_churn_multipliers():
    cm = ChurnModel(drift_rate=0.1,
                    events=[ChurnEvent(100.0, 2, 1.5),
                            ChurnEvent(200.0, 2, 0.5)])
    np.testing.assert_allclose(cm.multipliers(0.0, 4), 1.0)
    m = cm.multipliers(3600.0, 4)               # 1 h drift + both events
    np.testing.assert_allclose(m[[0, 1, 3]], 1.1)
    assert m[2] == pytest.approx(1.1 * 1.5 * 0.5)


def test_churn_drift_heats_devices():
    tm_still = ThermalModel(MI300X_PRESET, 4, seed=0)
    tm_drift = ThermalModel(MI300X_PRESET, 4, seed=0,
                            churn=ChurnModel(drift_rate=2.0))
    s1, s2 = tm_still.init_state(), tm_drift.init_state()
    util = np.full(4, 0.9)
    for _ in range(400):                        # ~400 s simulated
        tm_still.update(s1, util, 1.0)
        tm_drift.update(s2, util, 1.0)
    assert (s2.temp > s1.temp).all()
    assert (s2.freq <= s1.freq).all()


def test_churn_migrates_the_straggler():
    """Cooling degrades over simulated time: node 0 straggles first, then
    a harder degradation on node 2 takes over mid-run."""
    probe = make_cluster("dp", 1.0)
    probe.step()
    t1 = probe.history[0]["t_fleet"]
    churn = {0: ChurnModel(events=[ChurnEvent(0.0, 3, 1.35)]),
             2: ChurnModel(events=[ChurnEvent(30 * t1, 5, 1.8)])}
    cl = make_cluster("dp", 1.0, churn=churn)
    for _ in range(80):
        cl.step()
    slowest = np.array([h["slowest_node"] for h in cl.history])
    assert np.mean(slowest[5:25] == 0) > 0.8    # before the second event
    assert np.mean(slowest[-25:] == 2) > 0.8    # after it


# ------------------------------------------------------------- vector engine
def _trace_pair(n_layers=4, seed=3, freq_lo=1.5, spike_p=0.0):
    wl = small_workload(n_layers=n_layers)
    freq = np.linspace(freq_lo, 2.1, 8)
    kw = dict(seed=seed, comm_gbps=40.0, comm_spike_p=spike_p)
    t_e = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="event")
    t_v = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="vector")
    return t_e, t_v


def test_vector_engine_identical_traces():
    t_e, t_v = _trace_pair()
    for field in ("comp_start", "comp_end", "comp_overlap",
                  "comm_start", "comm_end", "util"):
        np.testing.assert_allclose(getattr(t_e, field), getattr(t_v, field),
                                   rtol=1e-9, atol=1e-12, err_msg=field)
    assert t_e.t_iter == pytest.approx(t_v.t_iter, rel=1e-12)


@settings(deadline=None, max_examples=12)
@given(seed=st.integers(0, 2 ** 16), freq_lo=st.floats(1.0, 2.05),
       spike_p=st.sampled_from([0.0, 0.05]))
def test_vector_engine_identical_property(seed, freq_lo, spike_p):
    """Property: the vector engine consumes the same RNG stream and emits
    the event engine's trace for any seed/frequency spread/spike setting —
    detection and the cluster layer are engine-independent."""
    t_e, t_v = _trace_pair(seed=seed, freq_lo=freq_lo, spike_p=spike_p)
    np.testing.assert_allclose(t_e.comp_end, t_v.comp_end,
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(t_e.comm_end, t_v.comm_end,
                               rtol=1e-9, atol=1e-12)


def test_vector_engine_moe_blocking():
    from repro.configs import get_config
    from repro.core.workload import fsdp_llm_iteration

    cfg = get_config("deepseek-v3-16b").replace(n_layers=4)
    wl = fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)
    freq = np.linspace(1.4, 2.1, 8)
    kw = dict(seed=7, comm_gbps=40.0)
    t_e = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="event")
    t_v = C3Sim(wl, MI300X_PRESET, SimConfig(**kw), 8).run_iteration(
        freq, engine="vector")
    for field in ("comp_start", "comp_end", "comm_end"):
        np.testing.assert_allclose(getattr(t_e, field), getattr(t_v, field),
                                   rtol=1e-9, atol=1e-12, err_msg=field)


@pytest.mark.parametrize("cc_kw", [
    {},
    {"node_presets": ["mi300x", "mi300x-air", "mi300x", "v5e"]},
], ids=["homogeneous", "heterogeneous"])
def test_cluster_vector_engine_identical(cc_kw):
    """engine='vector' batches all N*G lanes in one numpy pass and must
    reproduce the per-node batched run exactly — including heterogeneous
    per-node presets (per-lane rates)."""
    cb = make_cluster("dp", 1.28, engine="batched", **cc_kw)
    cv = make_cluster("dp", 1.28, engine="vector", **cc_kw)
    for _ in range(4):
        tb, tv = cb.step(), cv.step()
        for a, b in zip(tb, tv):
            np.testing.assert_array_equal(a.comp_end, b.comp_end)
            np.testing.assert_array_equal(a.comm_end, b.comm_end)
    assert cb.history[-1]["t_fleet"] == cv.history[-1]["t_fleet"]


# -------------------------------------------------------------- bench harness
@pytest.mark.slow
def test_bench_only_unknown_name_errors():
    """`benchmarks/run.py --only bogus` must fail loudly, not silently
    run nothing."""
    import os
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "benchmarks", "run.py"),
         "--only", "no-such-bench"],
        capture_output=True, text=True, cwd=root, env=env)
    assert proc.returncode != 0
    assert "no benchmark section" in proc.stderr.lower()
