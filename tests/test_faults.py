"""Fault injection + escalation: detect → mitigate → drain → restart.

Three layers under test: the `FaultModel` schedule queries, the physics of
each fault kind inside `ClusterSim`, and the `EscalationPolicy` /
`run_healing_fleet` control loop (with its acceptance ordering: healing
must out-goodput both ignoring the fault and hair-trigger draining).
"""
import json
import math

import numpy as np
import pytest

from conftest import small_workload
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.escalate import (DRAIN_MODES, STAGES, EscalationConfig,
                                 EscalationPolicy)
from repro.core.faults import (FAULT_KINDS, LOST_DEVICE_RATE,
                               UNRECOVERABLE_KINDS, FaultEvent, FaultModel,
                               random_faults)
from repro.core.thermal import MI300X_PRESET


# --------------------------------------------------------------- FaultModel
def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(0.0, "meltdown").validate()
    with pytest.raises(ValueError, match="duration"):
        FaultEvent(0.0, "kernel_hang", duration=0.0).validate()
    for kind in FAULT_KINDS:
        FaultEvent(1.0, kind).validate()


def test_fault_active_window_and_unrecoverable():
    ev = FaultEvent(5.0, "kernel_hang", magnitude=2.0, duration=3.0)
    assert not ev.active(4.9) and ev.active(5.0) and ev.active(7.9)
    assert not ev.active(8.0)
    assert not ev.unrecoverable                    # transient hang heals
    assert FaultEvent(5.0, "kernel_hang").unrecoverable   # forever: doesn't
    for kind in UNRECOVERABLE_KINDS:
        assert FaultEvent(0.0, kind, duration=1.0).unrecoverable


def test_rth_multiplier_grows_from_onset():
    fm = FaultModel([FaultEvent(10.0, "thermal_runaway", node=1, device=3,
                                magnitude=0.1)])
    np.testing.assert_array_equal(fm.rth_multipliers(5.0, 1, 8), np.ones(8))
    np.testing.assert_array_equal(fm.rth_multipliers(10.0, 0, 8), np.ones(8))
    m = fm.rth_multipliers(20.0, 1, 8)
    assert m[3] == pytest.approx(1.0 + 0.1 * 10.0)
    assert (np.delete(m, 3) == 1.0).all()


def test_perf_scale_none_when_idle_and_loss_pins_rate():
    fm = FaultModel([
        FaultEvent(0.0, "perf_degrade", node=0, device=1, magnitude=0.5,
                   duration=10.0),
        FaultEvent(5.0, "device_loss", node=0, device=1),
    ])
    assert fm.perf_scale(20.0, 1, 8) is None       # wrong node: no alloc
    m = fm.perf_scale(1.0, 0, 8)
    assert m[1] == pytest.approx(0.5)
    # loss takes the min — degradation can't make a dead chip faster
    assert fm.perf_scale(6.0, 0, 8)[1] == pytest.approx(LOST_DEVICE_RATE)
    assert fm.perf_scale(15.0, 0, 8)[1] == pytest.approx(LOST_DEVICE_RATE)


def test_hang_and_sensor_queries():
    fm = FaultModel([
        FaultEvent(2.0, "kernel_hang", node=1, magnitude=3.0, duration=4.0),
        FaultEvent(2.0, "kernel_hang", node=1, magnitude=0.5, duration=4.0),
        FaultEvent(1.0, "sensor_death", node=2, duration=5.0),
    ])
    assert fm.hang_multiplier(1.0, 1) == 1.0
    assert fm.hang_multiplier(3.0, 1) == pytest.approx(3.0)  # 0.5 clamps to 1
    assert fm.sensor_dead(3.0, 2) and not fm.sensor_dead(7.0, 2)
    assert not fm.sensor_dead(3.0, 1)


def test_onset_and_activation_queries():
    fm = FaultModel([
        FaultEvent(4.0, "kernel_hang", node=1, magnitude=2.0, duration=2.0),
        FaultEvent(12.0, "thermal_runaway", node=2, device=3, magnitude=0.4),
        FaultEvent(22.0, "device_loss", node=2, device=3),
    ])
    # a transient hang is not a drain justification
    assert fm.onset_of_unrecoverable(1) is None
    assert fm.onset_of_unrecoverable(2) == 12.0
    assert fm.onset_of_unrecoverable(2, before=10.0) is None
    assert [e.kind for e in fm.activated_between(4.0, 22.0)] == \
        ["thermal_runaway", "device_loss"]
    assert fm.activated_between(4.0, 22.0, nodes=[0, 1]) == []
    assert len(fm.events_for(2)) == 2


def test_random_faults_seeded_and_sorted():
    a = random_faults(7, n_nodes=3, horizon_s=500.0, rate_per_node_hour=60.0)
    b = random_faults(7, n_nodes=3, horizon_s=500.0, rate_per_node_hour=60.0)
    assert a == b
    assert a != random_faults(8, 3, 500.0, 60.0)
    assert all(0 <= e.t < 500.0 for e in a)
    assert [e.t for e in a] == sorted(e.t for e in a)
    assert all(e.kind in FAULT_KINDS for e in a)
    assert random_faults(7, 3, 500.0, 0.0) == []


# ------------------------------------------------------- injection physics
def _fleet(faults, n_nodes=2, **cc_kw):
    wl = small_workload(n_layers=8)
    return ClusterSim(wl, MI300X_PRESET,
                      SimConfig(seed=1, comm_gbps=40.0, engine="batched"),
                      ClusterConfig(n_nodes=n_nodes, straggler_boost=1.0,
                                    **cc_kw),
                      devices_per_node=8, seed=5, faults=faults)


def test_runaway_slows_its_node():
    faulted = _fleet(FaultModel([FaultEvent(2.0, "thermal_runaway", node=1,
                                            device=3, magnitude=0.4)]))
    healthy = _fleet(None)
    for _ in range(40):
        faulted.step()
        healthy.step()
    tf = np.asarray(faulted.history[-1]["t_local"], float)
    th = np.asarray(healthy.history[-1]["t_local"], float)
    assert tf[1] > 1.1 * th[1]          # runaway node visibly behind
    assert tf[0] == pytest.approx(th[0], rel=0.05)


def test_kernel_hang_multiplies_step_time_then_heals():
    fm = FaultModel([FaultEvent(2.0, "kernel_hang", node=0, magnitude=3.0,
                                duration=2.0)])
    cl = _fleet(fm)
    base = hung = healed = None
    for _ in range(30):
        cl.step()
        h = cl.history[-1]
        t0, ts = float(h["t_local"][0]), float(h["t_sim"])
        if ts < 2.0:
            base = t0
        elif fm.hang_multiplier(ts, 0) > 1.0 and hung is None:
            hung = t0
        elif ts > 5.0 and healed is None:
            healed = t0
    assert base and hung and healed
    assert hung == pytest.approx(3.0 * base, rel=0.1)
    assert healed == pytest.approx(base, rel=0.1)


def test_sensor_death_masks_only_observation():
    cl = _fleet(FaultModel([FaultEvent(0.0, "sensor_death", node=1)]))
    cl.step()
    h = cl.history[-1]
    dead = np.asarray(h["sensor_dead"], bool)
    assert dead[1] and not dead[0]
    # the simulator itself still runs the node (observers are blind, the
    # physics is not)
    assert np.isfinite(h["t_local"]).all()


def test_device_loss_reported_once_across_rebuilds():
    from repro.telemetry import TelemetryCollector
    fm = FaultModel([FaultEvent(0.1, "device_loss", node=0, device=0)])
    col = TelemetryCollector(max_samples=50)
    cl = _fleet(fm)
    col.attach_cluster(cl)
    cl.step()
    assert [e.kind for e in col.events] == ["device_loss"]
    # a rebuilt fleet sharing the dedup set must not re-report the onset
    cl2 = _fleet(fm)
    col.attach_cluster(cl2)
    cl2._fault_seen = cl._fault_seen
    cl2.step()
    assert [e.kind for e in col.events] == ["device_loss"]


# --------------------------------------------------------- EscalationPolicy
def _policy(mode="escalate", **kw):
    kw.setdefault("patience_s", 4.0)
    kw.setdefault("sensor_retries", 2)
    cfg = EscalationConfig(drain_mode=mode, **kw)
    return EscalationPolicy(cfg, nodes=[0, 1, 2, 3])


def _warm(pol, steps=20, dt=0.4):
    """Feed healthy uniform observations so the watchdogs learn a baseline;
    returns the advanced simulated clock."""
    t = 0.0
    for s in range(steps):
        t += dt
        assert pol.observe(s, np.full(4, 0.4), t_sim=t) is None
    return t


def test_policy_config_validation():
    assert DRAIN_MODES == ("escalate", "immediate", "never")
    with pytest.raises(ValueError, match="drain_mode"):
        EscalationConfig(drain_mode="panic").validate()
    with pytest.raises(ValueError, match="straggle_threshold"):
        EscalationConfig(straggle_threshold=1.0).validate()
    with pytest.raises(ValueError, match="patience_s"):
        EscalationConfig(patience_s=0.0).validate()
    rt = EscalationConfig.from_dict(EscalationConfig().to_dict())
    assert rt == EscalationConfig()
    with pytest.raises(ValueError, match="unknown"):
        EscalationConfig.from_dict({"straggle_thresold": 2.0})


def test_policy_width_and_singleton_guards():
    pol = _policy()
    with pytest.raises(ValueError, match="membership"):
        pol.observe(0, np.ones(3), t_sim=0.4)
    pol.reset([7])
    assert pol.observe(0, np.ones(1), t_sim=0.4) is None


def test_transient_straggle_rides_out_under_patience():
    pol = _policy()
    t = _warm(pol)
    # 2.4 s of straggling < patience_s=4: suspect fires, no drain
    for s in range(3):
        t += 0.8
        assert pol.observe(20 + s, np.array([0.4, 0.8, 0.4, 0.4]),
                           t_sim=t) is None
    assert [e.stage for e in pol.events] == ["suspect"]
    t += 0.4
    assert pol.observe(23, np.full(4, 0.4), t_sim=t) is None  # healed
    assert pol.strikes[1] == 0 and not pol.suspected[1]
    # a fresh streak later must re-run the whole patience window
    for s in range(3):
        t += 0.8
        assert pol.observe(24 + s, np.array([0.4, 0.8, 0.4, 0.4]),
                           t_sim=t) is None


def test_sustained_straggle_escalates_to_drain():
    pol = _policy()
    t = _warm(pol)
    decision = None
    for s in range(12):
        t += 0.8
        decision = pol.observe(20 + s, np.array([0.4, 0.4, 0.9, 0.4]),
                               t_sim=t)
        if decision is not None:
            break
    assert decision is not None
    assert decision.global_node == 2 and decision.reason == "straggle"
    assert decision.ratio == pytest.approx(0.9 / 0.4)
    stages = [e.stage for e in pol.events]
    assert stages == ["suspect", "escalate", "drain"]
    assert all(s in STAGES for s in stages)
    # patience honored: the drain came no earlier than patience_s after
    # the first strike
    first = next(e for e in pol.events if e.stage == "suspect")
    drain = next(e for e in pol.events if e.stage == "drain")
    assert drain.t_sim - first.t_sim >= pol.cfg.patience_s - 0.8 - 1e-9


def test_policy_reports_global_node_ids():
    pol = _policy()
    pol.reset([0, 2, 3])                 # node 1 already drained
    t = 0.0
    for s in range(20):
        t += 0.4
        pol.observe(s, np.full(3, 0.4), t_sim=t)
    for s in range(12):
        t += 0.8
        d = pol.observe(20 + s, np.array([0.4, 0.4, 0.9]), t_sim=t)
        if d is not None:
            break
    assert d.node == 2 and d.global_node == 3
    assert {e.node for e in pol.events} == {3}


def test_sensor_retry_then_death_then_drain():
    pol = _policy()                      # sensor_retries=2
    t = _warm(pol)
    # two NaN reads recover: retries absorb them, no event
    for s in range(2):
        t += 0.4
        assert pol.observe(20 + s, np.array([0.4, np.nan, 0.4, 0.4]),
                           t_sim=t) is None
    t += 0.4
    assert pol.observe(22, np.full(4, 0.4), t_sim=t) is None
    assert pol.events == [] and pol.stale[1] == 0
    # sustained NaNs: sensor-dead after the retry budget, then a drain
    # once the streak outlives patience (corroborated by the dead sensor)
    d = None
    for s in range(16):
        t += 0.4
        d = pol.observe(23 + s, np.array([0.4, np.nan, 0.4, 0.4]), t_sim=t)
        if d is not None:
            break
    assert d is not None and d.reason == "sensor" and d.global_node == 1
    assert [e.stage for e in pol.events] == ["sensor-dead", "escalate",
                                             "drain"]


def test_immediate_mode_drains_on_first_strike():
    pol = _policy("immediate")
    t = _warm(pol)
    d = pol.observe(20, np.array([0.4, 0.9, 0.4, 0.4]), t_sim=t + 0.9)
    assert d is not None and d.global_node == 1 and d.strikes == 1


def test_never_mode_observes_but_never_drains():
    pol = _policy("never")
    t = _warm(pol)
    for s in range(30):
        t += 0.9
        assert pol.observe(20 + s, np.array([0.4, 0.4, 0.9, 0.4]),
                           t_sim=t) is None
    assert {e.stage for e in pol.events} == {"suspect", "escalate"}


def test_min_nodes_floor_blocks_drain_in_runner():
    # exercised through run_healing_fleet: a 2-node fleet with min_nodes=2
    # must ride out an unrecoverable fault
    from repro.core.escalate import run_healing_fleet
    wl = small_workload(n_layers=8)
    rep = run_healing_fleet(
        wl, MI300X_PRESET,
        SimConfig(seed=1, comm_gbps=40.0, engine="batched"),
        ClusterConfig(n_nodes=2, straggler_boost=1.0),
        iterations=30, seed=5, node_caps_w=700.0,
        faults=FaultModel([FaultEvent(2.0, "device_loss", node=1,
                                      device=0)]),
        escalation=EscalationConfig(min_nodes=2))
    assert rep.drains == [] and rep.surviving_nodes == 2
    assert rep.progress == 30


# ------------------------------------------- healing run + acceptance order
@pytest.fixture(scope="module")
def heal_runs(tmp_path_factory):
    """The pinned fault-heal scenario in all three drain modes, plus the
    healing trace recorded to disk."""
    from repro.api import get_scenario, run_scenario, with_overrides
    trace_path = str(tmp_path_factory.mktemp("heal") / "trace.jsonl")
    heal = run_scenario(get_scenario("cluster/fault-heal"),
                        save_trace_path=trace_path)
    ignored = run_scenario(get_scenario("cluster/fault-ignored"))
    immediate = run_scenario(with_overrides(
        get_scenario("cluster/fault-heal"),
        {"escalation.drain_mode": "immediate"}))
    return heal, ignored, immediate, trace_path


def test_heal_report_shape(heal_runs):
    heal, _, _, _ = heal_runs
    rep = heal.heal
    assert rep is not None
    assert rep.progress == 160
    assert rep.false_drains == 0
    assert [d["node"] for d in rep.drains] == [2]
    assert rep.drains[0]["reason"] == "straggle"
    assert rep.surviving_nodes == 3
    assert math.isfinite(rep.time_to_detect_s)
    assert rep.time_to_heal_s == pytest.approx(6.0 + 8.0)
    assert rep.checkpoints >= 1 and rep.restores == 1
    assert rep.lost_units > 0                      # the rollback is charged
    assert rep.goodput == pytest.approx(rep.useful_units / rep.t_total_s)
    # elastic replan recorded: 3 nodes x 8 devices, TP kept at 8
    assert rep.drains[0]["mesh"] == [3, 8]
    assert rep.drains[0]["batch_per_replica"] * 3 >= 64
    assert rep.drains[0]["batch_padding"] == \
        rep.drains[0]["batch_per_replica"] * 3 - 64


def test_healing_beats_ignoring_and_hair_trigger(heal_runs):
    """The acceptance ordering: detect+drain+restart must out-goodput both
    limping behind the dead chip and draining on the first blip."""
    heal, ignored, immediate, _ = heal_runs
    g_heal = heal.metrics["goodput"]
    g_ign = ignored.metrics["goodput"]
    g_imm = immediate.metrics["goodput"]
    assert g_heal > g_ign
    assert g_heal > g_imm
    # the hang on node 1 must not cost a drain under patience — but the
    # hair-trigger mode pays for exactly that false drain
    assert heal.metrics["false_drains"] == 0
    assert ignored.metrics["n_drains"] == 0
    assert immediate.metrics["false_drains"] >= 1
    # every mode committed the same useful work; only time differs
    assert heal.heal.progress == ignored.heal.progress == 160


def test_heal_metrics_surface_in_result(heal_runs):
    heal, ignored, _, _ = heal_runs
    for key in ("goodput", "useful_units", "lost_units", "t_total_s",
                "n_drains", "false_drains", "time_to_detect_s",
                "time_to_heal_s", "surviving_nodes", "checkpoints",
                "checkpoint_restores"):
        assert key in heal.metrics
    # no-drain run reports the NaN sentinels as -1 (strict-JSON metrics)
    assert ignored.metrics["time_to_detect_s"] == -1.0
    payload = json.dumps(heal.to_json_dict(), allow_nan=False)
    assert json.loads(payload)["metrics"]["goodput"] > 0


def test_escalation_trace_replays_bit_for_bit(heal_runs):
    heal, _, _, trace_path = heal_runs
    from repro.telemetry import (escalation_replay_matches, load_trace,
                                 replay_escalation)
    trace = load_trace(trace_path)
    assert trace.meta["escalation"]["drain_mode"] == "escalate"
    rec = [e for e in trace.events if e.source == "escalation"]
    assert [e.kind for e in rec] == [e.stage for e in heal.heal.events]
    rp = replay_escalation(trace)
    assert rp.drained_nodes == [2]
    log = []
    assert escalation_replay_matches(trace, rp, log=log), log
    # a tampered trace must NOT match (the checker has teeth)
    rec[0].node = 3
    assert not escalation_replay_matches(trace, rp, log=[])


def test_fault_onsets_recorded_in_trace(heal_runs):
    *_, trace_path = heal_runs
    from repro.telemetry import load_trace
    trace = load_trace(trace_path)
    inj = [e for e in trace.events if e.source == "fault"]
    assert [e.kind for e in inj] == ["kernel_hang", "thermal_runaway",
                                    "device_loss"]
    assert [e.node for e in inj] == [1, 2, 2]


# ------------------------------------------------------------ spec round-trip
def test_fault_scenario_spec_round_trip():
    from repro.api import EscalationSpec, FaultSpec, get_scenario
    from repro.api.spec import Scenario
    sc = get_scenario("cluster/fault-heal")
    assert isinstance(sc.faults, FaultSpec)
    assert isinstance(sc.escalation, EscalationSpec)
    rt = Scenario.from_json(sc.to_json())
    assert rt.to_dict() == sc.to_dict()
    # inf duration survives the strict-JSON encoding
    assert math.isinf(rt.faults.events[1].duration)
    assert rt.escalation.watchdog.stall_factor == pytest.approx(1.35)


def test_fault_spec_validation_requires_fleet():
    from repro.api import get_scenario
    sc = get_scenario("cluster/fault-heal").replace(fleet=None, manager=None)
    with pytest.raises(ValueError):
        sc.validate()
