"""repro.analysis: the AST invariant checker (repro lint).

Each rule gets a planted-violation fixture (positive) and a compliant
twin (negative) in a throwaway repo-shaped tree; the baseline gets an
add/expire round trip; the JSON report is schema-checked against the
registry; and the end-to-end test asserts the real repo lints clean —
the checked-in ``lint_baseline.json`` is part of that contract.
"""
from __future__ import annotations

import json
import textwrap

import pytest

from repro.analysis import (RULES, SCHEMAS, lint_paths, load_baseline,
                            render_json, run_lint, schema_version,
                            update_baseline)
from repro.analysis.baseline import (BASELINE_FORMAT, BASELINE_VERSION,
                                     Baseline, BaselineEntry)
from repro.analysis.report import REPORT_FORMAT, REPORT_VERSION
from repro.api.cli import main as cli_main


def _plant(tmp_path, rel, code):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    return rel


def _findings(tmp_path, rel_or_rels, rules=None):
    rels = [rel_or_rels] if isinstance(rel_or_rels, str) else rel_or_rels
    res = run_lint(rels, root=str(tmp_path), rules=rules)
    return res.findings


# --------------------------------------------------------------- rule catalog
def test_rule_catalog_shape():
    assert sorted(RULES) == [f"RPL00{i}" for i in range(1, 9)]
    for rid, rule in RULES.items():
        assert rule.id == rid
        assert rule.title and rule.rationale
        assert rule.check_file or rule.check_project


# ------------------------------------------------------------------- RPL001
def test_rpl001_flags_unseeded_and_entropy_seeded_rng(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        import random
        import time
        import numpy as np

        a = np.random.default_rng()
        b = np.random.default_rng(time.time_ns())
        c = random.random()
        d = np.random.normal(0.0, 1.0)
        """)
    found = _findings(tmp_path, rel, rules=["RPL001"])
    assert len(found) == 4
    assert all(f.rule == "RPL001" for f in found)


def test_rpl001_negative_and_tests_scope(tmp_path):
    code = """\
        import numpy as np
        ok1 = np.random.default_rng(0)
        ok2 = np.random.default_rng(seed=123)
        """
    rel = _plant(tmp_path, "src/repro/foo.py", code)
    assert _findings(tmp_path, rel, rules=["RPL001"]) == []
    # tests/ may use whatever RNG it likes
    rel = _plant(tmp_path, "tests/test_foo.py", "import random\n"
                 "x = random.random()\n")
    assert _findings(tmp_path, rel, rules=["RPL001"]) == []


def test_rpl001_prngkey_float_seed(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        import jax
        bad = jax.random.PRNGKey(1.5)
        ok = jax.random.PRNGKey(0)
        """)
    found = _findings(tmp_path, rel, rules=["RPL001"])
    assert len(found) == 1 and "float" in found[0].message


# ------------------------------------------------------------------- RPL002
def test_rpl002_wall_clock_in_clocked_layer(tmp_path):
    code = """\
        import time
        t = time.time()
        """
    rel = _plant(tmp_path, "src/repro/core/foo.py", code)
    found = _findings(tmp_path, rel, rules=["RPL002"])
    assert len(found) == 1 and "time.time" in found[0].message
    # same code outside the clocked layers: legal
    rel = _plant(tmp_path, "src/repro/models/foo.py", code)
    assert _findings(tmp_path, rel, rules=["RPL002"]) == []


def test_rpl002_reference_is_not_a_call(tmp_path):
    rel = _plant(tmp_path, "src/repro/serve/foo.py", """\
        import time

        def run(clock=time.perf_counter):
            return clock()
        """)
    assert _findings(tmp_path, rel, rules=["RPL002"]) == []


# ------------------------------------------------------------------- RPL003
def test_rpl003_missing_kwargs_and_nan_literal(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        import json
        a = json.dumps({"x": 1})
        b = json.dumps({"x": float("nan")}, sort_keys=True,
                       allow_nan=False)
        """)
    found = _findings(tmp_path, rel, rules=["RPL003"])
    msgs = [f.message for f in found]
    assert sum("allow_nan" in m for m in msgs) == 1
    assert sum("sort_keys" in m for m in msgs) == 1
    assert sum("non-finite literal" in m for m in msgs) == 1


def test_rpl003_negative(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        import json
        a = json.dumps({"x": 1}, sort_keys=True, allow_nan=False)
        """)
    assert _findings(tmp_path, rel, rules=["RPL003"]) == []


# ------------------------------------------------------------------- RPL004
def test_rpl004_unsorted_listing_and_set_iteration(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        import os
        for f in os.listdir("."):
            print(f)
        for x in {1, 2, 3}:
            print(x)
        """)
    found = _findings(tmp_path, rel, rules=["RPL004"])
    assert len(found) == 2


def test_rpl004_sorted_listing_is_legal(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        import os
        for f in sorted(os.listdir(".")):
            print(f)
        for x in sorted({1, 2, 3}):
            print(x)
        """)
    assert _findings(tmp_path, rel, rules=["RPL004"]) == []


# ------------------------------------------------------------------- RPL005
_PARITY_DECL = """\
    from dataclasses import dataclass

    @dataclass
    class SimConfig:
        alpha: float = 1.0
        beta: float = 2.0

    def run(cfg):
        return cfg.alpha + cfg.beta
    """


def test_rpl005_one_sided_field_read(tmp_path):
    rels = [
        _plant(tmp_path, "src/repro/core/c3sim.py", _PARITY_DECL),
        _plant(tmp_path, "src/repro/core/jax_engine.py", """\
            def run(cfg):
                return cfg.alpha        # beta is silently ignored
            """),
    ]
    found = _findings(tmp_path, rels, rules=["RPL005"])
    assert len(found) == 1
    assert found[0].snippet == "SimConfig.beta"
    assert found[0].path == "src/repro/core/c3sim.py"


def test_rpl005_both_sides_read_is_clean(tmp_path):
    rels = [
        _plant(tmp_path, "src/repro/core/c3sim.py", _PARITY_DECL),
        _plant(tmp_path, "src/repro/core/jax_engine.py", """\
            def run(cfg):
                return cfg.alpha * cfg.beta
            """),
    ]
    assert _findings(tmp_path, rels, rules=["RPL005"]) == []


def test_rpl005_partial_lint_run_skips_contract(tmp_path):
    rel = _plant(tmp_path, "src/repro/core/c3sim.py", _PARITY_DECL)
    assert _findings(tmp_path, rel, rules=["RPL005"]) == []


# ------------------------------------------------------------------- RPL006
def test_rpl006_unregistered_format_and_version_drift(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        rogue = {"format": "totally-new-format", "version": 1}
        drift = {"format": "lit-silicon-telemetry", "version": 99}
        ok = {"format": "lit-silicon-telemetry", "version": 1}
        """)
    found = _findings(tmp_path, rel, rules=["RPL006"])
    assert len(found) == 2
    assert any("not registered" in f.message for f in found)
    assert any("registry declares version" in f.message for f in found)


def test_rpl006_resolves_module_constants(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        MY_FORMAT = "lit-silicon-metrics"
        MY_VERSION = 1
        doc = {"format": MY_FORMAT, "version": MY_VERSION}
        """)
    assert _findings(tmp_path, rel, rules=["RPL006"]) == []


# ------------------------------------------------------------------- RPL007
def test_rpl007_bare_float_equality(tmp_path):
    rel = _plant(tmp_path, "src/repro/telemetry/foo.py", """\
        def check(x):
            if x == 1.5:
                return True
            return x == 0.0     # additive identity: allowed
        """)
    found = _findings(tmp_path, rel, rules=["RPL007"])
    assert len(found) == 1
    # the same comparison outside the replay surfaces is not flagged
    rel = _plant(tmp_path, "src/repro/models/foo.py",
                 "def f(x):\n    return x == 1.5\n")
    assert _findings(tmp_path, rel, rules=["RPL007"]) == []


# ------------------------------------------------------------------- RPL008
def test_rpl008_wall_clock_default_and_body_fallback(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", """\
        import time

        class A:
            def __init__(self, clock=time.monotonic):
                self.clock = clock

        class B:
            def __init__(self, clock=None):
                self.clock = time.monotonic if clock is None else clock

        class C:
            def __init__(self, clock=None):
                self.clock = clock
        """)
    found = _findings(tmp_path, rel, rules=["RPL008"])
    assert len(found) == 2
    assert {f.snippet for f in found} == {"A.__init__.clock",
                                          "B.__init__.clock"}


# ------------------------------------------------------------------ baseline
def test_baseline_add_expire_roundtrip(tmp_path):
    rel = _plant(tmp_path, "src/repro/core/foo.py",
                 "import time\nt = time.time()\n")
    res = run_lint([rel], root=str(tmp_path), rules=["RPL002"])
    assert len(res.findings) == 1 and res.exit_code() == 1

    # add: --update-baseline captures the finding as UNREVIEWED
    bl_path = str(tmp_path / "lint_baseline.json")
    bl = update_baseline(Baseline.empty(), res.findings)
    assert all("UNREVIEWED" in e.reason for e in bl.entries)
    bl.save(bl_path)
    back = load_baseline(bl_path)
    assert len(back.entries) == 1

    # suppressed now, and byte-deterministic on re-save
    res2 = run_lint([rel], root=str(tmp_path), rules=["RPL002"],
                    baseline=back)
    assert res2.findings == [] and len(res2.suppressed) == 1
    assert res2.exit_code() == 0
    back.save(bl_path + ".2")
    assert (tmp_path / "lint_baseline.json").read_text() == \
        (tmp_path / "lint_baseline.json.2").read_text()

    # expire: fix the violation -> the entry is stale and fails the run
    _plant(tmp_path, "src/repro/core/foo.py", "t = 0.0\n")
    res3 = run_lint([rel], root=str(tmp_path), rules=["RPL002"],
                    baseline=back)
    assert res3.findings == [] and len(res3.stale_baseline) == 1
    assert res3.exit_code() == 1

    # --update-baseline prunes it
    pruned = update_baseline(back, res3.findings + res3.suppressed)
    assert pruned.entries == []


def test_baseline_file_scope_suppresses_whole_file(tmp_path):
    rel = _plant(tmp_path, "benchmarks/bench.py",
                 "import time\na = time.time()\nb = time.monotonic()\n")
    bl = Baseline(entries=[BaselineEntry(rule="RPL002", path=rel,
                                         scope="file", reason="by design")])
    res = run_lint([rel], root=str(tmp_path), rules=["RPL002"], baseline=bl)
    assert res.findings == [] and len(res.suppressed) == 2
    assert res.exit_code() == 0


def test_baseline_rejects_malformed_documents(tmp_path):
    p = tmp_path / "bl.json"
    p.write_text(json.dumps({"format": "something-else", "version": 1},
                            sort_keys=True, allow_nan=False))
    with pytest.raises(ValueError, match=BASELINE_FORMAT):
        load_baseline(str(p))
    p.write_text(json.dumps({"format": BASELINE_FORMAT,
                             "version": BASELINE_VERSION,
                             "entries": [{"rule": "RPL001"}]},
                            sort_keys=True, allow_nan=False))
    with pytest.raises(ValueError, match="missing"):
        load_baseline(str(p))
    with pytest.raises(FileNotFoundError):
        load_baseline(str(tmp_path / "nope.json"))


# -------------------------------------------------------------- JSON report
def test_json_report_schema_and_registry_pins(tmp_path):
    rel = _plant(tmp_path, "src/repro/foo.py", "import json\n"
                 "x = json.dumps({})\n")
    res = run_lint([rel], root=str(tmp_path))
    doc = json.loads(render_json(res))
    assert doc["format"] == REPORT_FORMAT
    assert doc["version"] == REPORT_VERSION
    assert doc["exit_code"] == 1 and doc["clean"] is False
    assert doc["counts"] == {"RPL003": 2}
    assert [f["rule"] for f in doc["findings"]] == ["RPL003", "RPL003"]
    for f in doc["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message",
                          "snippet"}
    # the report and baseline formats are themselves registered artifacts
    assert schema_version(REPORT_FORMAT) == REPORT_VERSION
    assert schema_version(BASELINE_FORMAT) == BASELINE_VERSION
    with pytest.raises(KeyError):
        schema_version("no-such-format")


def test_registry_pins_runtime_format_constants():
    """Every writer-side FORMAT/VERSION constant matches the registry —
    the invariant RPL006 enforces statically, checked live."""
    from repro.api.spec import SPEC_FORMAT, SPEC_VERSION
    from repro.api.sweep import (SWEEP_FORMAT, SWEEP_SPEC_FORMAT,
                                 SWEEP_VERSION)
    from repro.obs.incidents import INCIDENTS_FORMAT, INCIDENTS_VERSION
    from repro.obs.metrics import METRICS_FORMAT, METRICS_VERSION
    from repro.telemetry.trace_io import TRACE_FORMAT, TRACE_VERSION
    pairs = [(TRACE_FORMAT, TRACE_VERSION), (SPEC_FORMAT, SPEC_VERSION),
             (SWEEP_FORMAT, SWEEP_VERSION),
             (SWEEP_SPEC_FORMAT, SWEEP_VERSION),
             (METRICS_FORMAT, METRICS_VERSION),
             (INCIDENTS_FORMAT, INCIDENTS_VERSION)]
    for fmt, ver in pairs:
        assert schema_version(fmt) == ver
    assert set(SCHEMAS) >= {fmt for fmt, _ in pairs}


# --------------------------------------------------------------- CLI + e2e
def test_cli_lint_exit_codes_and_update_baseline(tmp_path, capsys):
    _plant(tmp_path, "src/repro/core/foo.py",
           "import time\nt = time.time()\n")
    argv = ["lint", "--root", str(tmp_path), "--baseline", "none", "src"]
    assert cli_main(argv) == 1
    assert cli_main(argv + ["--json"]) == 1
    out = capsys.readouterr().out
    doc = json.loads(out.splitlines()[-1] and out[out.index("{"):])
    assert doc["counts"] == {"RPL002": 1}

    assert cli_main(["lint", "--root", str(tmp_path), "src",
                     "--update-baseline"]) == 0
    assert (tmp_path / "lint_baseline.json").exists()
    assert cli_main(["lint", "--root", str(tmp_path), "src"]) == 0

    assert cli_main(["lint", "--root", str(tmp_path), "src",
                     "--rules", "RPL999"]) == 2
    assert cli_main(["lint", "--root", str(tmp_path), "no/such/dir"]) == 2
    assert cli_main(["lint", "--list-rules"]) == 0


def test_repo_lints_clean_end_to_end():
    """The whole tree passes its own invariants: zero non-baselined
    findings, zero stale baseline entries, against the committed
    lint_baseline.json."""
    result, baseline = lint_paths()
    assert result.findings == []
    assert result.stale_baseline == []
    assert result.clean and result.exit_code() == 0
    # the shipped baseline is reviewed: no UNREVIEWED placeholders
    assert all("UNREVIEWED" not in e.reason for e in baseline.entries)
