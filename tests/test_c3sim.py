"""C3 simulator semantics + the Lit Silicon dynamics of paper §III."""
import numpy as np
import pytest

from conftest import small_node, small_workload
from repro.core.detect import (classify_overlap, lead_value_detect,
                               straggler_index)


@pytest.fixture(scope="module")
def settled():
    node = small_node(seed=1)
    for _ in range(35):
        tr = node.step()
    return node, tr


def test_deterministic_workload_structure():
    wl = small_workload(n_layers=8)
    assert wl.total_gflop > 0 and wl.total_bytes > 0
    # every layer has one fwd AG; backward adds AG+RS
    ags = [c for c in wl.comm if c.name.startswith("ag_")]
    rss = [c for c in wl.comm if c.name.startswith("rs_")]
    assert len(ags) == 2 * 8 and len(rss) == 8
    # RS kernels have producers (gradient computes)
    assert all(c.producer is not None for c in rss)


def test_trace_sanity(settled):
    node, tr = settled
    assert np.isfinite(tr.comp_start).all() and np.isfinite(tr.comp_end).all()
    assert (tr.comp_end >= tr.comp_start).all()
    # per-device compute stream is ordered
    assert (np.diff(tr.comp_start, axis=1) >= -1e-12).all()
    # collective: local starts never after the global end
    valid = np.isfinite(tr.comm_start)
    assert (tr.comm_start[valid] <= np.broadcast_to(
        tr.comm_end, tr.comm_start.shape)[valid] + 1e-12).all()
    # overlap time bounded by kernel duration
    assert (tr.comp_overlap <= tr.comp_dur + 1e-9).all()


def test_straggler_emerges_and_is_detected(settled):
    node, tr = settled
    # detection identifies the *slowest* device (the operational straggler)
    slowest = int(np.argmin(node.history[-1]["freq_used"]))
    assert straggler_index(tr.comp_start) == slowest
    # the cooling-worst slot is among the hottest two devices
    s = node.thermal.straggler_hint
    assert node.state.temp[s] >= np.sort(node.state.temp)[-2]
    # paper Fig 5 bands: hottest/coolest and fastest/slowest ratios
    fr = node.state.freq.max() / node.state.freq.min()
    assert 1.03 < fr < 1.15


def test_insight3_straggler_faster_on_varying_overlap(settled):
    node, tr = settled
    s = int(np.argmin(node.history[-1]["freq_used"]))
    const = classify_overlap(tr.overlap_ratio)
    d_v = tr.comp_dur[:, ~const]
    d_c = tr.comp_dur[:, const]
    if (~const).sum() >= 3:
        assert d_v[s].mean() < np.delete(d_v, s, 0).mean()
    # and slower on constant-overlap kernels
    assert d_c[s].mean() > np.delete(d_c, s, 0).mean()


def test_leads_grow_then_equilibrium(settled):
    """Fig 6 dynamics: leads accumulate across forward layers (phase 2),
    then collective gating clamps them to a small equilibrium (phase 3)."""
    node, tr = settled
    s = int(np.argmin(node.history[-1]["freq_used"]))
    leader = int(np.argmax(lead_value_detect(tr.comp_start)))
    lead_k = tr.comp_start[s] - tr.comp_start[leader]
    K = lead_k.shape[0]
    # growth through the forward half
    assert lead_k[3 * K // 8: K // 2].mean() > lead_k[: K // 8].mean()
    # equilibrium: the lead stops accumulating (late values well below peak)
    assert lead_k[3 * K // 4:].mean() < lead_k.max() / 3
    assert lead_k[3 * K // 4:].std() < lead_k.max() / 4


def test_same_seed_reproducible():
    n1 = small_node(seed=7)
    n2 = small_node(seed=7)
    t1 = n1.step()
    t2 = n2.step()
    np.testing.assert_allclose(t1.comp_start, t2.comp_start)
    np.testing.assert_allclose(t1.t_iter, t2.t_iter)


def test_moe_blocking_a2a_resets_leads():
    """Fig 16: non-overlapped all-to-all syncs every layer -> small leads."""
    from repro.configs import get_config
    from repro.core.c3sim import NodeSim, SimConfig
    from repro.core.thermal import MI300X_PRESET
    from repro.core.workload import fsdp_llm_iteration

    moe_cfg = get_config("deepseek-v3-16b").replace(n_layers=8)
    wl = fsdp_llm_iteration(moe_cfg, batch=8, seq=4096, n_shards=8)
    node = NodeSim(wl, MI300X_PRESET, SimConfig(seed=1, comm_gbps=40.0),
                   8, seed=1)
    for _ in range(10):
        tr_moe = node.step()
    dense = small_node(seed=1)
    for _ in range(10):
        tr_dense = dense.step()
    # per-kernel leads (excluding aggregate) are smaller under MoE sync
    lead_moe = np.median(np.nanmax(
        tr_moe.comp_start.max(0) - tr_moe.comp_start, axis=0))
    lead_dense = np.median(np.nanmax(
        tr_dense.comp_start.max(0) - tr_dense.comp_start, axis=0))
    assert lead_moe < lead_dense
