"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses that set the flag
themselves (see test_multidevice.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too, so tests can reuse benchmark builders (one setup, no
# drifting copies — e.g. benchmarks.telemetry_bench.fleet_cfg)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

try:                                   # property tests prefer the real thing
    import hypothesis                  # noqa: F401
except ImportError:                    # containers without it use the shim
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_shims"))

# the `slow` marker is registered in pyproject.toml [tool.pytest.ini_options]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def small_workload(arch="llama3.1-8b", n_layers=32):
    from repro.configs import get_config
    from repro.core.workload import fsdp_llm_iteration
    cfg = get_config(arch).replace(n_layers=n_layers)
    return fsdp_llm_iteration(cfg, batch=2, seq=4096, n_shards=8)


def small_node(seed=1, n_layers=32, **sim_kw):
    from repro.core.c3sim import NodeSim, SimConfig
    from repro.core.thermal import MI300X_PRESET
    # the batched engine produces traces identical to the event engine
    # (property-tested in test_cluster.py) at ~10x the speed
    sim_kw.setdefault("engine", "batched")
    return NodeSim(small_workload(n_layers=n_layers), MI300X_PRESET,
                   SimConfig(seed=seed, comm_gbps=40.0, **sim_kw),
                   n_devices=8, seed=seed)
