"""Pallas kernel sweeps (deliverable c): shapes x dtypes vs the pure-jnp
oracle, in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.moe_gemm import moe_gemm, moe_gemm_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.rwkv6_wkv import wkv6, wkv6_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _keys(n):
    return jax.random.split(jax.random.PRNGKey(42), n)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("S", [64, 200, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [dict(causal=True), dict(causal=True, window=32),
                                dict(causal=False)])
def test_flash_attention_sweep(S, dtype, kw):
    B, H, kvH, D = 2, 4, 2, 32
    ks = _keys(3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, kvH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, kvH, D), dtype)
    out = flash_attention(q, k, v, causal=kw.get("causal"),
                          window=kw.get("window", 0),
                          mask=None if kw.get("causal") is not False else None)
    kk = jnp.repeat(k, H // kvH, 2)
    vv = jnp.repeat(v, H // kvH, 2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = kk.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = vv.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ref = flash_attention_ref(qf, kf, vf, **kw).reshape(B, H, S, D)
    ref = ref.transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_explicit_mask():
    B, S, H, D = 1, 96, 2, 16
    ks = _keys(3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    mask = jnp.tril(jnp.ones((S, S), bool), k=-1) | jnp.eye(S, dtype=bool)
    out = flash_attention(q, k, v, mask=mask)
    ref = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ------------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape", [(4, 128), (2, 100, 256), (1, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    ks = _keys(3)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], shape[-1:], jnp.float32)
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, w), np.float32),
        np.asarray(rmsnorm_ref(x, w), np.float32), atol=TOL[dtype])
    r = jax.random.normal(ks[2], shape, dtype)
    o1, res1 = rmsnorm(x, w, r)
    o2, res2 = rmsnorm_ref(x, w, r)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=TOL[dtype])
    np.testing.assert_allclose(np.asarray(res1, np.float32),
                               np.asarray(res2, np.float32), atol=TOL[dtype])


# ------------------------------------------------------------------ moe gemm
@pytest.mark.parametrize("ECdh", [(4, 64, 96, 200), (2, 100, 48, 64),
                                  (8, 8, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_sweep(ECdh, dtype):
    E, C, d, h = ECdh
    ks = _keys(2)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    w = jax.random.normal(ks[1], (E, d, h), dtype)
    np.testing.assert_allclose(
        np.asarray(moe_gemm(x, w), np.float32),
        np.asarray(moe_gemm_ref(x, w), np.float32),
        atol=TOL[dtype] * np.sqrt(d), rtol=TOL[dtype])


# ---------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("S", [16, 64, 130])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_sweep(S, dtype):
    B, H, D = 2, 3, 16
    ks = _keys(5)
    r = (jax.random.normal(ks[0], (B, S, H, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, H, D)) * 0.5).astype(dtype)
    wl = -jnp.exp(jax.random.normal(ks[3], (B, S, H, D))).astype(dtype)
    u = jax.random.normal(ks[4], (H, D))
    y1, s1 = wkv6(r, k, v, wl, u)
    y2, s2 = wkv6_ref(r, k, v, wl, u)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=tol)


# -------------------------------------------------- property: random shapes
@pytest.mark.slow
@settings(deadline=None, max_examples=15)
@given(S=st.integers(8, 96), D=st.sampled_from([8, 16, 32]),
       H=st.integers(1, 4))
def test_flash_attention_property(S, D, H):
    ks = _keys(3)
    q = jax.random.normal(ks[0], (1, S, H, D))
    k = jax.random.normal(ks[1], (1, S, H, D))
    v = jax.random.normal(ks[2], (1, S, H, D))
    out = flash_attention(q, k, v, causal=True)
    qf = q.transpose(0, 2, 1, 3).reshape(H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(H, S, D)
    ref = flash_attention_ref(qf, kf, vf, causal=True)
    np.testing.assert_allclose(
        np.asarray(out[0].transpose(1, 0, 2)), np.asarray(ref), atol=3e-5)


@settings(deadline=None, max_examples=15)
@given(rows=st.integers(1, 300), d=st.sampled_from([32, 128, 384]))
def test_rmsnorm_property(rows, d):
    ks = _keys(2)
    x = jax.random.normal(ks[0], (rows, d))
    w = jax.random.normal(ks[1], (d,))
    np.testing.assert_allclose(np.asarray(rmsnorm(x, w)),
                               np.asarray(rmsnorm_ref(x, w)), atol=2e-5)


# ------------------------------------------------ chunked == naive (fp32)
@pytest.mark.parametrize("S,chunk", [(64, 16), (200, 64), (96, 1024)])
def test_sdpa_flash_matches_naive(S, chunk):
    from repro.models.attention import sdpa, sdpa_flash, make_mask
    B, H, kvH, D = 2, 4, 2, 16
    ks = _keys(3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, kvH, D))
    v = jax.random.normal(ks[2], (B, S, kvH, D))
    out = sdpa_flash(q, k, v, causal=True, chunk=chunk)
    ref = sdpa(q, k, v, make_mask(S, S, causal=True))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)
    # sliding window with a traced window_eff
    we = jnp.asarray(32, jnp.int32)
    out = sdpa_flash(q, k, v, causal=True, window_eff=we, chunk=chunk)
    ref = sdpa(q, k, v, make_mask(S, S, causal=True, window=32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)
