"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward + one train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ParallelConfig, TrainConfig, get_reduced_config,
                           list_archs)
from repro.models import build_model, make_batch
from repro.models.common import init_params, pad_vocab

B, S = 2, 16


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_no_nans(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg, max_cache_len=64)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, pad_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_train_step_no_nans(arch):
    from repro.parallel.fsdp import build_train_step, init_train_state
    from repro.parallel.sharding import ShardingRules
    from repro.launch.mesh import make_host_mesh

    cfg = get_reduced_config(arch)
    model = build_model(cfg, max_cache_len=64)
    mesh = make_host_mesh()
    parallel = ParallelConfig()
    rules = ShardingRules(mesh, cfg, parallel)
    step, _ = build_train_step(model, TrainConfig(warmup_steps=1), rules,
                               parallel)
    with mesh:
        state = init_train_state(model, rules, parallel)
        batch = make_batch(cfg, B, S)
        state, metrics = step(state, batch)
        state, metrics2 = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics2["loss"])
    assert jnp.isfinite(metrics2["grad_norm"])
    # one step on the same batch should not increase loss catastrophically
    assert float(metrics2["loss"]) < float(metrics["loss"]) + 1.0
