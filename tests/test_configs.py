"""Config registry + param accounting vs published sizes."""
import pytest

from repro.configs import (SHAPES, get_config, get_reduced_config,
                           iter_cells, list_archs, shape_applicable)

PUBLISHED_B = {
    "grok-1-314b": (314, 0.08), "deepseek-moe-16b": (16.4, 0.05),
    "whisper-medium": (0.769, 0.10), "nemotron-4-15b": (15.0, 0.08),
    "qwen2.5-32b": (32.8, 0.05), "qwen3-4b": (4.0, 0.05),
    "deepseek-7b": (6.9, 0.05), "hymba-1.5b": (1.5, 0.15),
    "llama-3.2-vision-90b": (90, 0.05), "rwkv6-3b": (3.1, 0.05),
    "llama3.1-8b": (8.0, 0.05), "mistral-7b": (7.2, 0.05),
}


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_matches_published(arch):
    if arch not in PUBLISHED_B:
        pytest.skip("no published reference")
    cfg = get_config(arch)
    n = cfg.param_count() / 1e9
    ref, tol = PUBLISHED_B[arch]
    assert abs(n - ref) / ref < tol, f"{arch}: {n:.2f}B vs {ref}B"


def test_moe_active_less_than_total():
    for arch in ("grok-1-314b", "deepseek-moe-16b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_cell_grid():
    cells = list(iter_cells())
    assert len(cells) == 40                      # 10 archs x 4 shapes
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 32
    assert all(s == "long_500k" for _, s, ok in skipped)
    # long_500k runs exactly for the sub-quadratic archs
    long_ok = {a for a, s, ok in cells if s == "long_500k" and ok}
    assert long_ok == {"rwkv6-3b", "hymba-1.5b"}


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_configs_are_small(arch):
    r = get_reduced_config(arch)
    assert r.param_count() < 5e6
    assert r.family == get_config(arch).family


def test_shapes_table():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["decode_32k"].kind == "decode"


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nonexistent-1b")
