"""Serving stack: trace generation determinism, continuous batching,
SLO metrics + bit-for-bit offline replay, the tail-latency manager
objective, and the jax `ServingLoop` shape paths.

The load-bearing properties:

  * request traces are *prefix-stable*: request k depends only on
    ``[seed, k]`` child seeding, so growing the horizon or request cap
    never changes earlier requests, and the trace is byte-identical
    across simulator engines (it never touches the sim RNG streams);
  * SLO summaries replayed from a saved JSONL trace match the live run
    bit-for-bit (shortest-repr doubles, NaN round-trips as null);
  * on the pinned serve/straggler-slo seed, the ``tail-latency``
    objective strictly beats ``throughput`` on fleet p99 TTFT.
"""
import math

import numpy as np
import pytest

from repro.api import get_scenario, run_scenario, with_overrides
from repro.api.spec import Scenario, ServeSpec
from repro.serve import (ContinuousBatcher, RequestTrace, SLO_METRICS,
                         generate_requests, replay_slo, slo_replay_matches,
                         slo_summary)
from repro.serve.traffic import _diurnal_rate
from repro.telemetry.collector import RequestRecord
from repro.telemetry.trace_io import load_trace

NAN = float("nan")


# --------------------------------------------------------------------------- #
# traffic: [seed, k] child seeding
# --------------------------------------------------------------------------- #
def _as_tuples(trace: RequestTrace):
    return [(r.rid, r.t_arrival, r.prompt_len, r.output_len)
            for r in trace.requests]


@pytest.mark.parametrize("process", ["poisson", "diurnal"])
def test_trace_prefix_stable_under_growth(process):
    """Growing horizon or max_requests must not perturb earlier requests:
    request k draws from rng([seed, k]), never from a shared stream."""
    base = ServeSpec(process=process, rate_rps=20.0, horizon_s=5.0,
                     max_requests=64)
    short = generate_requests(base, seed=7)
    for grown in (ServeSpec(process=process, rate_rps=20.0, horizon_s=50.0,
                            max_requests=64),
                  ServeSpec(process=process, rate_rps=20.0, horizon_s=5.0,
                            max_requests=4096)):
        long = generate_requests(grown, seed=7)
        assert len(long) >= len(short)
        assert _as_tuples(long)[:len(short)] == _as_tuples(short)


def test_trace_seed_and_spec_sensitivity():
    sv = ServeSpec(rate_rps=20.0, horizon_s=5.0)
    a, b = generate_requests(sv, seed=1), generate_requests(sv, seed=2)
    assert _as_tuples(a) != _as_tuples(b)
    assert _as_tuples(a) == _as_tuples(generate_requests(sv, seed=1))


def test_trace_engine_independent():
    """The trace is pure numpy over the serve spec: two scenario builds
    differing only in simulator engine carry byte-identical traces."""
    from repro.api import build_scenario
    sc = get_scenario("serve/poisson")
    sc2 = with_overrides(sc, {"sim.engine": "event"})
    ta = build_scenario(sc).serving.trace
    tb = build_scenario(sc2).serving.trace
    assert _as_tuples(ta) == _as_tuples(tb)


def test_trace_shapes_and_bounds():
    sv = ServeSpec(rate_rps=50.0, horizon_s=4.0, prompt_max=1024,
                   output_max=128)
    tr = generate_requests(sv, seed=3)
    assert len(tr) > 50
    ts = [r.t_arrival for r in tr.requests]
    assert all(t2 >= t1 for t1, t2 in zip(ts, ts[1:]))
    assert all(t <= sv.horizon_s for t in ts)
    assert all(1 <= r.prompt_len <= sv.prompt_max for r in tr.requests)
    assert all(1 <= r.output_len <= sv.output_max for r in tr.requests)
    assert [r.rid for r in tr.requests] == list(range(len(tr)))
    assert tr.total_prompt_tokens == sum(r.prompt_len for r in tr.requests)


def test_users_m_arrival_rate():
    """The millions-of-users knob: users_m * req/day / 86400 wins over
    rate_rps when set."""
    sv = ServeSpec(users_m=8.64, user_req_per_day=10.0, rate_rps=999.0)
    assert sv.arrival_rate() == pytest.approx(1000.0)
    assert ServeSpec(rate_rps=7.0).arrival_rate() == 7.0


def test_diurnal_rate_modulation():
    assert _diurnal_rate(10.0, 0.5, 40.0, 10.0) == pytest.approx(15.0)
    assert _diurnal_rate(10.0, 0.5, 40.0, 30.0) == pytest.approx(5.0)
    lam = [_diurnal_rate(10.0, 0.9, 60.0, t) for t in np.linspace(0, 60, 50)]
    assert min(lam) > 0


# --------------------------------------------------------------------------- #
# continuous batcher
# --------------------------------------------------------------------------- #
def _req(rid, t=0.0, prompt=512, out=4):
    from repro.serve.traffic import Request
    return Request(rid=rid, t_arrival=t, prompt_len=prompt, output_len=out)


def test_batcher_prefill_then_decode_timeline():
    b = ContinuousBatcher(slots=1, prefill_chunk=512)
    b.enqueue(_req(0, t=0.0, prompt=1000, out=3))
    assert b.admit(now=1.0) == 1
    assert b.step(2.0) == []          # prefill chunk 1 of 2
    assert b.step(3.0) == []          # final chunk -> first token @3.0
    assert b.first_token_events == [(3.0, 3.0)]
    assert b.step(4.0) == []          # token 2
    done = b.step(5.0)                # token 3 -> complete, slot freed
    assert len(done) == 1 and b.n_active == 0
    rec = done[0]
    assert (rec.t_admit, rec.t_first, rec.t_done) == (1.0, 3.0, 5.0)
    assert rec.ttft == pytest.approx(3.0)
    assert rec.tokens_out == 3 and rec.complete
    assert rec.tpot == pytest.approx((5.0 - 3.0) / 2)


def test_batcher_slot_recycling_and_queue():
    b = ContinuousBatcher(slots=2, prefill_chunk=512)
    for i in range(4):
        b.enqueue(_req(i, t=0.0, prompt=100, out=1))
    assert b.admit(0.0) == 2 and b.n_queued == 2
    done = b.step(1.0)                # prefill+first token completes out=1
    assert [r.rid for r in done] == [0, 1]
    assert b.admit(1.0) == 2 and b.n_queued == 0
    assert [r.rid for r in b.step(2.0)] == [2, 3]


def test_batcher_oldest_unserved_age():
    b = ContinuousBatcher(slots=1, prefill_chunk=8)
    assert b.oldest_unserved_age(5.0) == 0.0
    b.enqueue(_req(0, t=1.0, prompt=64, out=2))
    b.enqueue(_req(1, t=2.0, prompt=8, out=2))
    b.admit(3.0)
    # rid 0 admitted but mid-prefill, rid 1 queued: oldest is rid 0
    assert b.oldest_unserved_age(10.0) == pytest.approx(9.0)
    for t in range(8):
        b.step(4.0 + t)               # rid 0 first token arrives
    assert b.oldest_unserved_age(12.0) == pytest.approx(10.0)  # now rid 1


def test_batcher_flush_incomplete_records():
    b = ContinuousBatcher(slots=1, prefill_chunk=512, node=3)
    b.enqueue(_req(0, t=0.0, prompt=10, out=100))
    b.enqueue(_req(1, t=0.5, prompt=10, out=100))
    b.admit(1.0)
    b.step(2.0)
    out = b.flush()
    assert [r.rid for r in out] == [0, 1]
    assert out[0].t_first == 2.0 and math.isnan(out[0].t_done)
    assert math.isnan(out[1].t_admit) and math.isnan(out[1].t_first)
    assert all(r.node == 3 and not r.complete for r in out)
    assert b.n_active == 0 and b.n_queued == 0


def test_batcher_validates_config():
    with pytest.raises(ValueError, match="batch_slots"):
        ContinuousBatcher(slots=0, prefill_chunk=1)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ContinuousBatcher(slots=1, prefill_chunk=0)


# --------------------------------------------------------------------------- #
# SLO metrics
# --------------------------------------------------------------------------- #
def _rec(rid, node=0, arr=0.0, admit=0.0, first=1.0, done=2.0, out=2):
    return RequestRecord(rid=rid, node=node, t_arrival=arr, t_admit=admit,
                         t_first=first, t_done=done, prompt_len=8,
                         output_len=out, tokens_out=out)


def test_slo_summary_hand_computed():
    recs = [_rec(0, first=1.0, done=2.0),            # ttft 1, within SLOs
            _rec(1, first=3.0, done=4.0),            # ttft 3, misses TTFT
            _rec(2, first=1.0, done=NAN),            # never completed
            RequestRecord(rid=3, node=1, t_arrival=0.0, t_admit=NAN,
                          t_first=NAN, t_done=NAN, prompt_len=8,
                          output_len=2, tokens_out=0)]
    s = slo_summary(recs, ttft_deadline_s=2.0, tpot_deadline_s=1.5,
                    t_elapsed_s=10.0, n_nodes=2)
    assert s["offered"] == 4.0 and s["completed"] == 2.0
    assert s["first_tokens"] == 3.0
    assert s["ttft_p50"] == pytest.approx(1.0)
    assert s["goodput_rps"] == pytest.approx(0.1)    # only rid 0 in SLO
    assert s["slo_attainment"] == pytest.approx(0.25)
    assert s["tokens_per_s"] == pytest.approx(0.6)
    assert s["ttft_p99_node1"] == -1.0               # no first tokens there
    assert s["ttft_p99_node_max"] == s["ttft_p99_node0"]
    for k in SLO_METRICS:
        assert s[k] == s[k], f"{k} is NaN"


def test_slo_summary_empty_population_sentinels():
    s = slo_summary([], ttft_deadline_s=1.0, tpot_deadline_s=1.0,
                    t_elapsed_s=0.0, n_nodes=2)
    assert s["ttft_p99"] == -1.0 and s["slo_attainment"] == -1.0
    assert s["goodput_rps"] == 0.0
    assert not any(v != v for v in s.values())


# --------------------------------------------------------------------------- #
# end-to-end scenarios
# --------------------------------------------------------------------------- #
def test_serve_poisson_reports_tail_spread():
    res = run_scenario(get_scenario("serve/poisson"))
    m = res.metrics
    assert m["offered"] > 100 and m["completed"] > 100
    assert m["ttft_p99"] > m["ttft_p50"] > 0
    assert m["ttft_p99_node_spread"] > 0
    assert not any(v != v for v in m.values())


def test_serve_replay_matches_live_bit_for_bit(tmp_path):
    path = str(tmp_path / "serve.jsonl")
    sc = get_scenario("serve/poisson")
    res = run_scenario(sc, iterations=150, save_trace_path=path)
    trace = load_trace(path)
    assert len(trace.requests) == int(res.metrics["offered"])
    replayed = replay_slo(trace)
    live = res.serve.summary
    assert slo_replay_matches(live, replayed, log=print)
    # and not vacuously: the exact comparator must catch a perturbation
    replayed["ttft_p99"] += 1e-12
    assert not slo_replay_matches(live, replayed)


def test_replay_requires_serve_meta(tmp_path):
    class _Empty:
        meta = {}
        requests = []
    with pytest.raises(ValueError, match="serve"):
        replay_slo(_Empty())


@pytest.mark.slow
def test_tail_latency_objective_beats_throughput_on_pinned_seed():
    """The CI gate's property: same trace, same budget, same seed — the
    tail-latency objective must strictly reduce fleet p99 TTFT vs the
    paper's throughput (speed-equalizing) objective."""
    tail = run_scenario(get_scenario("serve/straggler-slo"))
    tp = run_scenario(with_overrides(
        get_scenario("serve/straggler-slo"),
        {"manager.config.objective": "throughput"}))
    p_tail = tail.metrics["ttft_p99"]
    p_tp = tp.metrics["ttft_p99"]
    assert 0 < p_tail < p_tp
    assert tail.metrics["node0_budget_w"] != tp.metrics["node0_budget_w"]


def test_serve_spec_validation():
    with pytest.raises(ValueError, match="process"):
        Scenario(name="x", serve=ServeSpec(process="bursty"),
                 fleet=_fleet()).validate()
    with pytest.raises(ValueError, match="rate"):
        Scenario(name="x", serve=ServeSpec(rate_rps=0.0),
                 fleet=_fleet()).validate()
    with pytest.raises(ValueError):    # serve requires a fleet
        Scenario(name="x", serve=ServeSpec()).validate()


def _fleet():
    from repro.core.cluster import ClusterConfig
    return ClusterConfig(n_nodes=2)


def test_tail_objective_requires_serve():
    from repro.core.manager import FleetManagerConfig
    from repro.api.spec import ManagerSpec
    sc = Scenario(name="x", fleet=_fleet(),
                  manager=ManagerSpec(scope="fleet", config=FleetManagerConfig(
                      use_case="gpu-realloc", objective="tail-latency")))
    with pytest.raises(ValueError, match="tail-latency"):
        sc.validate()


# --------------------------------------------------------------------------- #
# jax ServingLoop shape paths
# --------------------------------------------------------------------------- #
jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


class _ToyLM:
    """Duck-typed model: next token = (running token sum) % V, computed
    row-independently so padding rows cannot contaminate real rows."""

    V = 13

    def prefill(self, params, batch):
        cache = jnp.sum(batch["tokens"], axis=1, keepdims=True)  # (B, 1)
        return self._logits(cache), cache

    def decode_step(self, params, tok, cache):
        cache = cache + tok
        return self._logits(cache), cache

    def _logits(self, cache):
        return jax.nn.one_hot(cache % self.V, self.V)            # (B, 1, V)


def _expected(prompt, steps):
    out, acc = [], int(np.sum(prompt))
    for _ in range(steps):
        tok = acc % _ToyLM.V
        out.append(tok)
        acc += tok
    return out


def test_serving_loop_pads_and_unpads():
    from repro.serve import ServeConfig, ServingLoop
    loop = ServingLoop(_ToyLM(), {}, batch_size=4, prompt_len=3,
                       cfg=ServeConfig(max_new_tokens=5))
    prompts = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    toks = loop.serve(prompts)
    assert toks.shape == (2, 5)
    for row, prompt in zip(toks, prompts):
        assert list(row) == _expected(prompt, 5)


def test_serving_loop_reused_buffer_is_rezeroed():
    """A full batch followed by a smaller one: stale rows in the reused
    pad buffer must not leak into the smaller call's results."""
    from repro.serve import ServeConfig, ServingLoop
    loop = ServingLoop(_ToyLM(), {}, batch_size=3, prompt_len=2,
                       cfg=ServeConfig(max_new_tokens=4))
    full = np.array([[9, 9], [7, 7], [5, 5]], np.int32)
    loop.serve(full)
    small = loop.serve(np.array([[2, 2]], np.int32))
    assert small.shape == (1, 4)
    assert list(small[0]) == _expected([2, 2], 4)
    assert np.all(loop._pad_buf[1:] == 0)


def test_serving_loop_rejects_over_batch_and_ragged():
    from repro.serve import ServingLoop
    loop = ServingLoop(_ToyLM(), {}, batch_size=2, prompt_len=4)
    with pytest.raises(ValueError, match=r"exceeds batch_size=2"):
        loop.serve(np.zeros((3, 4), np.int32))
    with pytest.raises(ValueError, match=r"\(n, 4\)"):
        loop.serve(np.zeros((1, 5), np.int32))
    with pytest.raises(ValueError, match="shape"):
        loop.serve(np.zeros((4,), np.int32))
