"""prefill + decode_step must agree with the full forward pass (teacher
forcing) for every model family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.models.attention import set_attention_impl
from repro.models import build_model, make_batch
from repro.models.common import init_params

FAMS = ["qwen3-4b", "deepseek-7b", "hymba-1.5b", "rwkv6-3b",
        "whisper-medium", "llama-3.2-vision-90b", "mistral-7b",
        "grok-1-314b", "deepseek-moe-16b", "nemotron-4-15b"]


def _nodrop(cfg):
    if cfg.moe is not None:
        # capacity token-dropping is seq-length dependent; disable for the
        # exactness check (the dropping path is tested in test_moe.py)
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=8.0))
    return cfg


@pytest.fixture(autouse=True)
def _same_attention_path():
    """forward uses the chunked path, decode the naive one; pin both to
    'xla' so this test checks cache algebra, not softmax summation order
    (chunked==naive equivalence is covered in test_kernels.py)."""
    set_attention_impl("xla")
    yield
    set_attention_impl("chunked")


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMS)
def test_prefill_decode_matches_forward(arch):
    cfg = _nodrop(get_reduced_config(arch))
    model = build_model(cfg, max_cache_len=24)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    logits_full, _ = jax.jit(model.forward)(params, batch)
    pre = {k: (v[:, :12] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    lg, cache = jax.jit(model.prefill)(params, pre)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, 11])))]
    step = jax.jit(model.decode_step)
    for t in range(12, 15):
        lg, cache = step(params, batch["tokens"][:, t:t + 1], cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t]))))
    assert max(errs) < 2e-2, errs


def test_sliding_window_ring_cache():
    """mistral-style ring buffer: decode far past the window stays finite
    and ignores evicted positions."""
    cfg = get_reduced_config("mistral-7b").replace(window=8)
    model = build_model(cfg, max_cache_len=48)       # > window -> ring
    assert model.cache_window == 8
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, 1, 32)
    lg, cache = jax.jit(model.prefill)(params, batch)
    step = jax.jit(model.decode_step)
    for t in range(8):
        lg, cache = step(params, jnp.full((1, 1), 7, jnp.int32), cache)
        assert bool(jnp.isfinite(lg).all())
    assert int(cache["pos"]) == 40
