"""Property tests (hypothesis) for the paper's Algorithms 1-3."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.detect import (aggregate_lead, classify_overlap,
                               lead_value_detect, lead_values,
                               straggler_index)
from repro.core.mitigate import adj_power_node, inc_power_gpu

starts = st.integers(2, 8).flatmap(
    lambda g: st.integers(3, 30).flatmap(
        lambda k: st.lists(
            st.lists(st.floats(0, 1e3, allow_nan=False), min_size=k,
                     max_size=k), min_size=g, max_size=g)))


# ----------------------------------------------------------- Algorithm 1
@settings(deadline=None, max_examples=60)
@given(starts)
def test_lead_values_properties(t):
    t = np.asarray(t)
    lead = lead_values(t)
    assert (lead >= 0).all()
    # per kernel, the latest starter has zero lead
    assert np.allclose(lead.min(axis=0), 0.0)
    # translation invariance: shifting all clocks changes nothing
    lead2 = lead_values(t + 123.4)
    assert np.allclose(lead, lead2)


@settings(deadline=None, max_examples=40)
@given(starts, st.sampled_from(["sum", "max", "last"]))
def test_aggregate_modes(t, mode):
    t = np.asarray(t)
    agg = lead_value_detect(t, mode)
    assert agg.shape == (t.shape[0],)
    assert (agg >= 0).all()


def test_straggler_is_latest_starter():
    rngs = np.random.default_rng(0)
    for _ in range(20):
        g, k = 8, 50
        base = np.cumsum(rngs.random(k))[None, :]
        offsets = rngs.random(g)[:, None] * 0.1
        s = int(rngs.integers(g))
        offsets[s] += 5.0                      # one device always late
        t = base + offsets
        assert straggler_index(t) == s
        # straggler has (near) zero aggregate lead
        assert lead_value_detect(t)[s] == pytest.approx(0.0)


def test_aggregate_lead_max_mode():
    lead = np.array([[1.0, 5.0, 2.0], [0.0, 0.0, 7.0]])
    np.testing.assert_array_equal(aggregate_lead(lead, "max"), [5.0, 7.0])


def test_aggregate_lead_last_mode():
    lead = np.array([[1.0, 5.0, 2.0], [0.0, 0.0, 7.0]])
    np.testing.assert_array_equal(aggregate_lead(lead, "last"), [2.0, 7.0])


def test_aggregate_lead_unknown_mode_raises():
    with pytest.raises(ValueError, match="unknown aggregation"):
        aggregate_lead(np.zeros((2, 3)), "median")


def test_all_nan_kernel_column_zero_lead_without_warning():
    """A kernel no device reported (sensor dropout / never-ran) must not
    poison the aggregate or emit an all-NaN-slice warning."""
    t = np.array([[0.0, np.nan, 2.0], [1.0, np.nan, 2.5]])
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        lead = lead_values(t)
    np.testing.assert_array_equal(lead[:, 1], [0.0, 0.0])
    np.testing.assert_allclose(lead[:, 0], [1.0, 0.0])
    # finite columns unchanged by the NaN column
    np.testing.assert_allclose(lead_value_detect(t), [1.5, 0.0])


def test_dropped_device_row_gets_zero_lead():
    t = np.array([[np.nan, np.nan], [0.0, 1.0], [0.2, 1.3]])
    lead = lead_values(t)
    np.testing.assert_array_equal(lead[0], [0.0, 0.0])
    # zero lead ties the dropped device with the true straggler (device 2)
    # and argmin names the dropped one — why dropout corrupts detection
    assert straggler_index(t) == 0


def test_single_device_trace_zero_lead():
    t = np.array([[0.0, 1.0, 2.0]])
    np.testing.assert_array_equal(lead_values(t), [[0.0, 0.0, 0.0]])
    for mode in ("sum", "max", "last"):
        np.testing.assert_array_equal(lead_value_detect(t, mode), [0.0])
    assert straggler_index(t) == 0


def test_classify_overlap():
    o = np.array([[0.0, 0.5, 1.0], [0.0, 0.1, 1.0]])
    const = classify_overlap(o, tol=0.15)
    assert const.tolist() == [True, False, True]


# ----------------------------------------------------------- Algorithm 2
leads = st.integers(2, 16).flatmap(
    lambda g: st.lists(st.floats(0, 1e4, allow_nan=False), min_size=g,
                       max_size=g))


@settings(deadline=None, max_examples=60)
@given(leads, st.floats(1, 50), st.sampled_from(["global", "local"]))
def test_inc_power_bounds(lead, max_inc, scale):
    lead = np.asarray(lead)
    inc, gmax = inc_power_gpu(lead, max_inc, 0.0, scale)
    assert (inc >= -1e-9).all() and (inc <= max_inc + 1e-9).all()
    assert gmax >= lead.max()
    if lead.max() > lead.min():
        # the straggler (min lead) gets the largest increase
        assert inc[np.argmin(lead)] == pytest.approx(inc.max())
        # the biggest leader gets (near) zero
        assert inc[np.argmax(lead)] == pytest.approx(0.0, abs=1e-9)


def test_inc_power_global_damping():
    lead = np.array([100.0, 50.0, 0.0])
    inc1, gmax = inc_power_gpu(lead, 15.0, 0.0, "global")
    # later, leads have shrunk: increments shrink proportionally
    inc2, gmax = inc_power_gpu(lead / 10, 15.0, gmax, "global")
    assert inc2.max() <= inc1.max() / 5


# ----------------------------------------------------------- Algorithm 3
caps_st = st.integers(2, 16).flatmap(
    lambda g: st.tuples(
        st.lists(st.floats(0, 15, allow_nan=False), min_size=g, max_size=g),
        st.lists(st.floats(300, 750, allow_nan=False), min_size=g,
                 max_size=g)))


@settings(deadline=None, max_examples=60)
@given(caps_st, st.floats(600, 800))
def test_adj_power_node_invariants(caps_inc, tdp):
    inc, caps = (np.asarray(x) for x in caps_inc)
    caps = np.minimum(caps, tdp)
    g = caps.shape[0]
    node_cap = float(caps.sum())               # realloc-style binding cap
    out = adj_power_node(inc, caps, tdp, node_cap)
    assert (out <= tdp + 1e-6).all()           # TDP never exceeded
    assert out.sum() <= node_cap + 1e-6        # node cap respected
    # uniform-shift property: relative differences set only by inc
    d = (caps + inc) - out
    assert np.allclose(d, d[0])


def test_adj_power_paper_walkthrough():
    """Paper §V-C worked example: 8 GPUs, straggler +15W."""
    tdp = 750.0
    inc = np.array([0, 0, 0, 0, 0, 15.0, 0, 0])
    # GPU-Red: all at TDP, node cap = provisioned max
    out = adj_power_node(inc, np.full(8, tdp), tdp, 8 * tdp)
    assert out[5] == pytest.approx(tdp)        # straggler stays at TDP
    assert np.allclose(out[:5], tdp - 15)      # leaders lowered by 15
    # GPU-Realloc: caps 15W below TDP, node cap binding
    caps = np.full(8, tdp - 15)
    out = adj_power_node(inc, caps, tdp, 8 * (tdp - 15))
    assert out[5] == pytest.approx(tdp - 2)    # +15 then uniform -ceil(15/8)
    assert np.allclose(out[:5], tdp - 17)
    # CPU-Slosh: 2W/GPU budget -> 16W headroom, no leader reduction
    out = adj_power_node(inc, caps, tdp, 8 * (tdp - 15) + 16)
    assert out[5] == pytest.approx(tdp)
    assert np.allclose(out[:5], tdp - 15)
