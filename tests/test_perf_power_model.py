"""§IV analytical models: Insight-5 identity + power-model invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.perf_model import predict_speedup, t_agg
from repro.core.power_model import predict_power, rank_runtimes

dur_st = st.tuples(st.integers(2, 8), st.integers(4, 20)).flatmap(
    lambda gk: st.lists(
        st.lists(st.floats(0.1, 10, allow_nan=False), min_size=gk[1],
                 max_size=gk[1]), min_size=gk[0], max_size=gk[0]))


@settings(deadline=None, max_examples=50)
@given(dur_st, st.sampled_from(["max", "med", "min"]))
def test_insight5_s_iter_equals_s_c(dur, agg):
    dur = np.asarray(dur)
    overlap = np.zeros_like(dur)               # all constant-overlap
    pred = predict_speedup(dur, overlap, agg=agg)
    assert pred.s_iter == pytest.approx(pred.s_c, rel=1e-9)
    assert pred.s_c >= 1.0 - 1e-9              # aligning never slows past max


@settings(deadline=None, max_examples=50)
@given(dur_st)
def test_speedup_ordering(dur):
    """Aligning to min >= med >= max speedup (diminishing from Red->Slosh)."""
    dur = np.asarray(dur)
    overlap = np.zeros_like(dur)
    s = {agg: predict_speedup(dur, overlap, agg=agg).s_iter
         for agg in ("max", "med", "min")}
    assert s["min"] >= s["med"] >= s["max"] >= 1.0 - 1e-9


def test_varying_overlap_kernels_cap_speedup():
    """V-kernels are already fastest on the straggler -> Amdahl dampens."""
    G, K = 4, 10
    rng = np.random.default_rng(0)
    dur = 1.0 + rng.random((G, K))
    overlap = np.zeros((G, K))
    overlap[:, :5] = rng.random((G, 5))        # half the kernels vary
    pred = predict_speedup(dur, overlap, agg="min", tol=0.05)
    pred_all_c = predict_speedup(dur, np.zeros_like(dur), agg="min")
    assert pred.r_v > 0
    assert pred.s_iter == pytest.approx(pred.s_c)


# ------------------------------------------------------------- power model
@settings(deadline=None, max_examples=50)
@given(dur_st, st.floats(400, 750), st.floats(50, 200))
def test_power_model_invariants(dur, p_base, p_idle):
    dur = np.asarray(dur)
    overlap = np.zeros_like(dur)
    # align to max (GPU-Red-like): runtimes can only grow -> power drops
    pred = predict_power(dur, overlap, p_base, p_idle, agg="max")
    assert pred.p_sys_new <= pred.p_sys + 1e-6
    # align to min (Slosh-like): power grows
    pred2 = predict_power(dur, overlap, p_base, p_idle, agg="min")
    assert pred2.p_sys_new >= pred.p_sys_new - 1e-6


def test_rank_runtimes_sorted():
    dur = np.array([[3.0, 1.0], [1.0, 3.0], [2.0, 2.0]])
    r = rank_runtimes(dur)
    assert (np.diff(r) >= 0).all()
    assert r.sum() == pytest.approx(dur.sum())


def test_identical_devices_no_change():
    dur = np.ones((4, 6))
    pred = predict_power(dur, np.zeros_like(dur), 700.0, 140.0, agg="med")
    assert pred.ratio == pytest.approx(1.0)
    sp = predict_speedup(dur, np.zeros_like(dur), agg="med")
    assert sp.s_iter == pytest.approx(1.0)
