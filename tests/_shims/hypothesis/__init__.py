"""Minimal stand-in for `hypothesis` used when the real package is absent.

The container this repo grows in cannot install new packages, but four test
modules are property tests written against the hypothesis API.  This shim
implements the small subset they use — `given`, `settings`, and the
`strategies` combinators (integers, floats, lists, tuples, sampled_from,
flatmap, map, filter) — by drawing pseudo-random examples from a seeded
numpy generator, with light boundary biasing so min/max edges get exercised.

It is *not* hypothesis: no shrinking, no database, no health checks.  When
the real package is installed (see pyproject's `test` extra — CI does this),
`tests/conftest.py` never puts this shim on `sys.path` and the genuine
implementation is used instead.
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, List, Optional

import numpy as np

__version__ = "0.0.0-shim"
_DEFAULT_MAX_EXAMPLES = 30


class SearchStrategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example_with(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def flatmap(self, f: Callable[[Any], "SearchStrategy"]):
        return SearchStrategy(lambda r: f(self._draw(r))._draw(r))

    def map(self, f: Callable[[Any], Any]):
        return SearchStrategy(lambda r: f(self._draw(r)))

    def filter(self, pred: Callable[[Any], bool]):
        def draw(r):
            for _ in range(1000):
                x = self._draw(r)
                if pred(x):
                    return x
            raise RuntimeError("hypothesis-shim: filter rejected 1000 draws")
        return SearchStrategy(draw)


class _Strategies:
    """Namespace mirroring `hypothesis.strategies`."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        def draw(r):
            if r.random() < 0.15:                 # bias toward the edges
                return int(r.choice([min_value, max_value]))
            return int(r.integers(min_value, max_value + 1))
        return SearchStrategy(draw)

    @staticmethod
    def floats(min_value: float, max_value: float, *, allow_nan: bool = True,
               allow_infinity: bool = True, width: int = 64) -> SearchStrategy:
        def draw(r):
            u = r.random()
            if u < 0.08:
                return float(min_value)
            if u < 0.16:
                return float(max_value)
            return float(min_value + (max_value - min_value) * r.random())
        return SearchStrategy(draw)

    @staticmethod
    def lists(elements: SearchStrategy, *, min_size: int = 0,
              max_size: Optional[int] = None, unique: bool = False
              ) -> SearchStrategy:
        def draw(r):
            hi = max_size if max_size is not None else min_size + 8
            n = int(r.integers(min_size, hi + 1))
            out: List[Any] = []
            seen = set()
            tries = 0
            while len(out) < n and tries < 1000:
                x = elements._draw(r)
                tries += 1
                if unique:
                    key = x
                    if key in seen:
                        continue
                    seen.add(key)
                out.append(x)
            return out
        return SearchStrategy(draw)

    @staticmethod
    def tuples(*strats: SearchStrategy) -> SearchStrategy:
        return SearchStrategy(lambda r: tuple(s._draw(r) for s in strats))

    @staticmethod
    def sampled_from(seq) -> SearchStrategy:
        seq = list(seq)
        return SearchStrategy(lambda r: seq[int(r.integers(len(seq)))])

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda r: bool(r.integers(2)))

    @staticmethod
    def just(value) -> SearchStrategy:
        return SearchStrategy(lambda r: value)

    @staticmethod
    def one_of(*strats: SearchStrategy) -> SearchStrategy:
        strats = tuple(strats)
        return SearchStrategy(
            lambda r: strats[int(r.integers(len(strats)))]._draw(r))


strategies = _Strategies()


def settings(deadline=None, max_examples: int = _DEFAULT_MAX_EXAMPLES,
             **_ignored):
    """Decorator: records knobs for a @given-wrapped test (outer position)."""
    def deco(fn):
        setattr(fn, "_shim_max_examples", max_examples)
        return fn
    return deco


def given(*arg_strats: SearchStrategy, **kw_strats: SearchStrategy):
    """Decorator: run the test repeatedly with drawn examples.

    Deterministic per test name, so failures reproduce run to run.
    """
    def deco(fn):
        def runner():
            n = getattr(runner, "_shim_max_examples", None)
            if n is None:
                n = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                args = [s._draw(rng) for s in arg_strats]
                kwargs = {k: s._draw(rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, **kwargs)
                except _UnsatisfiedAssumption:
                    continue
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco


def assume(condition: bool) -> None:
    """Best-effort `assume`: a failed assumption just skips the example by
    raising nothing — callers in this repo don't use it, provided for API
    compatibility."""
    if not condition:
        raise _UnsatisfiedAssumption()


class _UnsatisfiedAssumption(Exception):
    pass


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    all = staticmethod(lambda: [])
