"""Topology-aware fleet lead estimation from observed iteration times.

What a real fleet manager has is the per-node iteration times through a
(possibly lossy, possibly dead) sensor — what it wants is each node's
*lead*: how much slack the node has before it becomes the fleet's
critical path.  The true lead depends on the parallelism topology
(``core/topology.py``), which the manager does not get to re-run; this
module is the observer-side counterpart, estimating the lead from
``t_obs`` plus the small static parameter block the collector records at
attach time (``meta["topology_params"]``).

  dp / serve   barrier:  lead = max(t) - t over the finite readings.
               Exact for a lossless sensor (bit-for-bit the topology's
               own arithmetic) — the original estimator, unchanged.

  pp           the bubble structure is deterministic given the stage
               times, so the estimator mirrors the 1F1B arithmetic
               exactly:  t_fleet = sum(t/M) + (M-1)*max(t/M) + comm,
               lead = t_fleet - t.  With a lossless sensor the estimate
               is bit-identical to the recorded true lead — the PP model
               bias of the plain barrier estimator goes to zero.

  tp           the per-sync jitter draws are private to the simulator,
               so exactness is impossible; the estimator corrects the
               barrier estimate's structural bias instead.  Under
               per-segment jitter the sum of per-segment maxima exceeds
               the max of sums: nodes whose totals tie near the top keep
               exchanging the per-segment lead, and everyone — including
               the apparent slowest — waits.  The correction inflates
               the rendezvous point by ``max(t) * jitter * E[max of n
               standard normals]`` with ``n`` the count of nodes within
               the jitter band of the top; a lone straggler (n = 1)
               leaves the barrier estimate untouched.

Old traces carry no ``topology_params``; every estimator degrades to the
barrier form, so replay of existing artifacts is unchanged.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

# E[max of n iid standard normals]; the sqrt(2 ln n) asymptote is used
# past the tabulated range (Blom's approximation drifts there anyway)
_EXP_MAX_STD_NORMAL = {2: 0.5642, 3: 0.8463, 4: 1.0294, 5: 1.1630,
                       6: 1.2672, 7: 1.3522, 8: 1.4236}


def _expected_max_std_normal(n: int) -> float:
    if n <= 1:
        return 0.0
    if n in _EXP_MAX_STD_NORMAL:
        return _EXP_MAX_STD_NORMAL[n]
    return math.sqrt(2.0 * math.log(n))


def topology_params(topo) -> Dict[str, object]:
    """The static parameter block the lead estimator needs, extracted from
    a ``core.topology.Topology`` at attach time (duck-typed: any object
    with a ``name`` and the matching attributes works).  Everything in the
    block is a JSON scalar so it survives the trace meta round trip
    exactly."""
    params: Dict[str, object] = {"kind": str(topo.name)}
    if getattr(topo, "M", None) is not None and topo.name == "pp":
        params["microbatches"] = int(topo.M)
        params["comm_time"] = float(topo.comm_time())
    if topo.name == "tp":
        params["n_syncs"] = int(topo.K)
        params["jitter"] = float(topo.jitter)
        params["comm_time"] = float(topo.comm_time())
    return params


def estimate_fleet_lead(t_obs: np.ndarray, topology: str = "dp",
                        params: Optional[Dict] = None) -> np.ndarray:
    """Per-node lead estimate from observed iteration times.

    ``t_obs`` may carry NaN where a sensor is dead; estimates are computed
    over the nodes still reporting and NaN propagates to the blind slots.
    ``params`` is the collector's ``meta["topology_params"]`` block (or
    None for legacy traces — barrier fallback).
    """
    t_obs = np.asarray(t_obs, float)
    finite = np.isfinite(t_obs)
    if not finite.any():
        return np.full_like(t_obs, np.nan)
    p = params if params and params.get("kind") == topology else None

    if topology == "pp" and p is not None and "microbatches" in p:
        m = int(p["microbatches"])
        comm = float(p.get("comm_time", 0.0))
        tau = t_obs[finite] / m
        t_fleet = float(tau.sum() + (m - 1) * tau.max()) + comm
        return t_fleet - t_obs

    if topology == "tp" and p is not None and float(p.get("jitter", 0.0)) > 0:
        jitter = float(p["jitter"])
        vals = t_obs[finite]
        tmax = float(np.max(vals))
        # nodes whose totals sit within ~2 sigma of the top keep trading
        # the per-segment lead; a lone straggler leaves n_tied = 1 and the
        # correction vanishes
        n_tied = int(np.sum(vals >= tmax * (1.0 - 2.0 * jitter)))
        inflation = tmax * jitter * _expected_max_std_normal(n_tied)
        return (tmax + inflation) - t_obs

    # dp / serve / unknown / legacy trace: barrier wait over the finite
    # readings (bit-for-bit the original estimator)
    return np.max(t_obs[finite]) - t_obs
