"""Telemetry subsystem: noisy sensor models, streaming trace recording,
versioned persistence (JSONL + Chrome trace), and offline replay of the
paper's detection/mitigation stack over recorded data.

The live simulators hand the manager perfect kernel-start matrices; real
deployments run Algorithms 1-3 from sampled, noisy counters.  This package
closes that gap: record any sim (node / cluster, every engine) through a
``SensorModel``, persist the trace, replay detection + mitigation offline
(bit-for-bit from a lossless trace), and measure how detection degrades as
sensor fidelity drops.
"""
from repro.telemetry.collector import (FaultRecord, FleetSample,
                                       ManagerAction, NodeSample,
                                       TelemetryCollector)
from repro.telemetry.lead import estimate_fleet_lead, topology_params
from repro.telemetry.replay import (DetectionReport, EscalationReplay,
                                    FleetLeadReport,
                                    FleetReplay, NodeReplay,
                                    ReplayCapBackend, degrade,
                                    detection_report,
                                    escalation_replay_matches,
                                    fleet_lead_report,
                                    fleet_replay_matches,
                                    replay_escalation,
                                    replay_fleet, replay_node)
from repro.telemetry.sensors import (LOSSLESS, ROCM_SMI_LIKE, SensorConfig,
                                     SensorModel)
from repro.telemetry.trace_io import (TRACE_FORMAT, TRACE_VERSION,
                                      TelemetryTrace, export_chrome_trace,
                                      load_trace, save_trace)

__all__ = [
    "SensorConfig", "SensorModel", "LOSSLESS", "ROCM_SMI_LIKE",
    "TelemetryCollector", "NodeSample", "FleetSample", "ManagerAction",
    "FaultRecord", "EscalationReplay", "replay_escalation",
    "escalation_replay_matches",
    "TelemetryTrace", "TRACE_FORMAT", "TRACE_VERSION",
    "save_trace", "load_trace", "export_chrome_trace",
    "ReplayCapBackend", "NodeReplay", "FleetReplay",
    "replay_node", "replay_fleet", "fleet_replay_matches", "degrade",
    "DetectionReport", "detection_report",
    "FleetLeadReport", "fleet_lead_report",
    "estimate_fleet_lead", "topology_params",
]
