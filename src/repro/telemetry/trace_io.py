"""Versioned JSONL trace persistence + Chrome-trace export.

Format (one JSON object per line):

  line 1   {"format": "lit-silicon-telemetry", "version": 1, "meta": {...}}
  then     {"type": "node",  "it": ..., "node": ..., "start": [[...]], ...}
           {"type": "fleet", "it": ..., "lead": [...], ...}
           {"type": "action", "it": ..., "kind": ..., "values": [...]}
           {"type": "event", "it": ..., "kind": ..., "node": ..., ...}
           {"type": "request", "rid": ..., "node": ..., "t_arrival": ...}

``event`` lines carry fault onsets and escalation decisions (FaultRecord);
``request`` lines carry per-request serving lifecycles (RequestRecord,
the ``repro.serve.replay_slo`` input).  Readers predating either skip
unknown record types, so the version stays 1.

Floats round-trip exactly (json emits the shortest repr that parses back to
the same IEEE-754 double), and NaN — not valid JSON — is encoded as null,
so a lossless recording survives save/load bit-for-bit; the offline replay
guarantee (replay.py) is tested *through* this round trip.

``export_chrome_trace`` writes the Chrome Trace Event format (load in
Perfetto / chrome://tracing): one process per node, one thread per device,
complete ("X") events per kernel, and counter ("C") tracks for power,
temperature and caps.  Unsampled iterations are elided, so the timeline is
the concatenation of sampled intervals.  A synthetic "fleet" process adds
cluster-scope counter tracks (lead, observed step time, node power, serve
tail) and instant ("i") events for fault onsets, escalation stages and
alert transitions, so one file shows physics and alerts together.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.telemetry.collector import (FaultRecord, FleetSample,
                                       ManagerAction, NodeSample,
                                       RequestRecord, TelemetryCollector)

TRACE_FORMAT = "lit-silicon-telemetry"
TRACE_VERSION = 1


def _enc(a) -> object:
    """numpy -> JSON-safe nested lists with NaN as null."""
    if a is None:
        return None
    arr = np.asarray(a, float)
    return np.where(np.isnan(arr), None, arr.astype(object)).tolist()


def _dumps(obj: dict) -> str:
    """One artifact line: key-sorted, strictly-finite JSON — equal payload
    means equal bytes, and a NaN that dodges _enc raises instead of
    emitting a bare non-JSON token."""
    return json.dumps(obj, sort_keys=True, allow_nan=False)


def _dec(x, ndmin: int = 1) -> Optional[np.ndarray]:
    """JSON nested lists (null = NaN) -> float ndarray."""
    if x is None:
        return None
    arr = np.array(x, dtype=object)
    out = np.where(arr == None, np.nan, arr).astype(float)    # noqa: E711
    return np.atleast_1d(out) if ndmin == 1 else out


@dataclass
class TelemetryTrace:
    """An in-memory trace: what ``load_trace`` returns and what the offline
    replay / degradation tooling consumes.  Mirrors the collector's buffers
    minus the ring-buffer bound."""

    meta: Dict = field(default_factory=dict)
    samples: List[NodeSample] = field(default_factory=list)
    fleet: List[FleetSample] = field(default_factory=list)
    actions: List[ManagerAction] = field(default_factory=list)
    events: List[FaultRecord] = field(default_factory=list)
    requests: List[RequestRecord] = field(default_factory=list)

    @classmethod
    def from_collector(cls, col: TelemetryCollector) -> "TelemetryTrace":
        return cls(meta=dict(col.meta), samples=list(col.samples),
                   fleet=list(col.fleet), actions=list(col.actions),
                   events=list(getattr(col, "events", [])),
                   requests=list(getattr(col, "requests", [])))

    def node_samples(self, node: int = 0) -> List[NodeSample]:
        return [s for s in self.samples if s.node == node]

    @property
    def n_nodes(self) -> int:
        return int(self.meta.get("n_nodes", 1))

    @property
    def n_devices(self) -> int:
        if "n_devices" in self.meta:
            return int(self.meta["n_devices"])
        return int(self.samples[0].power.shape[0]) if self.samples else 0


def save_trace(src, path: str, extra_meta: Optional[Dict] = None) -> int:
    """Write a collector or TelemetryTrace as JSONL; returns line count."""
    trace = (TelemetryTrace.from_collector(src)
             if isinstance(src, TelemetryCollector) else src)
    meta = dict(trace.meta)
    if extra_meta:
        meta.update(extra_meta)
    # straggler_hint keys are ints in memory; JSON makes them strings —
    # normalize here so save/load/save is stable
    if isinstance(meta.get("straggler_hint"), dict):
        meta["straggler_hint"] = {str(k): v for k, v
                                  in meta["straggler_hint"].items()}
    lines = 0
    with open(path, "w") as f:
        f.write(_dumps({"format": TRACE_FORMAT,
                        "version": TRACE_VERSION, "meta": meta}) + "\n")
        lines += 1
        for s in trace.samples:
            f.write(_dumps({
                "type": "node", "it": s.iteration, "node": s.node,
                "t_local": s.t_local, "t_wall": s.t_wall,
                "start": _enc(s.comp_start), "end": _enc(s.comp_end),
                "overlap": _enc(s.overlap),
                "power": _enc(s.power), "temp": _enc(s.temp),
                "freq": _enc(s.freq), "cap": _enc(s.cap),
                "truth_start": _enc(s.truth_start)}) + "\n")
            lines += 1
        for fs in trace.fleet:
            f.write(_dumps({
                "type": "fleet", "it": fs.iteration, "t_fleet": fs.t_fleet,
                "lead": _enc(fs.lead), "t_local": _enc(fs.t_local),
                "node_power": _enc(fs.node_power),
                "topology": fs.topology,
                "lead_obs": _enc(fs.lead_obs),
                "t_obs": _enc(fs.t_obs),
                "tail": _enc(fs.tail)}) + "\n")
            lines += 1
        for a in trace.actions:
            f.write(_dumps({
                "type": "action", "it": a.iteration, "kind": a.kind,
                "node": a.node, "values": _enc(a.values)}) + "\n")
            lines += 1
        for ev in trace.events:
            val = ev.value
            f.write(_dumps({
                "type": "event", "it": ev.iteration, "t_sim": ev.t_sim,
                "kind": ev.kind, "node": ev.node, "device": ev.device,
                "value": (None if val != val else val),
                "source": ev.source}) + "\n")
            lines += 1

        def _t(x: float):                   # NaN timestamps encode as null
            return None if x != x else x
        for rq in trace.requests:
            f.write(_dumps({
                "type": "request", "rid": rq.rid, "node": rq.node,
                "t_arrival": _t(rq.t_arrival), "t_admit": _t(rq.t_admit),
                "t_first": _t(rq.t_first), "t_done": _t(rq.t_done),
                "prompt_len": rq.prompt_len, "output_len": rq.output_len,
                "tokens_out": rq.tokens_out}) + "\n")
            lines += 1
    return lines


def load_trace(path: str) -> TelemetryTrace:
    with open(path) as f:
        header = json.loads(f.readline())
        if header.get("format") != TRACE_FORMAT:
            raise ValueError(f"{path}: not a {TRACE_FORMAT} trace "
                             f"(format={header.get('format')!r})")
        if "version" not in header:
            raise ValueError(f"{path}: trace header carries no version")
        if int(header["version"]) > TRACE_VERSION:
            raise ValueError(
                f"{path}: trace version {header['version']} is newer than "
                f"supported version {TRACE_VERSION}")
        meta = header.get("meta", {})
        if isinstance(meta.get("straggler_hint"), dict):
            meta["straggler_hint"] = {int(k): v for k, v
                                      in meta["straggler_hint"].items()}
        trace = TelemetryTrace(meta=meta)
        for line in f:
            line = line.strip()
            if not line:
                continue
            r = json.loads(line)
            if r["type"] == "node":
                trace.samples.append(NodeSample(
                    iteration=r["it"], node=r["node"],
                    t_local=r["t_local"], t_wall=r["t_wall"],
                    comp_start=_dec(r["start"], ndmin=2),
                    comp_end=_dec(r["end"], ndmin=2),
                    overlap=_dec(r["overlap"], ndmin=2),
                    power=_dec(r["power"]), temp=_dec(r["temp"]),
                    freq=_dec(r["freq"]), cap=_dec(r["cap"]),
                    truth_start=_dec(r.get("truth_start"), ndmin=2)))
            elif r["type"] == "fleet":
                trace.fleet.append(FleetSample(
                    iteration=r["it"], t_fleet=r["t_fleet"],
                    lead=_dec(r["lead"]), t_local=_dec(r["t_local"]),
                    node_power=_dec(r["node_power"]),
                    topology=r["topology"],
                    # .get(): traces written before the fleet sensor existed
                    # load with lead_obs=None rather than failing
                    lead_obs=_dec(r.get("lead_obs")),
                    t_obs=_dec(r.get("t_obs")),
                    tail=_dec(r.get("tail"))))
            elif r["type"] == "action":
                trace.actions.append(ManagerAction(
                    iteration=r["it"], kind=r["kind"], node=r["node"],
                    values=_dec(r["values"])))
            elif r["type"] == "event":
                v = r.get("value")
                trace.events.append(FaultRecord(
                    iteration=r["it"], t_sim=r["t_sim"], kind=r["kind"],
                    node=r["node"], device=r.get("device", -1),
                    value=(float("nan") if v is None else float(v)),
                    source=r.get("source", "fault")))
            elif r["type"] == "request":
                def _t(x):
                    return float("nan") if x is None else float(x)
                trace.requests.append(RequestRecord(
                    rid=r["rid"], node=r["node"],
                    t_arrival=_t(r["t_arrival"]), t_admit=_t(r["t_admit"]),
                    t_first=_t(r["t_first"]), t_done=_t(r["t_done"]),
                    prompt_len=r["prompt_len"], output_len=r["output_len"],
                    tokens_out=r["tokens_out"]))
    return trace


# --------------------------------------------------------------------------- #
# Chrome trace (Perfetto / chrome://tracing)
# --------------------------------------------------------------------------- #
def export_chrome_trace(src, path: str, max_samples: Optional[int] = None,
                        counters: bool = True) -> int:
    """Write trace-event JSON; returns the number of events emitted.

    Timestamps are microseconds on a per-node clock that concatenates the
    *sampled* intervals (elided iterations collapse), which keeps kernels
    visually aligned across devices within each iteration.
    """
    trace = (TelemetryTrace.from_collector(src)
             if isinstance(src, TelemetryCollector) else src)
    events: List[dict] = []
    comp_names = trace.meta.get("comp_names") or []
    offsets: Dict[int, float] = {}
    seen_nodes, seen_tids = set(), set()
    samples = trace.samples[-max_samples:] if max_samples else trace.samples
    for s in samples:
        off = offsets.setdefault(s.node, 0.0)
        if s.node not in seen_nodes:
            seen_nodes.add(s.node)
            events.append({"ph": "M", "name": "process_name", "pid": s.node,
                           "tid": 0, "args": {"name": f"node{s.node}"}})
        G, K = s.comp_start.shape
        for g in range(G):
            if (s.node, g) not in seen_tids:
                seen_tids.add((s.node, g))
                events.append({"ph": "M", "name": "thread_name",
                               "pid": s.node, "tid": g,
                               "args": {"name": f"gpu{g}"}})
            for k in range(K):
                t0, t1 = s.comp_start[g, k], s.comp_end[g, k]
                if np.isnan(t0) or np.isnan(t1):
                    continue
                name = comp_names[k] if k < len(comp_names) else f"k{k}"
                events.append({
                    "ph": "X", "name": name, "cat": "compute",
                    "pid": s.node, "tid": g,
                    "ts": (off + t0) * 1e6,
                    "dur": max(t1 - t0, 0.0) * 1e6,
                    "args": {"iter": s.iteration,
                             "overlap_s": (float(s.overlap[g, k])
                                           if s.overlap.size else 0.0)}})
        if counters:
            ts = off * 1e6
            for cname, vec in (("power_w", s.power), ("temp_c", s.temp),
                               ("cap_w", s.cap), ("freq_ghz", s.freq)):
                vals = {f"gpu{g}": (None if np.isnan(v) else float(v))
                        for g, v in enumerate(np.asarray(vec))}
                events.append({"ph": "C", "name": cname, "pid": s.node,
                               "tid": 0, "ts": ts, "args": vals})
        offsets[s.node] = off + s.t_wall
    # ---------------------------------------------------------------- fleet
    # one extra "fleet" process carries the cluster-scope signals: counter
    # tracks per fleet sample (lead / observed time / node power / serve
    # tail) on the cumulative sampled-fleet clock, plus instant events for
    # every fault onset, escalation stage and alert transition — so a
    # single Perfetto file shows physics and alerts together.  Event
    # timestamps are the records' own simulated-seconds clock, which
    # coincides with the cumulative track clock at lossless fidelity.
    fleet_pid = max([trace.n_nodes] + [n + 1 for n in seen_nodes])
    if trace.fleet or trace.events:
        events.append({"ph": "M", "name": "process_name", "pid": fleet_pid,
                       "tid": 0, "args": {"name": "fleet"}})
    if counters:
        clock = 0.0
        for fs in trace.fleet:
            clock += float(fs.t_fleet)
            ts = clock * 1e6
            lead = fs.lead_obs if fs.lead_obs is not None else fs.lead
            for cname, vec in (("lead_s", lead), ("t_obs_s", fs.t_obs),
                               ("node_power_w", fs.node_power),
                               ("tail_s", fs.tail)):
                if vec is None:
                    continue
                vals = {f"node{n}": (None if np.isnan(v) else float(v))
                        for n, v in enumerate(np.asarray(vec, float))}
                events.append({"ph": "C", "name": cname, "pid": fleet_pid,
                               "tid": 0, "ts": ts, "args": vals})
    for ev in trace.events:
        events.append({
            "ph": "i", "name": f"{ev.source}:{ev.kind}", "cat": ev.source,
            "pid": fleet_pid, "tid": 0, "ts": float(ev.t_sim) * 1e6,
            "s": "g",
            "args": {"node": ev.node, "device": ev.device,
                     "iter": ev.iteration,
                     "value": (None if ev.value != ev.value
                               else float(ev.value))}})
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms",
                   "otherData": {"format": TRACE_FORMAT,
                                 "version": TRACE_VERSION}}, f,
                  sort_keys=True, allow_nan=False)
    return len(events)
