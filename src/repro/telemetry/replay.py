"""Offline replay: re-run detection + mitigation over a recorded trace.

This is the paper's Fig-12 workflow made concrete: the detect→mitigate loop
(Algorithm 1 + Algorithms 2/3 inside an unmodified ``PowerManager``) runs
in *dry-run* mode against recorded telemetry — caps live in memory, no
simulator or hardware behind them — so a trace recorded once can be
analyzed, re-tuned, and its converged cap schedule exported, offline.

Two guarantees, both tested:

  * **Bit-for-bit**: replaying a lossless trace (default sensor, every
    iteration recorded) with the live run's ManagerConfig reproduces the
    live cap schedule exactly — same floats, every adjustment, under the
    event, batched, and vector engines.  The cap arithmetic is a pure
    function of the kernel-start stream, the config, and the initial caps;
    a lossless trace preserves all three.
  * **Degradation is measurable**: ``degrade`` re-observes a recorded
    trace through an arbitrary ``SensorModel`` (noise / quantization /
    subsampling / dropout) without re-simulating, and
    ``detection_report`` quantifies what the detector loses — straggler
    identification accuracy and lead-estimate error as sensor fidelity
    drops.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from types import SimpleNamespace
from typing import Dict, List, Optional

import numpy as np

from repro.core.detect import lead_value_detect
from repro.core.manager import (FleetManagerConfig, FleetPowerManager,
                                ManagerConfig, PowerManager)
from repro.telemetry.collector import NodeSample
from repro.telemetry.lead import estimate_fleet_lead
from repro.telemetry.sensors import SensorModel
from repro.telemetry.trace_io import TelemetryTrace


class ReplayCapBackend:
    """Dry-run ``PowerBackend``: caps are plain state, nothing executes."""

    def __init__(self, n_devices: int, tdp: float):
        self.n_devices = n_devices
        self.tdp = tdp
        self._caps = np.full(n_devices, float(tdp))

    def run_iteration(self):
        raise NotImplementedError(
            "ReplayCapBackend is offline: iterations come from the trace")

    def set_power_caps(self, caps: np.ndarray) -> None:
        self._caps = np.asarray(caps, float).copy()

    def get_power_caps(self) -> np.ndarray:
        return self._caps.copy()

    def telemetry(self) -> dict:
        return {"cap": self._caps.copy()}


class _FleetReplayBackend:
    """Fleet-scope dry-run backend: per-node cap views + the recorded
    topology lead signal (what ``FleetPowerManager`` consumes live)."""

    def __init__(self, n_nodes: int, n_devices: int, node_tdps):
        self.n_nodes = n_nodes
        self.n_devices = n_devices
        self.node_tdps = np.asarray(node_tdps, float)
        self.tdp = float(self.node_tdps[0])
        self.node_views = [ReplayCapBackend(n_devices, t)
                           for t in self.node_tdps]
        self._lead: Optional[np.ndarray] = None

    def node_leads(self) -> Optional[np.ndarray]:
        return self._lead

    def get_power_caps(self) -> np.ndarray:
        return np.stack([v.get_power_caps() for v in self.node_views])


def _trace_view(start: np.ndarray) -> SimpleNamespace:
    """The slice of ``IterationTrace`` the manager consumes offline."""
    return SimpleNamespace(comp_start=start)


# --------------------------------------------------------------------------- #
# node-level replay
# --------------------------------------------------------------------------- #
@dataclass
class NodeReplay:
    manager: PowerManager
    cap_schedule: List[np.ndarray]      # every adjustment, in order
    lead_log: List[np.ndarray]
    final_caps: np.ndarray

    def export_caps(self, path: str) -> None:
        """Same caps-file format as the live manager (Fig 12): a replayed
        schedule can warm-start a future run via ``import_caps``."""
        self.manager.export_caps(path)


def replay_node(trace: TelemetryTrace, cfg: ManagerConfig, node: int = 0,
                tune_after: Optional[int] = None,
                sensor: Optional[SensorModel] = None) -> NodeReplay:
    """Drive an unmodified ``PowerManager`` over node ``node``'s recorded
    kernel-start stream.  For a bit-for-bit match, ``tune_after`` must be
    the enable point the live run used — note ``run_closed_loop`` defaults
    it to ``iterations // 2``, while here ``None`` means enabled from the
    first sample (there is no way to infer the live loop's horizon from a
    trace, so nothing is guessed).  ``sensor`` optionally degrades the
    stream on the way in (on top of whatever the recording sensor already
    did)."""
    samples = trace.node_samples(node)
    if not samples:
        raise ValueError(f"trace holds no samples for node {node}")
    G = samples[0].comp_start.shape[0]
    tdp = float(trace.meta.get("tdp", 750.0))
    mgr = PowerManager(ReplayCapBackend(G, tdp), cfg, sensor=sensor)
    armed = tune_after is None
    mgr.enabled = armed
    for s in samples:
        if not armed and s.iteration >= tune_after:
            mgr.enabled = True
            armed = True
        mgr.on_iteration(s.iteration, _trace_view(s.comp_start))
    return NodeReplay(manager=mgr,
                      cap_schedule=[c.copy() for c in mgr.adjust_log],
                      lead_log=[v.copy() for v in mgr.lead_log],
                      final_caps=mgr.backend.get_power_caps())


# --------------------------------------------------------------------------- #
# fleet-level replay
# --------------------------------------------------------------------------- #
@dataclass
class FleetReplay:
    manager: FleetPowerManager
    budget_log: List[np.ndarray]
    node_cap_schedules: List[List[np.ndarray]]   # per node, every adjustment
    final_caps: np.ndarray                       # (N, G)
    skipped_iterations: List[int]                # fleet samples missing some
    #                                              node samples (truncation)

    def export_caps(self, path: str, node: int = 0) -> None:
        self.manager.managers[node].export_caps(path)


def replay_fleet(trace: TelemetryTrace, cfg: FleetManagerConfig,
                 tune_after: int = 0) -> FleetReplay:
    """Drive an unmodified ``FleetPowerManager`` (nested node managers +
    node-budget loop) over a recorded cluster trace.  For a bit-for-bit
    match, ``tune_after`` must be the enable point the live run used
    (``run_fleet_closed_loop`` defaults it to ``iterations // 2``; the
    default here enables from the first sample).  Fleet samples whose node
    samples were partially evicted by the recording ring buffer cannot be
    replayed — they are skipped with a warning and listed in
    ``FleetReplay.skipped_iterations``, so a truncated trace reads as
    truncation, not as a replay mismatch."""
    if not trace.fleet:
        raise ValueError("trace holds no fleet samples (record through "
                         "TelemetryCollector.attach_cluster)")
    N = trace.n_nodes
    node_tdps = trace.meta.get("node_tdps") or [trace.meta.get("tdp", 750.0)] * N
    by_iter: Dict[int, Dict[int, NodeSample]] = {}
    for s in trace.samples:
        by_iter.setdefault(s.iteration, {})[s.node] = s
    backend = _FleetReplayBackend(N, trace.n_devices, node_tdps)
    mgr = FleetPowerManager(backend, cfg)
    skipped: List[int] = []
    for fs in trace.fleet:
        if fs.iteration < tune_after:
            continue
        nodes = by_iter.get(fs.iteration, {})
        if len(nodes) != N:
            skipped.append(fs.iteration)
            continue
        traces = [_trace_view(nodes[n].comp_start) for n in range(N)]
        backend._lead = fs.lead
        mgr.on_iteration(fs.iteration, traces)
    if skipped:
        warnings.warn(
            f"replay_fleet: {len(skipped)} fleet sample(s) "
            f"(iterations {skipped[:5]}{'...' if len(skipped) > 5 else ''}) "
            f"lacked node samples for all {N} nodes — the recording ring "
            "buffer truncated them; raise TelemetryCollector.max_samples "
            "to replay the full run", stacklevel=2)
    return FleetReplay(
        manager=mgr,
        budget_log=[b.copy() for b in mgr.budget_log],
        node_cap_schedules=[[c.copy() for c in m.adjust_log]
                            for m in mgr.managers],
        final_caps=backend.get_power_caps(),
        skipped_iterations=skipped)


def fleet_replay_matches(live: FleetPowerManager, rp: FleetReplay,
                         live_caps: Optional[np.ndarray] = None,
                         log=None) -> bool:
    """Bit-for-bit comparison of a live fleet run against its replay:
    budget schedule, every node's cap schedule, and (when given) the final
    live cap matrix.  ``log`` (e.g. ``print``) receives one line per
    divergence — the single checker the CI smoke and the benchmark share,
    so the two cannot drift apart."""
    log = log or (lambda *_: None)
    ok = True
    if len(rp.budget_log) != len(live.budget_log):
        log(f"MISMATCH: {len(rp.budget_log)} replayed budget steps vs "
            f"{len(live.budget_log)} live")
        ok = False
    for i, (a, b) in enumerate(zip(rp.budget_log, live.budget_log)):
        if not np.array_equal(a, b):
            log(f"MISMATCH: budget step {i}: replay={a} live={b}")
            ok = False
            break
    for n, (sched, mgr) in enumerate(zip(rp.node_cap_schedules,
                                         live.managers)):
        if len(sched) != len(mgr.adjust_log):
            log(f"MISMATCH: node {n}: {len(sched)} replayed cap steps vs "
                f"{len(mgr.adjust_log)} live")
            ok = False
        for i, (a, b) in enumerate(zip(sched, mgr.adjust_log)):
            if not np.array_equal(a, b):
                log(f"MISMATCH: node {n} cap step {i}: replay={a} live={b}")
                ok = False
                break
    if live_caps is not None and not np.array_equal(rp.final_caps,
                                                    live_caps):
        log(f"MISMATCH: final caps: replay={rp.final_caps} live={live_caps}")
        ok = False
    return ok


# --------------------------------------------------------------------------- #
# escalation replay: re-derive drain decisions from the observed stream
# --------------------------------------------------------------------------- #
@dataclass
class EscalationReplay:
    """What the offline escalation policy decided over a recorded trace."""

    decisions: List                 # DrainDecision, in order
    events: List                    # EscalationEvent: every stage transition
    drained_nodes: List[int]        # global node ids, in drain order


def replay_escalation(trace: TelemetryTrace, cfg=None) -> EscalationReplay:
    """Re-run the :class:`~repro.core.escalate.EscalationPolicy` over the
    recorded observed per-node times (``FleetSample.t_obs``) and return
    the decisions it makes offline.

    The policy is a pure function of the observed stream and the config,
    so with the config the live run used (taken from
    ``trace.meta["escalation"]`` when ``cfg`` is None) the replay emits
    the *same* stage transitions — suspect, escalate, sensor-death, drain
    — at the same steps with the same values, bit-for-bit
    (``escalation_replay_matches``).  Membership is replayed too: each
    drain removes the node and resets the policy, mirroring the live
    elastic restart, and the simulated clock advances by the recorded
    ``t_fleet`` per sample plus ``drain_s + restart_penalty_s`` per drain.
    """
    from repro.core.escalate import EscalationConfig, EscalationPolicy
    if cfg is None:
        d = trace.meta.get("escalation")
        if d is None:
            raise ValueError("trace meta carries no escalation config; "
                             "pass cfg explicitly")
        cfg = EscalationConfig.from_dict(d)
    samples = [fs for fs in trace.fleet if fs.t_obs is not None]
    if not samples:
        raise ValueError("trace fleet samples carry no t_obs (recorded "
                         "before fault telemetry existed)")
    alive = list(range(trace.n_nodes))
    policy = EscalationPolicy(cfg, nodes=alive)
    decisions: List = []
    t_sim = 0.0
    heal_s = cfg.drain_s + cfg.restart_penalty_s
    # recorded alert transitions reconstruct the observability firing set
    # the live policy saw via note_alerts (only consulted when
    # cfg.alert_corroborate is on): transitions with iteration <= the
    # sample's were emitted before the live observe() call
    alert_rows = [e for e in trace.events if e.source == "alert"]
    ai = 0
    firing: dict = {}               # (rule, node, device) -> True
    for fs in samples:
        if len(fs.t_obs) != len(alive):
            raise ValueError(
                f"fleet sample at iteration {fs.iteration} is "
                f"{len(fs.t_obs)} nodes wide but the replayed membership "
                f"is {len(alive)} — the trace's drains diverge from this "
                "config's decisions")
        t_sim += float(fs.t_fleet)
        while ai < len(alert_rows) and alert_rows[ai].iteration <= fs.iteration:
            ev = alert_rows[ai]
            ai += 1
            rule, _, state = ev.kind.rpartition("/")
            if state == "firing":
                firing[(rule, ev.node, ev.device)] = True
            elif state == "resolved":
                firing.pop((rule, ev.node, ev.device), None)
        policy.note_alerts({n for (_, n, _) in firing if n >= 0})
        decision = policy.observe(fs.iteration, fs.t_obs, t_sim=t_sim)
        if decision is not None and len(alive) - 1 < cfg.min_nodes:
            decision = None         # mirror the live runner's fleet floor
        if decision is not None:
            decisions.append(decision)
            t_sim += heal_s
            alive = [a for a in alive if a != decision.global_node]
            policy.reset(alive)
            policy.emit(fs.iteration + 1, t_sim, "restart", -1,
                        value=len(alive))
    return EscalationReplay(decisions=decisions,
                            events=list(policy.events),
                            drained_nodes=[d.global_node
                                           for d in decisions])


def _feq(a: float, b: float) -> bool:
    return (a != a and b != b) or a == b       # NaN-tolerant exact equality


def escalation_replay_matches(trace: TelemetryTrace, rp: EscalationReplay,
                              log=None) -> bool:
    """Bit-for-bit comparison of the live run's recorded escalation events
    (``source == "escalation"`` in the trace) against an offline replay:
    same stages, on the same global nodes, at the same steps, with the
    same simulated timestamps and values.  ``log`` (e.g. ``print``)
    receives one line per divergence — shared by the CI smoke and the
    tests, so the two cannot drift apart."""
    log = log or (lambda *_: None)
    rec = [e for e in trace.events if e.source == "escalation"]
    ok = True
    if len(rec) != len(rp.events):
        log(f"MISMATCH: {len(rp.events)} replayed escalation events vs "
            f"{len(rec)} recorded")
        ok = False
    for i, (a, b) in enumerate(zip(rec, rp.events)):
        if not (a.iteration == b.step and a.kind == b.stage
                and a.node == b.node and _feq(a.t_sim, b.t_sim)
                and _feq(a.value, b.value)):
            log(f"MISMATCH: escalation event {i}: recorded "
                f"(it={a.iteration}, {a.kind}, node={a.node}, "
                f"t={a.t_sim}, v={a.value}) vs replayed "
                f"(it={b.step}, {b.stage}, node={b.node}, "
                f"t={b.t_sim}, v={b.value})")
            ok = False
            break
    rec_drained = [e.node for e in rec if e.kind == "drain"]
    if ok and rec_drained[:len(rp.drained_nodes)] != rp.drained_nodes[
            :len(rec_drained)]:
        log(f"MISMATCH: drain order: recorded {rec_drained} vs replayed "
            f"{rp.drained_nodes}")
        ok = False
    return ok


# --------------------------------------------------------------------------- #
# sensor-fidelity studies
# --------------------------------------------------------------------------- #
def degrade(trace: TelemetryTrace, sensor: SensorModel) -> TelemetryTrace:
    """Re-observe a recorded trace through a (worse) sensor — offline, no
    re-simulation.  Ground truth is taken from ``truth_start`` when the
    recording sensor was already lossy, else from the recorded starts.
    The sensor's ``sample_period``/``phase_jitter`` subsample which
    iterations survive; noise/quantization/dropout degrade the rest.  The
    returned trace keeps the truth beside the observation so
    ``detection_report`` can quantify the damage.

    Fleet rows are re-observed too: ``t_obs`` is redrawn from the true
    per-node times through a fleet-scope stream of the same config
    (``FLEET_SENSOR_OFFSET``, mirroring live recording), preserving the
    recorded dead-sensor NaN mask, and ``lead_obs`` is recomputed from it.
    Fault/escalation events and request records carry over unchanged
    (they are engine facts, not sensor readings); recorded *alert* rows
    are dropped — they were computed at the recording fidelity, and
    ``repro.obs.replay_alerts`` over the degraded trace regenerates them
    at the degraded one."""
    from repro.telemetry.collector import FLEET_SENSOR_OFFSET
    out = TelemetryTrace(meta=dict(trace.meta))
    out.meta["sensor"] = sensor.cfg.to_dict()
    keep = {it for it in sorted({s.iteration for s in trace.samples})
            if sensor.take_sample(it)}
    for s in trace.samples:
        if s.iteration not in keep:
            continue
        truth = s.truth_start if s.truth_start is not None else s.comp_start
        out.samples.append(dataclasses.replace(
            s, comp_start=sensor.observe_starts(truth),
            comp_end=sensor.observe_times(s.comp_end),
            power=np.asarray(sensor.observe_power(s.power), float),
            temp=np.asarray(sensor.observe_temp(s.temp), float),
            truth_start=np.array(truth, float, copy=True)))
    fleet_sensor = SensorModel(sensor.cfg, seed_offset=FLEET_SENSOR_OFFSET)
    for fs in trace.fleet:
        if fs.iteration not in keep:
            continue
        t_obs = np.asarray(fleet_sensor.observe_times(
            np.asarray(fs.t_local, float)), float).copy()
        if fs.t_obs is not None:
            t_obs[np.isnan(np.asarray(fs.t_obs, float))] = np.nan
        # same topology-aware estimator the live collector runs, driven
        # from the trace meta (legacy traces fall back to the barrier)
        lead_obs = estimate_fleet_lead(
            t_obs, topology=str(fs.topology),
            params=trace.meta.get("topology_params"))
        out.fleet.append(dataclasses.replace(
            fs, t_obs=t_obs, lead_obs=lead_obs))
    out.actions = list(trace.actions)
    out.events = [e for e in trace.events if e.source != "alert"]
    out.requests = list(trace.requests)
    return out


@dataclass
class DetectionReport:
    n_samples: int
    accuracy: float             # fraction of samples naming the straggler
    majority_device: int        # argmin of the mean observed lead
    majority_correct: bool
    lead_rel_error: float       # mean ‖observed − true lead‖ / true span
    true_straggler: int
    accuracy_imputed: Optional[float] = None  # accuracy after last-known-
    #                             value fill of dropped rows; None when the
    #                             stream carries no dropped rows (imputation
    #                             then changes nothing)
    dropped_samples: int = 0    # samples with >=1 all-NaN device row

    def row(self) -> str:
        imp = ("" if self.accuracy_imputed is None
               else f";acc_imputed={self.accuracy_imputed:.3f}")
        return (f"samples={self.n_samples};acc={self.accuracy:.3f};"
                f"majority_ok={int(self.majority_correct)};"
                f"lead_err={self.lead_rel_error:.4f}" + imp)


def detection_report(trace: TelemetryTrace, node: int = 0,
                     mode: str = "sum",
                     true_straggler: Optional[int] = None) -> DetectionReport:
    """How well Algorithm 1 does on this trace's observed stream, against
    the ground truth the trace carries (``truth_start``, or the observed
    stream itself for a lossless recording).

    When the stream contains dropped device rows (all-NaN — they read as
    zero lead and shadow the straggler at argmin), the report additionally
    scores the *imputed* stream, with each dropped row replaced by that
    device's last observed row (``accuracy_imputed``) — the recovery the
    ``SensorConfig.impute_dropout`` mitigation buys a live manager."""
    samples = trace.node_samples(node)
    if not samples:
        raise ValueError(f"trace holds no samples for node {node}")
    if true_straggler is None:
        hint = trace.meta.get("straggler_hint", {})
        if node not in hint:
            raise ValueError("no straggler_hint in trace meta; pass "
                             "true_straggler explicitly")
        true_straggler = int(hint[node])
    hits, hits_imp, dropped, errs, leads = 0, 0, 0, [], []
    held: Optional[np.ndarray] = None       # last observed row per device
    for s in samples:
        obs = lead_value_detect(s.comp_start, mode)
        start_imp = np.asarray(s.comp_start, float)
        nan_rows = np.isnan(start_imp).all(axis=1) & (start_imp.shape[1] > 0)
        if nan_rows.any():
            dropped += 1
            if held is not None and held.shape == start_imp.shape:
                start_imp = start_imp.copy()
                start_imp[nan_rows] = held[nan_rows]
        if held is None or held.shape != np.asarray(s.comp_start).shape:
            held = np.array(start_imp, float, copy=True)
        else:
            keep = ~np.isnan(start_imp).all(axis=1)
            held[keep] = start_imp[keep]
        hits_imp += int(np.argmin(lead_value_detect(start_imp, mode))
                        == true_straggler)
        truth_start = (s.truth_start if s.truth_start is not None
                       else s.comp_start)
        truth = lead_value_detect(truth_start, mode)
        hits += int(np.argmin(obs) == true_straggler)
        span = float(truth.max() - truth.min())
        errs.append(float(np.sqrt(np.mean((obs - truth) ** 2)))
                    / max(span, 1e-12))
        leads.append(obs)
    mean_lead = np.mean(leads, axis=0)
    maj = int(np.argmin(mean_lead))
    return DetectionReport(
        n_samples=len(samples), accuracy=hits / len(samples),
        majority_device=maj, majority_correct=(maj == true_straggler),
        lead_rel_error=float(np.mean(errs)),
        true_straggler=true_straggler,
        accuracy_imputed=(hits_imp / len(samples) if dropped else None),
        dropped_samples=dropped)


@dataclass
class FleetLeadReport:
    """How well the fleet-scope lead *estimate* tracks the true topology
    lead signal.  The estimate (``FleetSample.lead_obs``) is what a real
    fleet manager has: per-node iteration times read through a sensor,
    folded into a barrier-wait lead ``max(t) - t``.  The error it carries
    is sensor noise plus — under PP/TP, whose true lead is not a barrier
    wait — the estimator's model bias; a lossless DP trace scores zero."""

    n_samples: int
    accuracy: float             # fraction naming the true per-sample straggler
    majority_node: int          # argmin of the mean estimated lead
    majority_correct: bool      # ...equals argmin of the mean true lead
    lead_rel_error: float       # mean rms(est - true lead) / true span

    def row(self) -> str:
        """``derived``-column fragment, same shape as DetectionReport.row."""
        return (f"fleet_samples={self.n_samples};"
                f"fleet_acc={self.accuracy:.3f};"
                f"fleet_majority_ok={int(self.majority_correct)};"
                f"fleet_lead_err={self.lead_rel_error:.4f}")


def fleet_lead_report(trace: TelemetryTrace) -> FleetLeadReport:
    """Score the recorded fleet-lead estimate against the true topology
    lead the same trace carries.  Ground truth is per-sample (``argmin``
    of the lossless ``lead``), so node churn that moves the straggler is
    scored correctly.  Raises ``ValueError`` on traces without fleet
    samples or recorded before ``lead_obs`` existed."""
    samples = [fs for fs in trace.fleet if fs.lead_obs is not None]
    if not trace.fleet:
        raise ValueError("trace holds no fleet samples (record through "
                         "TelemetryCollector.attach_cluster)")
    if not samples:
        raise ValueError("trace fleet samples carry no lead_obs (recorded "
                         "before the fleet lead sensor existed)")
    hits, errs, est, true = 0, [], [], []
    for fs in samples:
        hits += int(np.argmin(fs.lead_obs) == np.argmin(fs.lead))
        span = float(fs.lead.max() - fs.lead.min())
        errs.append(float(np.sqrt(np.mean((fs.lead_obs - fs.lead) ** 2)))
                    / max(span, 1e-12))
        est.append(fs.lead_obs)
        true.append(fs.lead)
    maj = int(np.argmin(np.mean(est, axis=0)))
    return FleetLeadReport(
        n_samples=len(samples), accuracy=hits / len(samples),
        majority_node=maj,
        majority_correct=(maj == int(np.argmin(np.mean(true, axis=0)))),
        lead_rel_error=float(np.mean(errs)))
