"""Noisy sensor models: how telemetry actually reaches a power manager.

On real systems the paper's detection (Algorithm 1) never sees ground
truth.  Kernel timestamps come from a profiler hook with finite clock
resolution; power and temperature come from rocm-smi-style counters that
quantize to 1 W / 1 °C, are sampled at a period (with scheduling jitter on
the sampling phase), carry additive read noise, and occasionally drop a
reading entirely.  Every degradation here is a knob, so detection and
mitigation robustness can be measured as a function of sensor fidelity
(the telemetry-replay studies in examples/telemetry_study.py).

All stochastic draws come from a dedicated ``numpy`` Generator seeded from
the sensor config, so a recorded run is reproducible end to end: the same
seed consumes the same stream regardless of which signals are observed in
which order per sample (each observation kind draws only when its knob is
non-zero, and the lossless default draws nothing at all — observations are
then bit-for-bit the ground truth).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SensorConfig:
    """Sensor fidelity knobs.  The default is a lossless oracle sensor:
    no noise, no quantization, every iteration sampled, nothing dropped —
    recording through it is exact, which is what the bit-for-bit replay
    guarantee (replay.py) rests on."""

    noise_time_s: float = 0.0       # additive Gaussian σ on timestamps (s)
    noise_power_w: float = 0.0      # additive Gaussian σ on power reads (W)
    noise_temp_c: float = 0.0       # additive Gaussian σ on temp reads (°C)
    quant_time_s: float = 0.0       # timestamp clock resolution (0 = off)
    quant_power_w: float = 0.0      # power counter step (rocm-smi: 1 W)
    quant_temp_c: float = 0.0       # temperature counter step (1 °C)
    sample_period: int = 1          # observe 1 of every N iterations
    phase_jitter: int = 0           # ± iterations of sampling-phase slack
    dropout_p: float = 0.0          # P(a device's sample is lost) per read
    impute_dropout: bool = False    # last-known-value fill for dropped rows
    seed: int = 0

    @property
    def lossless(self) -> bool:
        return (self.noise_time_s == 0 and self.noise_power_w == 0
                and self.noise_temp_c == 0 and self.quant_time_s == 0
                and self.quant_power_w == 0 and self.quant_temp_c == 0
                and self.sample_period <= 1 and self.phase_jitter == 0
                and self.dropout_p == 0)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SensorConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


LOSSLESS = SensorConfig()

# The rocm-smi-style counter stack, calibrated knob by knob (this preset
# is pinned by tests/test_obs.py — change it deliberately):
#
#   * timestamps — kernel starts come from a profiler hook (hipEvent /
#     rocprof), whose documented tick is ~1 us; the host-side read adds
#     scheduling jitter of a few tens of us.  So quant_time_s=1e-6 and
#     noise σ=20 us — three orders tighter than the old 1 ms placeholder,
#     which was noise at the scale of a whole kernel, not of a clock read.
#   * power — the SMU's average-socket-power register steps in 1 W
#     (documented interface) and the averaging window makes successive
#     reads wobble a couple of watts against the true instantaneous draw
#     at MI300-class power levels: σ=2 W.
#   * temperature — edge/junction sensors also report whole degrees;
#     sensor accuracy is ~±1 °C: σ=1 °C, 1 °C step.
#   * sampling — rocm-smi polls on a wall clock (~1 s period) while the
#     fleet iterates every ~0.35-0.40 s, so a poll lands roughly every
#     3rd iteration with ±1 iteration of scheduling phase slack.
#   * dropout — a busy SMU occasionally rejects a read; ~0.1 % per
#     device-sample matches how rarely a long capture shows a hole.
ROCM_SMI_LIKE = SensorConfig(
    noise_time_s=2e-5, noise_power_w=2.0, noise_temp_c=1.0,
    quant_time_s=1e-6, quant_power_w=1.0, quant_temp_c=1.0,
    sample_period=3, phase_jitter=1, dropout_p=0.001,
)


def _quantize(x: np.ndarray, step: float) -> np.ndarray:
    return np.round(x / step) * step if step > 0 else x


class SensorModel:
    """A stateful observer over one node's ground-truth signals.

    ``take_sample`` decides which iterations are observed (period + phase
    jitter); the ``observe_*`` methods degrade the signals.  Instantiate
    one model per recorded node so per-node streams stay independent and
    reproducible (``seed_offset`` separates them under one config)."""

    def __init__(self, cfg: SensorConfig = LOSSLESS, seed_offset: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed + 15485863 * (seed_offset + 1))
        self._next_sample = 0
        # last successfully observed per-device start rows, for
        # ``impute_dropout`` (the ROADMAP dropout-shadowing mitigation)
        self._last_starts = None

    # ------------------------------------------------------------- sampling
    def take_sample(self, iteration: int) -> bool:
        """True when this iteration is observed.  Without jitter the poll
        grid is anchored to absolute iteration numbers (``iteration %
        sample_period == 0``) — exactly the oracle manager's sampling
        rule, so a lossless sensor at the manager's period reproduces the
        oracle schedule no matter when the manager was enabled.  With
        ``phase_jitter`` the next sample lands ``sample_period ± jitter``
        iterations after the previous one — the drift a wall-clock poller
        shows against the iteration clock."""
        cfg = self.cfg
        if cfg.sample_period <= 1 and cfg.phase_jitter == 0:
            return True
        if cfg.phase_jitter == 0:
            return iteration % cfg.sample_period == 0
        if iteration < self._next_sample:
            return False
        j = int(self.rng.integers(-cfg.phase_jitter, cfg.phase_jitter + 1))
        self._next_sample = iteration + max(1, cfg.sample_period + j)
        return True

    # ---------------------------------------------------------- observation
    def observe_times(self, t: np.ndarray) -> np.ndarray:
        """Timestamps (any shape): additive noise then clock quantization.
        Lossless config returns the input unchanged (no RNG consumed)."""
        cfg = self.cfg
        if cfg.noise_time_s == 0 and cfg.quant_time_s == 0:
            return t
        out = np.asarray(t, float)
        if cfg.noise_time_s > 0:
            out = out + self.rng.normal(0.0, cfg.noise_time_s, out.shape)
        return _quantize(out, cfg.quant_time_s)

    def observe_power(self, p: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        if cfg.noise_power_w == 0 and cfg.quant_power_w == 0:
            return p
        out = np.asarray(p, float)
        if cfg.noise_power_w > 0:
            out = out + self.rng.normal(0.0, cfg.noise_power_w, out.shape)
        return _quantize(out, cfg.quant_power_w)

    def observe_temp(self, t: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        if cfg.noise_temp_c == 0 and cfg.quant_temp_c == 0:
            return t
        out = np.asarray(t, float)
        if cfg.noise_temp_c > 0:
            out = out + self.rng.normal(0.0, cfg.noise_temp_c, out.shape)
        return _quantize(out, cfg.quant_temp_c)

    def drop_mask(self, n_devices: int) -> np.ndarray:
        """(G,) bool: True where this sample's per-device reading is lost."""
        if self.cfg.dropout_p <= 0:
            return np.zeros(n_devices, bool)
        return self.rng.random(n_devices) < self.cfg.dropout_p

    def observe_starts(self, start: np.ndarray) -> np.ndarray:
        """The Algorithm-1 input path: (G, K) kernel-start timestamps →
        noisy/quantized observation with dropped devices as NaN rows
        (lead_value_detect maps NaN starts to zero lead, so a dropped
        device is indistinguishable from the straggler that sample — a
        real failure mode the robustness studies quantify).

        With ``impute_dropout`` a dropped device's row is replaced by its
        last successfully observed row instead of NaN: kernel starts drift
        slowly between samples, so the stale lead stays near the device's
        true lead and no longer shadows the straggler at argmin.  A device
        dropped before it was ever observed still reads NaN (there is
        nothing to hold).  The RNG stream is identical either way — the
        knob changes only what is reported, never what is drawn."""
        out = self.observe_times(start)
        drop = self.drop_mask(np.asarray(start).shape[0])
        if drop.any():
            held = self._last_starts
            out = np.array(out, float, copy=True)
            if (self.cfg.impute_dropout and held is not None
                    and held.shape == out.shape):
                out[drop] = held[drop]
            else:
                out[drop] = np.nan
        if self.cfg.impute_dropout:
            # remember the per-device rows that were actually observed (or
            # imputed — still the freshest value the consumer has seen)
            self._last_starts = np.array(out, float, copy=True)
        return out
