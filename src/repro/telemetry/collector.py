"""Streaming telemetry collector over the node/cluster simulators.

``TelemetryCollector`` attaches to a ``NodeSim`` or a ``ClusterSim`` and
records, per sampled iteration: kernel start/end matrices and overlap (the
Algorithm-1 input), per-device power / temperature / frequency / cap, and —
at cluster scope — the topology lead signal and fleet timing.  Manager
actions (cap schedules) are recorded when a ``PowerManager`` is handed the
collector.  All signals pass through a ``SensorModel`` first, so a trace is
either an exact record (lossless default — the replay bit-for-bit
guarantee) or a realistic degraded one (noise / quantization / sampling /
dropout studies).

Hooks fire inside ``NodeSim.commit`` and ``ClusterSim.step``, i.e. *after*
the engine produced the iteration — so every engine (event, batched,
vector) records identically; the collector never perturbs execution.

Buffers are bounded ring buffers (``deque(maxlen=...)``): a collector left
attached to a long-running fleet holds the most recent ``max_samples``
records at a fixed memory footprint instead of growing without bound.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.telemetry.lead import estimate_fleet_lead, topology_params
from repro.telemetry.sensors import LOSSLESS, SensorConfig, SensorModel

# seed_offset of the fleet-scope sensor (the poller that observes per-node
# iteration times for the lead estimate); far above any plausible node index
# so per-node RNG streams stay bit-identical whether or not a fleet is
# attached
FLEET_SENSOR_OFFSET = 10_000


@dataclass
class NodeSample:
    """One node's observed telemetry for one sampled iteration."""

    iteration: int
    node: int
    t_local: float                  # node-local iteration time (s)
    t_wall: float                   # committed (fleet-stretched) interval
    comp_start: np.ndarray          # (G, Kc) observed kernel starts
    comp_end: np.ndarray            # (G, Kc)
    overlap: np.ndarray             # (G, Kc) comm-overlap seconds (exact)
    power: np.ndarray               # (G,) observed W
    temp: np.ndarray                # (G,) observed °C
    freq: np.ndarray                # (G,) GHz (governor state, exact)
    cap: np.ndarray                 # (G,) W (manager-set, exact)
    truth_start: Optional[np.ndarray] = None  # kept when sensor is lossy


@dataclass
class FleetSample:
    """Cluster-scope signals for one sampled iteration."""

    iteration: int
    t_fleet: float
    lead: np.ndarray                # (N,) topology lead signal (ground truth)
    t_local: np.ndarray             # (N,) per-node local iteration times
    node_power: np.ndarray          # (N,) summed node power (W)
    topology: str
    lead_obs: Optional[np.ndarray] = None  # (N,) lead estimated from the
    #                                 fleet sensor's observed t_local stream
    #                                 (barrier-wait estimator) — what a real
    #                                 fleet manager would see; None on traces
    #                                 recorded before the fleet sensor existed
    t_obs: Optional[np.ndarray] = None     # (N,) the observed t_local vector
    #                                 itself (NaN where the node's sensor is
    #                                 dead) — the EscalationPolicy input, so
    #                                 drain decisions replay bit-for-bit
    tail: Optional[np.ndarray] = None      # (N,) serving tail signal
    #                                 (``ServingFleet._tail_signal``) — only
    #                                 on serve-scope rows; None on training
    #                                 fleets and on traces recorded before
    #                                 serving emitted fleet rows


@dataclass
class ManagerAction:
    """A mitigation decision: the cap/budget vector a manager applied.

    ``iteration`` is -1 when the manager's adjust path was driven directly
    (e.g. ``adjust_node_budgets``) rather than through ``on_iteration`` —
    the decision then belongs to no sampled iteration."""

    iteration: int
    kind: str                       # "caps" (node) | "budgets" (fleet)
    node: int                       # -1 for fleet-scope actions
    values: np.ndarray


@dataclass
class FaultRecord:
    """A discrete fault/escalation event, on the recording-relative
    iteration clock.  ``source="fault"`` rows are injected-fault onsets
    (``kind`` is a ``repro.core.faults.FAULT_KINDS`` entry); ``source=
    "escalation"`` rows are EscalationPolicy stage transitions (``kind``
    is a ``repro.core.escalate.STAGES`` entry).  ``node`` is the *global*
    node id — stable across post-drain fleet rebuilds."""

    iteration: int
    t_sim: float                    # simulated-seconds clock of the event
    kind: str
    node: int
    device: int = -1                # -1: node-scoped
    value: float = 0.0              # kind-specific (magnitude, ratio, ...)
    source: str = "fault"           # "fault" | "escalation"


@dataclass
class RequestRecord:
    """One inference request's lifecycle through a serving fleet
    (serve/*): every timestamp is on the owning node's simulated clock.
    Incomplete requests (still queued / in flight when the run ends) are
    flushed with NaN in the fields that never happened, so a trace always
    carries the *full* offered population — the offline SLO replay
    (``repro.serve.replay_slo``) recomputes every metric from these rows
    alone."""

    rid: int
    node: int
    t_arrival: float
    t_admit: float                  # NaN: never reached a batch slot
    t_first: float                  # NaN: prefill never completed
    t_done: float                   # NaN: decode incomplete at end of run
    prompt_len: int
    output_len: int
    tokens_out: int                 # decoded tokens actually produced

    @property
    def complete(self) -> bool:
        return self.t_done == self.t_done      # not NaN

    @property
    def ttft(self) -> float:
        """Time to first token (NaN until prefill completes)."""
        return self.t_first - self.t_arrival

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_arrival

    @property
    def tpot(self) -> float:
        """Mean per-output-token latency after the first token."""
        return (self.t_done - self.t_first) / max(self.output_len - 1, 1)


@dataclass
class TelemetryCollector:
    sensor_cfg: SensorConfig = LOSSLESS
    max_samples: int = 2048         # sampled iterations retained; a cluster
    #                                 attach scales the node ring by N so
    #                                 all buffers cover the same window
    keep_truth: bool = False        # store exact starts beside lossy ones
    with_kernels: bool = True       # False: drop (G,K) matrices (counters
    #                                 only — cheap long-horizon recording)
    meta: Dict = field(default_factory=dict)

    def __post_init__(self):
        self.samples: Deque[NodeSample] = deque(maxlen=self.max_samples)
        self.fleet: Deque[FleetSample] = deque(maxlen=self.max_samples)
        self.actions: Deque[ManagerAction] = deque(maxlen=self.max_samples)
        self.events: Deque[FaultRecord] = deque(maxlen=self.max_samples)
        # request records are never sampled (every completion matters for
        # the SLO quantiles) but stay ring-bounded; 8 requests/iteration
        # comfortably covers the registered serve scenarios' arrival rates
        self.requests: Deque[RequestRecord] = deque(
            maxlen=self.max_samples * 8)
        self._sensors: Dict[int, SensorModel] = {}
        self._fleet_sensor: Optional[SensorModel] = None
        self._last_iter: Optional[int] = None
        self._last_decision = False
        # pure observers (e.g. repro.obs.ObsPipeline): each appended record
        # is forwarded to every observer, after it enters the ring — the
        # observers see exactly the sampled stream a trace would carry, so
        # anything computed from them replays bit-for-bit offline
        self.observers: List = []

    # ------------------------------------------------------------ attaching
    def sensor_for(self, node_index: int) -> SensorModel:
        if node_index not in self._sensors:
            self._sensors[node_index] = SensorModel(
                self.sensor_cfg, seed_offset=node_index)
        return self._sensors[node_index]

    def fleet_sensor(self) -> SensorModel:
        """The cluster-scope observer: degrades the per-node ``t_local``
        vector the lead estimate is computed from.  A separate stream
        (``FLEET_SENSOR_OFFSET``) so the per-node kernel-start streams are
        bit-identical with or without a fleet attached."""
        if self._fleet_sensor is None:
            self._fleet_sensor = SensorModel(
                self.sensor_cfg, seed_offset=FLEET_SENSOR_OFFSET)
        return self._fleet_sensor

    def attach_node(self, node, node_index: int = 0) -> "TelemetryCollector":
        """Hook a ``NodeSim``: every subsequent ``commit`` is offered to the
        sampler.  Returns self so attach chains at construction sites."""
        node.collector = self
        self.sensor_for(node_index)
        node._telemetry_index = node_index
        # recording-relative clock: NodeSim's counter is already past its
        # thermal warmup at attach time (and a cluster's nodes are offset
        # from the cluster counter), so rebase every stream to "iterations
        # since recording started" — the same numbering a training loop
        # (run_closed_loop) drives the manager with
        node._telemetry_iter0 = node.iteration
        self.meta.setdefault("n_devices", node.G)
        self.meta.setdefault("tdp", float(node.preset.tdp))
        self.meta.setdefault("preset", node.preset.name)
        self.meta.setdefault("comp_names", list(node.sim.arrays["comp_names"]))
        self.meta.setdefault("comm_names", list(node.sim.arrays["comm_names"]))
        self.meta.setdefault("straggler_hint", {})
        self.meta["straggler_hint"][node_index] = int(
            node.thermal.straggler_hint)
        self.meta.setdefault("sensor", self.sensor_cfg.to_dict())
        return self

    def attach_cluster(self, cluster) -> "TelemetryCollector":
        """Hook a ``ClusterSim`` and all of its nodes.  The node-sample
        ring is rescaled to N x max_samples records so every buffer
        (node, fleet, actions) retains the same most-recent-
        ``max_samples``-iterations window — otherwise the fleet stream
        would outlive the node streams it is analyzed against."""
        cluster.collector = self
        cluster._telemetry_iter0 = cluster.iteration
        target_samples = self.max_samples * cluster.N
        target_actions = self.max_samples * (cluster.N + 1)
        # grow-only: re-attaching a *smaller* fleet (elastic restart after
        # a drain) must not shrink the ring and drop recorded history
        if (self.samples.maxlen or 0) < target_samples:
            self.samples = deque(self.samples, maxlen=target_samples)
        if (self.actions.maxlen or 0) < target_actions:
            self.actions = deque(self.actions, maxlen=target_actions)
        target_requests = self.max_samples * 8 * cluster.N
        if (self.requests.maxlen or 0) < target_requests:
            self.requests = deque(self.requests, maxlen=target_requests)
        for n, node in enumerate(cluster.nodes):
            self.attach_node(node, n)
        self.meta["n_nodes"] = cluster.N
        self.meta["topology"] = cluster.topology.name
        self.meta["topology_params"] = topology_params(cluster.topology)
        self.meta["node_tdps"] = [float(p.tdp) for p in cluster.presets]
        self.meta["straggler_node"] = int(cluster.cfg.straggler_node)
        return self

    # ------------------------------------------------------------- sampling
    def _sampled(self, iteration: int) -> bool:
        """One sampling decision per iteration, shared by every node of a
        fleet and the fleet record itself (node 0's sensor is the poller)."""
        if iteration == self._last_iter:
            return self._last_decision
        self._last_iter = iteration
        self._last_decision = self.sensor_for(0).take_sample(iteration)
        return self._last_decision

    # ---------------------------------------------------------------- hooks
    def on_node_commit(self, node, trace, t_interval: float,
                       iteration: int) -> None:
        idx = getattr(node, "_telemetry_index", 0)
        iteration -= getattr(node, "_telemetry_iter0", 0)
        if not self._sampled(iteration):
            return
        sensor = self.sensor_for(idx)
        lossy = not self.sensor_cfg.lossless
        if self.with_kernels:
            truth = np.array(trace.comp_start, float, copy=True)
            start = sensor.observe_starts(truth)
            end = sensor.observe_times(
                np.array(trace.comp_end, float, copy=True))
            ovl = np.array(trace.comp_overlap, float, copy=True)
        else:
            truth = start = end = ovl = np.empty((node.G, 0))
        s = node.state
        self.samples.append(NodeSample(
            iteration=iteration, node=idx,
            t_local=float(trace.t_iter), t_wall=float(t_interval),
            comp_start=start, comp_end=end, overlap=ovl,
            power=np.asarray(sensor.observe_power(s.power), float).copy(),
            temp=np.asarray(sensor.observe_temp(s.temp), float).copy(),
            freq=s.freq.copy(), cap=s.cap.copy(),
            truth_start=(truth if (lossy and self.keep_truth
                                   and self.with_kernels) else None)))
        for ob in self.observers:
            ob.on_node_sample(self.samples[-1])

    def on_cluster_step(self, cluster, traces) -> None:
        h = cluster.history[-1]
        iteration = int(h["iter"]) - getattr(cluster, "_telemetry_iter0", 0)
        if not self._sampled(iteration):
            return
        # what a real fleet manager sees: per-node iteration times through
        # the (possibly lossy) fleet sensor, folded into the topology-aware
        # lead estimate (telemetry/lead.py): exact for DP, exact 1F1B
        # arithmetic for PP, jitter-corrected barrier for TP — the residual
        # gap to the true lead is what fleet_lead_report quantifies
        # alongside the sensor noise.  A dead sensor reads as NaN; the
        # estimate degrades to the nodes still reporting (NaN where blind).
        # A lossless sensor draws nothing, so recording stays bit-for-bit.
        t_obs = np.asarray(self.fleet_sensor().observe_times(
            np.asarray(h["t_local"], float)), float).copy()
        dead = h.get("sensor_dead")
        if dead is not None and np.any(dead):
            t_obs[np.asarray(dead, bool)] = np.nan
        lead_obs = estimate_fleet_lead(
            t_obs, topology=str(h["topology"]),
            params=self.meta.get("topology_params"))
        self.fleet.append(FleetSample(
            iteration=iteration, t_fleet=float(h["t_fleet"]),
            lead=np.asarray(h["lead"], float).copy(),
            t_local=np.asarray(h["t_local"], float).copy(),
            node_power=np.asarray(h["node_power"], float).copy(),
            topology=str(h["topology"]),
            lead_obs=lead_obs, t_obs=t_obs))
        for ob in self.observers:
            ob.on_fleet_sample(self.fleet[-1])

    def on_manager_action(self, kind: str, iteration: int,
                          values: np.ndarray, node: int = -1) -> None:
        self.actions.append(ManagerAction(
            iteration=int(iteration), kind=kind, node=node,
            values=np.asarray(values, float).copy()))
        for ob in self.observers:
            ob.on_action(self.actions[-1])

    def on_fault_event(self, iteration: int, t_sim: float, kind: str,
                       node: int, device: int = -1, value: float = 0.0,
                       source: str = "fault") -> None:
        """Record a fault onset (``ClusterSim.step``) or an escalation
        stage transition (``EscalationPolicy`` via the healing runner)."""
        self.events.append(FaultRecord(
            iteration=int(iteration), t_sim=float(t_sim), kind=str(kind),
            node=int(node), device=int(device), value=float(value),
            source=str(source)))
        for ob in self.observers:
            ob.on_event(self.events[-1])

    def on_request(self, record: "RequestRecord") -> None:
        """Record one serving request's lifecycle (ServingFleet hook) —
        unsampled: SLO tails need the full population."""
        self.requests.append(record)
        for ob in self.observers:
            ob.on_request(record)

    def on_serve_round(self, round_index: int, t_local: np.ndarray,
                       tail: np.ndarray, topology: str = "serve") -> None:
        """Record a serving round as a fleet row: async replicas have no
        barrier, so ``t_fleet`` is the round's span (the slowest node's
        interval) and ``lead`` the shortfall behind it.  ``t_obs`` passes
        through the fleet sensor exactly like a training fleet row, so the
        straggler-ratio signal degrades with sensor fidelity the same way;
        ``tail`` is the per-node SLO tail signal (exact: it is engine
        state, not a sensor reading)."""
        if not self._sampled(int(round_index)):
            return
        t_local = np.asarray(t_local, float).copy()
        t_obs = np.asarray(self.fleet_sensor().observe_times(t_local),
                           float).copy()
        lead_obs = estimate_fleet_lead(t_obs, topology=str(topology))
        self.fleet.append(FleetSample(
            iteration=int(round_index), t_fleet=float(np.max(t_local)),
            lead=t_local.max() - t_local, t_local=t_local,
            node_power=np.array([float(np.sum(s.power)) for s in
                                 self._node_power_rows(round_index,
                                                       len(t_local))]),
            topology=str(topology),
            lead_obs=lead_obs, t_obs=t_obs,
            tail=np.asarray(tail, float).copy()))
        for ob in self.observers:
            ob.on_fleet_sample(self.fleet[-1])

    def _node_power_rows(self, iteration: int, n: int):
        """The iteration's node samples in node order (zero-power dummies
        where a node's sample is missing) — serve fleet rows reuse the
        power the commit hooks already observed rather than re-drawing."""
        rows = {s.node: s for s in self.samples
                if s.iteration == iteration}
        dummy = NodeSample(iteration=iteration, node=-1, t_local=0.0,
                           t_wall=0.0, comp_start=np.empty((0, 0)),
                           comp_end=np.empty((0, 0)),
                           overlap=np.empty((0, 0)),
                           power=np.zeros(1), temp=np.zeros(1),
                           freq=np.zeros(1), cap=np.zeros(1))
        return [rows.get(i, dummy) for i in range(n)]

    # ------------------------------------------------------------ accessors
    def node_samples(self, node: int = 0) -> List[NodeSample]:
        return [s for s in self.samples if s.node == node]

    def iterations(self) -> List[int]:
        return sorted({s.iteration for s in self.samples})

    def clear(self) -> None:
        """Drop all buffered records *and* rebuild the sensor models, so a
        recording started after clear() is bit-for-bit what a fresh
        collector with the same config would record (the sensors' RNG
        streams restart rather than continuing mid-stream)."""
        self.samples.clear()
        self.fleet.clear()
        self.actions.clear()
        self.events.clear()
        self.requests.clear()
        self._sensors = {}
        self._fleet_sensor = None
        self._last_iter = None
        self._last_decision = False
