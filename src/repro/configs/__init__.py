from repro.configs.base import (SHAPES, SMOKE_SHAPE, AudioConfig, ModelConfig,
                                MoEConfig, ParallelConfig, RWKVConfig,
                                ShapeConfig, SSMConfig, TrainConfig,
                                VisionConfig, reduce_config, shape_applicable)
from repro.configs.registry import (ASSIGNED_ARCHS, PAPER_ARCHS, get_config,
                                    get_reduced_config, get_shape, iter_cells,
                                    list_archs)

__all__ = [
    "SHAPES", "SMOKE_SHAPE", "AudioConfig", "ModelConfig", "MoEConfig",
    "ParallelConfig", "RWKVConfig", "ShapeConfig", "SSMConfig", "TrainConfig",
    "VisionConfig", "reduce_config", "shape_applicable", "ASSIGNED_ARCHS",
    "PAPER_ARCHS", "get_config", "get_reduced_config", "get_shape",
    "iter_cells", "list_archs",
]
