"""mistral-7b-v0.1 — the paper's second workload (Table II).  32L d_model=4096
32H (GQA kv=8) d_ff=14336 vocab=32000; sliding-window 4096.
[hf:mistralai/Mistral-7B-v0.1; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=32000,
    activation="silu",
    gated_mlp=True,
    attention="sliding",
    window=4096,
    rope_theta=10_000.0,
    max_seq_len=8192,
    source="[hf:mistralai/Mistral-7B-v0.1; hf] (paper Table II workload)",
)
