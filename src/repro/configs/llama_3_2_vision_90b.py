"""llama-3.2-vision-90b [vlm] — 100L (80 self + 20 cross-attn, every 5th)
d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; vision frontend STUBBED:
input_specs() provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,                    # counts both self- and cross-attn layers
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=128256,
    activation="silu",
    gated_mlp=True,
    rope_theta=500_000.0,
    max_seq_len=8192,
    vision=VisionConfig(vision_dim=1280, vision_seq=1601, cross_attn_every=5),
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)
