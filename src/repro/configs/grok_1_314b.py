"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,                      # per-expert hidden dim
    vocab_size=131072,
    activation="gelu",
    gated_mlp=True,
    logit_softcap=30.0,              # grok uses tanh soft-capping on logits
    rope_theta=10_000.0,
    max_seq_len=8192,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768),
    source="[hf:xai-org/grok-1; unverified]",
)
