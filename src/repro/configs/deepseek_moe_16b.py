"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=102400, MoE 2 shared + 64 routed top-6, fine-grained, first layer dense.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,                       # per (fine-grained) expert
    vocab_size=102400,
    activation="silu",
    gated_mlp=True,
    rope_theta=10_000.0,
    max_seq_len=4096,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  first_k_dense=1, d_ff_dense=10944),
    source="[arXiv:2401.06066; hf]",
)
