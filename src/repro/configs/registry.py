"""Architecture registry: ``--arch <id>`` -> ModelConfig.

The 10 assigned architectures plus the paper's own three workloads.  IDs match
the assignment exactly (dots and dashes); module names are sanitized.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig,
                                reduce_config, shape_applicable)

# arch-id -> module (under repro.configs)
_ARCH_MODULES: Dict[str, str] = {
    # --- assigned pool (10) -------------------------------------------------
    "grok-1-314b":          "grok_1_314b",
    "deepseek-moe-16b":     "deepseek_moe_16b",
    "whisper-medium":       "whisper_medium",
    "nemotron-4-15b":       "nemotron_4_15b",
    "qwen2.5-32b":          "qwen2_5_32b",
    "qwen3-4b":             "qwen3_4b",
    "deepseek-7b":          "deepseek_7b",
    "hymba-1.5b":           "hymba_1_5b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "rwkv6-3b":             "rwkv6_3b",
    # --- paper's own workloads (Table II, §VII-C) ----------------------------
    "llama3.1-8b":          "llama3_1_8b",
    "mistral-7b":           "mistral_7b",
    "deepseek-v3-16b":      "deepseek_v3_16b",
}

ASSIGNED_ARCHS: List[str] = list(_ARCH_MODULES)[:10]
PAPER_ARCHS: List[str] = list(_ARCH_MODULES)[10:]


def list_archs(include_paper: bool = True) -> List[str]:
    return list(_ARCH_MODULES) if include_paper else list(ASSIGNED_ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    return reduce_config(get_config(arch))


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def iter_cells(include_paper: bool = False):
    """Yield every applicable (arch, shape) dry-run cell (+ skip records)."""
    for arch in list_archs(include_paper):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            yield arch, shape.name, shape_applicable(cfg, shape)
