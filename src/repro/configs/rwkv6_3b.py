"""rwkv6-3b [ssm] — Finch: 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536; data-dependent decay.  [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,                      # 2560 / head_dim 64
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,                       # channel-mix hidden (3.5x)
    vocab_size=65536,
    attention="none",
    pos_embedding="none",
    rope_theta=0.0,
    max_seq_len=1_048_576,           # state-based: effectively unbounded
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, ffn_mult=3.5),
    source="[arXiv:2404.05892; hf]",
)
