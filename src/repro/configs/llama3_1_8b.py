"""llama-3.1-8b — the paper's default workload (Table II).  32L d_model=4096
32H (GQA kv=8) d_ff=14336 vocab=128256.  [hf:meta-llama/Llama-3.1-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=128256,
    activation="silu",
    gated_mlp=True,
    rope_theta=500_000.0,
    max_seq_len=8192,
    source="[hf:meta-llama/Llama-3.1-8B; hf] (paper Table II workload)",
)
