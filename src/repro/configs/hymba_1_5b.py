"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16; parallel attention + mamba heads in each block; sliding-window
attention except global layers {first, middle, last}.  [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    activation="silu",
    gated_mlp=True,
    attention="sliding",
    window=1024,
    global_attn_layers=(0, 15, 31),
    rope_theta=10_000.0,
    max_seq_len=8192,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="[arXiv:2411.13676; hf]",
)
