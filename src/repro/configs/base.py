"""Config system: model/shape/mesh/run dataclasses shared by the whole framework.

Every assigned architecture is a ``ModelConfig`` instance in its own module
under ``repro.configs``; the registry maps ``--arch <id>`` to it.  Shapes are
the four assigned input-shape cells (train_4k / prefill_32k / decode_32k /
long_500k).  ``param_count``/``active_param_count`` feed the roofline's
MODEL_FLOPS = 6·N·D term.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# --------------------------------------------------------------------------- #
# Sub-configs
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (routed + optional shared experts)."""

    n_experts: int
    top_k: int
    d_expert: int                      # per-expert hidden dim
    n_shared: int = 0                  # always-on shared experts
    router: str = "softmax"            # "softmax" | "sigmoid" (deepseek-v3)
    capacity_factor: float = 1.25      # padded dispatch capacity (paper: padded GEMMs)
    aux_loss_weight: float = 0.01      # load-balancing auxiliary loss
    first_k_dense: int = 0             # leading layers that use a dense MLP
    d_ff_dense: int = 0                # dense-MLP hidden dim for those layers


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM config (hymba's parallel SSM heads)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix / channel-mix config."""

    head_dim: int = 64
    decay_lora: int = 64               # low-rank dim for data-dependent decay
    mix_lora: int = 32                 # low-rank dim for the 5-way token-shift mix
    ffn_mult: float = 3.5              # channel-mix hidden = ffn_mult * d_model


@dataclass(frozen=True)
class VisionConfig:
    """Stub modality frontend: inputs are precomputed patch embeddings."""

    vision_dim: int = 1280             # dim of precomputed patch embeddings
    vision_seq: int = 1601             # patches per image (stubbed frontend)
    cross_attn_every: int = 5          # every k-th layer is a cross-attn layer


@dataclass(frozen=True)
class AudioConfig:
    """Stub audio frontend: inputs are precomputed mel-frame embeddings."""

    frame_dim: int = 80                # mel bins of precomputed frames
    frame_seq: int = 1500              # encoder positions (whisper: 30 s / 20 ms)


# --------------------------------------------------------------------------- #
# Model config
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | encdec | hybrid | vlm | rwkv
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                    # 0 -> d_model // n_heads
    # --- block options ------------------------------------------------------
    activation: str = "silu"           # silu | squared_relu | gelu
    gated_mlp: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"        # rope | learned | none
    tie_embeddings: bool = False
    attention: str = "full"            # full | sliding | none
    window: int = 0                    # sliding-window size
    global_attn_layers: tuple = ()     # layers forced to full attention (hymba)
    logit_softcap: float = 0.0         # grok-style tanh soft-capping (0 = off)
    max_seq_len: int = 131_072
    # --- family extensions ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    vision: Optional[VisionConfig] = None
    audio: Optional[AudioConfig] = None
    enc_layers: int = 0                # encoder depth for enc-dec (whisper)
    # --- numerics ------------------------------------------------------------
    param_dtype: str = "float32"       # master/param dtype for training
    compute_dtype: str = "bfloat16"    # activation/matmul dtype
    serve_dtype: str = "bfloat16"      # weight dtype for inference
    # --- provenance ----------------------------------------------------------
    source: str = ""                   # [source; verified-tier] from assignment

    # ------------------------------------------------------------------ utils
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Whether long-context decode (long_500k) is feasible."""
        if self.family in ("rwkv",):
            return True
        if self.family == "hybrid":
            return self.attention == "sliding"
        return self.attention == "sliding"

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode path (whisper is enc-dec)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- param accounting
    def _mlp_params(self, d_ff: int) -> int:
        mats = 3 if self.gated_mlp else 2
        return mats * self.d_model * d_ff

    def _attn_params(self) -> int:
        p = self.d_model * self.q_dim            # Wq
        p += 2 * self.d_model * self.kv_dim      # Wk, Wv
        p += self.q_dim * self.d_model           # Wo
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        return p

    def _ssm_params(self) -> int:
        if self.ssm is None:
            return 0
        c = self.ssm
        d_inner = c.expand * self.d_model
        dt_rank = c.dt_rank or -(-self.d_model // 16)
        p = self.d_model * 2 * d_inner           # in_proj (x, z)
        p += d_inner * c.d_conv                  # depthwise conv
        p += d_inner * (dt_rank + 2 * c.d_state) # x -> (dt, B, C)
        p += dt_rank * d_inner                   # dt proj
        p += d_inner * c.d_state                 # A_log
        p += d_inner                             # D
        p += d_inner * self.d_model              # out proj
        return p

    def _rwkv_layer_params(self) -> int:
        c = self.rwkv
        d = self.d_model
        # time-mix: r,k,v,g,o projections + low-rank decay + low-rank mix + ln_x
        p = 5 * d * d
        p += 2 * d * c.decay_lora
        p += 5 * 2 * d * c.mix_lora              # 5-way token-shift mix LoRA
        p += 2 * d                               # per-head group-norm (ln_x)
        p += 2 * (d // c.head_dim) * c.head_dim  # time_first/time_decay bases
        # channel-mix: k (d->h), v (h->d), r (d->d)
        h = int(c.ffn_mult * d)
        p += d * h + h * d + d * d
        return p

    def layer_params(self, layer_idx: int) -> int:
        """Parameter count of one decoder layer (norms excluded: negligible)."""
        if self.family == "rwkv":
            return self._rwkv_layer_params()
        p = self._attn_params()
        if self.family == "hybrid":
            p += self._ssm_params()
        if self.family == "vlm" and self.vision is not None:
            k = self.vision.cross_attn_every
            if (layer_idx + 1) % k == 0:
                p += self._attn_params()         # extra cross-attn projections
        if self.moe is not None:
            if layer_idx < self.moe.first_k_dense:
                p += self._mlp_params(self.moe.d_ff_dense or self.d_ff)
            else:
                n = self.moe.n_experts + self.moe.n_shared
                p += n * self._mlp_params(self.moe.d_expert)
                p += self.d_model * self.moe.n_experts   # router
        else:
            p += self._mlp_params(self.d_ff)
        return p

    def active_layer_params(self, layer_idx: int) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.moe is None or layer_idx < (self.moe.first_k_dense or 0):
            return self.layer_params(layer_idx)
        p = self._attn_params()
        if self.family == "hybrid":
            p += self._ssm_params()
        k = self.moe.top_k + self.moe.n_shared
        p += k * self._mlp_params(self.moe.d_expert)
        p += self.d_model * self.moe.n_experts
        return p

    def param_count(self) -> int:
        p = sum(self.layer_params(i) for i in range(self.n_layers))
        emb = self.vocab_size * self.d_model
        p += emb if self.tie_embeddings else 2 * emb
        if self.pos_embedding == "learned":
            p += self.max_seq_len * self.d_model
        if self.enc_layers:                      # whisper encoder (dense MHA+MLP)
            enc = self.enc_layers * (4 * self.d_model * self.d_model
                                     + 2 * self.d_model * self.d_ff)
            dec_cross = self.n_layers * self._attn_params()  # decoder cross-attn
            p += enc + dec_cross
        if self.vision is not None:
            p += self.vision.vision_dim * self.d_model      # connector proj
        if self.audio is not None:
            p += self.audio.frame_dim * self.d_model        # conv-stub proj
        return p

    def active_param_count(self) -> int:
        p = sum(self.active_layer_params(i) for i in range(self.n_layers))
        emb = self.vocab_size * self.d_model
        p += emb if self.tie_embeddings else 2 * emb
        if self.enc_layers:
            p += self.enc_layers * (4 * self.d_model * self.d_model
                                    + 2 * self.d_model * self.d_ff)
            p += self.n_layers * self._attn_params()
        return p


# --------------------------------------------------------------------------- #
# Input shapes (assigned cells)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """Spec: long_500k needs sub-quadratic attention; decode needs a decoder."""
    if shape.name == "long_500k":
        return model.is_subquadratic
    if shape.kind == "decode":
        return model.has_decoder
    return True


# --------------------------------------------------------------------------- #
# Run / parallelism config
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ParallelConfig:
    multi_pod: bool = False
    fsdp_over_pod: Optional[bool] = None   # None -> auto (>=30B params)
    sequence_parallel: bool = True         # SP residual-stream sharding
    remat_policy: str = "nothing"          # nothing | dots | full
    scan_layers: bool = True
    explicit_overlap: bool = False         # shard_map prefetch FSDP variant
    grad_compression: str = "none"         # none | int8 (pod-axis RS)

    def fsdp_axes(self, model: ModelConfig) -> tuple:
        over_pod = self.fsdp_over_pod
        if over_pod is None:
            over_pod = model.param_count() >= 30e9
        if self.multi_pod and over_pod:
            return ("pod", "data")
        return ("data",)

    def batch_axes(self) -> tuple:
        return ("pod", "data") if self.multi_pod else ("data",)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss_weight: float = 1e-4
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3


# --------------------------------------------------------------------------- #
# Reduced (smoke) configs
# --------------------------------------------------------------------------- #
def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to laptop scale, preserving family features.

    Used by per-arch smoke tests: same block structure (MoE routing, ssm,
    cross-attn interleave, enc-dec, qk-norm, ...) at tiny dims.
    """
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=256,
        window=min(cfg.window, 32) if cfg.window else 0,
    )
    if cfg.global_attn_layers:
        kw["global_attn_layers"] = (0, kw["n_layers"] - 1)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            n_shared=min(cfg.moe.n_shared, 1),
            d_expert=32,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            d_ff_dense=128 if cfg.moe.first_k_dense else 0,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=8)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=16, decay_lora=8,
                                         mix_lora=8)
    if cfg.vision is not None:
        kw["vision"] = dataclasses.replace(cfg.vision, vision_dim=32,
                                           vision_seq=16, cross_attn_every=2)
        kw["n_layers"] = 4
    if cfg.audio is not None:
        kw["audio"] = dataclasses.replace(cfg.audio, frame_dim=16, frame_seq=32)
    if cfg.enc_layers:
        kw["enc_layers"] = 2
    return cfg.replace(name=cfg.name + "-reduced", **kw)


SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 4)
