"""whisper-medium [audio] — enc-dec, 24L enc + 24L dec, d_model=1024 16H (MHA)
d_ff=4096 vocab=51865; conv frontend STUBBED: input_specs() provides
precomputed mel-frame embeddings.  [arXiv:2212.04356; unverified]"""
from repro.configs.base import AudioConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,                     # decoder layers
    enc_layers=24,                   # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    pos_embedding="learned",
    rope_theta=0.0,
    max_seq_len=448,                 # decoder positions (whisper max target len)
    audio=AudioConfig(frame_dim=80, frame_seq=1500),
    source="[arXiv:2212.04356; unverified]",
)
