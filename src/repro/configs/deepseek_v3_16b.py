"""deepseek-v3-16b — the paper's MoE workload (§VII-C, trained with Primus/
torchtitan, 8-way expert parallel).  DeepSeek-MoE-16B dims with V3-style
sigmoid routing.  [arXiv:2412.19437 + 2401.06066]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=102400,
    activation="silu",
    gated_mlp=True,
    rope_theta=10_000.0,
    max_seq_len=4096,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  router="sigmoid", first_k_dense=1, d_ff_dense=10944),
    source="[arXiv:2412.19437; paper §VII-C MoE workload]",
)
