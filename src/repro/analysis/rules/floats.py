"""RPL007 — bare float equality in replay / equivalence paths.

The replay and detection layers compare *modeled* quantities that are
reconstructed through arithmetic — comparing them with ``==`` against a
float literal encodes an exactness assumption that holds only until
someone reorders an operation.  Where the contract genuinely IS
bit-identity (trace replay equivalence), the comparison belongs on the
encoded artifact values or behind ``np.array_equal`` with an explicit
comment; a threshold belongs in ``math.isclose`` / ``np.isclose`` or an
ordered comparison.

Flagged: ``==`` / ``!=`` where either operand is a non-integral float
literal (or a ``float(...)`` cast), inside the replay/equivalence
surfaces.  Comparisons against ``0.0`` exactly are allowed — testing
"was this ever set" against the additive identity is well-defined.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.linter import FileCtx, Finding
from repro.analysis.rules import Rule, call_name, path_in

_SURFACES = ("src/repro/telemetry", "src/repro/obs",
             "src/repro/serve/metrics.py", "src/repro/core/escalate.py",
             "src/repro/core/detect.py")


def _float_operand(expr: ast.AST) -> bool:
    if isinstance(expr, ast.Constant) and isinstance(expr.value, float):
        return expr.value != 0.0
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        return _float_operand(expr.operand)
    return call_name(expr) == "float"


def _check(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Compare):
            continue
        ops_operands = zip(node.ops, [node.left] + node.comparators,
                           node.comparators)
        for op, left, right in ops_operands:
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            if _float_operand(left) or _float_operand(right):
                yield ctx.finding(
                    "RPL007", node,
                    "bare float ==/!= against a float literal in a "
                    "replay/equivalence path — use math.isclose / "
                    "np.isclose (tolerance) or an ordered comparison")
                break


RPL007 = Rule(
    id="RPL007",
    title="bare float equality in replay/equivalence paths",
    rationale="exact float comparison against a literal encodes an "
              "operation-order assumption; replay equivalence is defined "
              "on encoded artifact values, not intermediate arithmetic",
    scope=path_in(*_SURFACES),
    check_file=_check,
)
