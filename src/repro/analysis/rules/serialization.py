"""RPL003 + RPL006 — artifact serialization discipline.

Every JSON artifact this repo writes (telemetry traces, sweep results,
metrics/incident logs, checkpoints, CLI reports) participates in two
contracts:

  * **byte-determinism** — equal payload ⇒ equal bytes, so replay
    equivalence and CI diffing work.  ``json.dump(s)`` must pass
    ``sort_keys=True`` (dict insertion order is an implementation detail
    of the writer, not part of the payload) and ``allow_nan=False``
    (bare ``NaN``/``Infinity`` tokens are not JSON; readers in other
    runtimes reject them).  Non-finite floats go through the
    ``{"$float": "nan" | "inf" | "-inf"}`` envelope (api/spec.py) or a
    writer-local null encoding — RPL003 additionally flags NaN/Inf
    *literals* fed straight into a dump call.

  * **schema registration (RPL006)** — any ``{"format": ..., "version":
    ...}`` envelope a writer emits must name a format declared in
    ``repro.analysis.schema_registry.SCHEMAS`` at the registered version,
    so artifact formats cannot fork silently.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Tuple

from repro.analysis.linter import FileCtx, Finding
from repro.analysis.rules import (Rule, call_name, dotted_name,
                                  module_int_constants,
                                  module_str_constants, path_not_in)
from repro.analysis.schema_registry import SCHEMAS

_DUMPS = {"json.dump", "json.dumps"}
_NONFINITE_NAMES = {"math.nan", "math.inf", "np.nan", "np.inf", "np.NaN",
                    "np.NAN", "np.Inf", "numpy.nan", "numpy.inf"}


def _kwarg(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _is_const(node: Optional[ast.expr], value) -> bool:
    return (isinstance(node, ast.Constant) and node.value is value)


def _nonfinite_literal(expr: ast.AST) -> Optional[str]:
    """'float("nan")' / 'math.inf' token if expr IS a non-finite literal."""
    if (isinstance(expr, ast.Call) and call_name(expr) == "float"
            and expr.args and isinstance(expr.args[0], ast.Constant)
            and isinstance(expr.args[0].value, str)
            and expr.args[0].value.lower().lstrip("+-") in ("nan", "inf",
                                                            "infinity")):
        return f'float("{expr.args[0].value}")'
    name = dotted_name(expr)
    if name in _NONFINITE_NAMES:
        return name
    return None


def _check_dump_calls(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if call_name(node) not in _DUMPS:
            continue
        fn = call_name(node)
        if not _is_const(_kwarg(node, "allow_nan"), False):
            yield ctx.finding(
                "RPL003", node,
                f"{fn}() without allow_nan=False — a NaN/Inf that slips "
                f"into the payload becomes a bare non-JSON token; escape "
                f"non-finite floats via the {{\"$float\": ...}} envelope "
                f"and dump with allow_nan=False")
        if not _is_const(_kwarg(node, "sort_keys"), True):
            yield ctx.finding(
                "RPL003", node,
                f"{fn}() without sort_keys=True — dict insertion order "
                f"leaks into artifact bytes and breaks byte-determinism")
        for sub in ast.walk(node):
            tok = _nonfinite_literal(sub)
            if tok is not None:
                yield ctx.finding(
                    "RPL003", sub,
                    f"non-finite literal {tok} fed to {fn}() — encode it "
                    f"through the {{\"$float\": ...}} envelope instead")


RPL003 = Rule(
    id="RPL003",
    title="json.dump(s) missing allow_nan=False/sort_keys=True, or raw "
          "NaN/Inf in the payload",
    rationale="artifact bytes must be deterministic and strictly-valid "
              "JSON: replay equivalence diffs them, and non-Python "
              "readers reject bare NaN tokens",
    scope=path_not_in("tests"),
    check_file=_check_dump_calls,
)


def _envelope_values(ctx: FileCtx,
                     d: ast.Dict) -> Optional[Tuple[object, object,
                                                    ast.AST]]:
    """(format_value, version_value, anchor_node) for a dict literal that
    carries both a "format" and a "version" key; Name values resolve
    through module-level constants, unresolvable values come back as
    Ellipsis (checked for registration by name only)."""
    strs = module_str_constants(ctx.tree)
    ints = module_int_constants(ctx.tree)

    def resolve(expr: ast.expr, consts: Dict) -> object:
        if isinstance(expr, ast.Constant):
            return expr.value
        if isinstance(expr, ast.Name) and expr.id in consts:
            return consts[expr.id]
        return Ellipsis

    fmt = ver = None
    for k, v in zip(d.keys, d.values):
        if isinstance(k, ast.Constant) and k.value == "format":
            fmt = resolve(v, strs)
        elif isinstance(k, ast.Constant) and k.value == "version":
            ver = resolve(v, ints)
    if fmt is None or ver is None:
        return None
    return fmt, ver, d


def _check_envelopes(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Dict):
            continue
        env = _envelope_values(ctx, node)
        if env is None:
            continue
        fmt, ver, anchor = env
        if fmt is Ellipsis:
            continue                    # dynamic format: reader-side code
        if fmt not in SCHEMAS:
            yield ctx.finding(
                "RPL006", anchor,
                f"artifact envelope declares format {fmt!r} which is not "
                f"registered in repro.analysis.schema_registry.SCHEMAS",
                snippet=f"format={fmt}")
        elif ver is not Ellipsis and ver != SCHEMAS[fmt]:
            yield ctx.finding(
                "RPL006", anchor,
                f"artifact envelope writes {fmt!r} version {ver}, but the "
                f"schema registry declares version {SCHEMAS[fmt]} — bump "
                f"both together",
                snippet=f"format={fmt} version={ver}")


RPL006 = Rule(
    id="RPL006",
    title="artifact format/version envelope not declared in the schema "
          "registry",
    rationale="every on-disk artifact format is declared once in "
              "schema_registry.SCHEMAS; writers drifting from it fork "
              "the format silently",
    scope=path_not_in("tests"),
    check_file=_check_envelopes,
)
