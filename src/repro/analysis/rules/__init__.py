"""Rule registry + the small AST vocabulary the rule modules share.

A rule is a dataclass with an id (``RPLnnn``), a one-line title, a scope
predicate over repo-relative posix paths, and one or both of:

  * ``check_file(ctx)``      — per-file visitor, yields Findings;
  * ``check_project(ctxs)``  — cross-file analysis over the whole lint set
                               (RPL005 engine parity needs to compare
                               modules against each other).

``FileCtx`` carries the parsed tree, the source, and a parent map so rules
can climb from a node to its enclosing statement (RPL004 needs to know
whether an unordered producer sits under a ``sorted()``).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional

from repro.analysis.linter import FileCtx, Finding


@dataclass(frozen=True)
class Rule:
    id: str
    title: str
    rationale: str                  # which repo contract the rule protects
    scope: Callable[[str], bool]    # repo-relative posix path -> in scope?
    check_file: Optional[Callable[[FileCtx], Iterable[Finding]]] = None
    check_project: Optional[
        Callable[[Dict[str, FileCtx]], Iterable[Finding]]] = None


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.AST) -> Optional[str]:
    """Dotted name of a call's callee, else None."""
    return dotted_name(node.func) if isinstance(node, ast.Call) else None


def used_field_names(tree: ast.AST) -> set:
    """Every name a module reads attribute-style: ``x.name`` attribute
    accesses, string-literal subscripts ``d["name"]``, and
    ``getattr(x, "name", ...)`` literals — the cross-module usage signal
    RPL005 compares engine modules by."""
    names: set = set()
    for n in ast.walk(tree):
        if isinstance(n, ast.Attribute):
            names.add(n.attr)
        elif (isinstance(n, ast.Subscript)
              and isinstance(n.slice, ast.Constant)
              and isinstance(n.slice.value, str)):
            names.add(n.slice.value)
        elif (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
              and n.func.id in ("getattr", "hasattr") and len(n.args) >= 2
              and isinstance(n.args[1], ast.Constant)
              and isinstance(n.args[1].value, str)):
            names.add(n.args[1].value)
    return names


def dataclass_fields(tree: ast.AST, class_name: str) -> Optional[List[str]]:
    """Annotated field names of ``class_name`` in ``tree`` (declaration
    order), or None when the class is absent."""
    for n in ast.walk(tree):
        if isinstance(n, ast.ClassDef) and n.name == class_name:
            return [s.target.id for s in n.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)]
    return None


def module_str_constants(tree: ast.AST) -> Dict[str, str]:
    """Module-level ``NAME = "literal"`` string bindings."""
    out: Dict[str, str] = {}
    for n in tree.body if isinstance(tree, ast.Module) else []:
        if (isinstance(n, ast.Assign) and len(n.targets) == 1
                and isinstance(n.targets[0], ast.Name)
                and isinstance(n.value, ast.Constant)
                and isinstance(n.value.value, str)):
            out[n.targets[0].id] = n.value.value
    return out


def module_int_constants(tree: ast.AST) -> Dict[str, int]:
    """Module-level ``NAME = <int>`` bindings."""
    out: Dict[str, int] = {}
    for n in tree.body if isinstance(tree, ast.Module) else []:
        if (isinstance(n, ast.Assign) and len(n.targets) == 1
                and isinstance(n.targets[0], ast.Name)
                and isinstance(n.value, ast.Constant)
                and isinstance(n.value.value, bool) is False
                and isinstance(n.value.value, int)):
            out[n.targets[0].id] = n.value.value
    return out


def path_in(*prefixes: str) -> Callable[[str], bool]:
    """Scope predicate: path starts with any of the given prefixes."""
    def pred(path: str) -> bool:
        return any(path == p or path.startswith(p.rstrip("/") + "/")
                   or (p.endswith(".py") and path == p) for p in prefixes)
    return pred


def path_not_in(*prefixes: str) -> Callable[[str], bool]:
    inside = path_in(*prefixes)
    return lambda path: not inside(path)


# rule modules are imported at the bottom so they can use the helpers above
from repro.analysis.rules import (clock, floats, ordering, parity,  # noqa: E402
                                  rng, serialization)

RULES: Dict[str, Rule] = {
    r.id: r for r in (
        rng.RPL001,
        clock.RPL002,
        serialization.RPL003,
        ordering.RPL004,
        parity.RPL005,
        serialization.RPL006,
        floats.RPL007,
        clock.RPL008,
    )
}

__all__ = ["RULES", "Rule", "call_name", "dataclass_fields", "dotted_name",
           "module_int_constants", "module_str_constants", "path_in",
           "path_not_in", "used_field_names"]
