"""RPL001 — unseeded or wall-clock-seeded RNG outside tests/.

Every random draw in this repo must come from an explicitly seeded
generator: the ``[seed, k]`` prefix-stability of Monte-Carlo populations
and the bit-for-bit record/replay guarantee both die the moment a stream
seeds itself from process entropy or the wall clock.  Flagged:

  * ``np.random.default_rng()`` / ``np.random.Generator`` construction
    with no seed argument;
  * any RNG seeded from a call (``default_rng(time.time_ns())``,
    ``PRNGKey(int(time.time()))``, ``seed=os.getpid()`` ...) — a seed must
    be a literal or plumbed-through value, never freshly minted entropy;
  * the stdlib ``random`` module's global functions and unseeded
    ``random.Random()`` (hidden process-global state);
  * the legacy numpy global RNG (``np.random.normal`` & co. — global
    mutable state that any import can perturb);
  * ``jax.random.PRNGKey`` / ``jax.random.key`` with a float seed.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.linter import FileCtx, Finding
from repro.analysis.rules import Rule, call_name, dotted_name, path_not_in

_DEFAULT_RNG = {"np.random.default_rng", "numpy.random.default_rng",
                "random.default_rng", "default_rng"}
_STDLIB_GLOBAL = {"random.random", "random.randint", "random.seed",
                  "random.shuffle", "random.choice", "random.choices",
                  "random.uniform", "random.sample", "random.randrange",
                  "random.getrandbits", "random.gauss", "random.normalvariate"}
_NP_SEEDED_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "RandomState", "get_state", "set_state"}
_PRNG_KEY = {"jax.random.PRNGKey", "random.PRNGKey", "PRNGKey",
             "jax.random.key"}
_ENTROPY_CALLS = {"time.time", "time.time_ns", "time.monotonic",
                  "time.monotonic_ns", "time.perf_counter",
                  "time.perf_counter_ns", "os.getpid", "os.urandom",
                  "uuid.uuid4", "uuid.uuid1", "secrets.token_bytes",
                  "secrets.randbits", "datetime.now", "datetime.utcnow",
                  "datetime.datetime.now", "datetime.datetime.utcnow"}


def _seed_args(node: ast.Call):
    """The expressions that act as the seed: positional[0] and any
    seed-ish keyword."""
    if node.args:
        yield node.args[0]
    for kw in node.keywords:
        if kw.arg in ("seed", "key", "rng_seed"):
            yield kw.value


def _entropy_call_inside(expr: ast.AST):
    for sub in ast.walk(expr):
        name = call_name(sub)
        if name in _ENTROPY_CALLS:
            return name
    return None


def _check(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name is None:
            continue
        if name in _DEFAULT_RNG:
            if not node.args and not any(kw.arg == "seed" or kw.arg is None
                                         for kw in node.keywords):
                yield ctx.finding(
                    "RPL001", node,
                    "unseeded np.random.default_rng() — pass an explicit "
                    "seed so the stream is replayable")
                continue
        if name in _DEFAULT_RNG or name in _PRNG_KEY \
                or name == "random.Random":
            for seed in _seed_args(node):
                ent = _entropy_call_inside(seed)
                if ent is not None:
                    yield ctx.finding(
                        "RPL001", node,
                        f"RNG seeded from {ent}() — wall-clock/entropy "
                        f"seeds break replay; use a literal or a plumbed "
                        f"seed")
                    break
        if name in _PRNG_KEY:
            for seed in _seed_args(node):
                if (isinstance(seed, ast.Constant)
                        and isinstance(seed.value, float)):
                    yield ctx.finding(
                        "RPL001", node,
                        "float PRNGKey seed — key derivation truncates; "
                        "use an int literal or plumbed int")
        if name == "random.Random" and not node.args \
                and not node.keywords:
            yield ctx.finding(
                "RPL001", node,
                "unseeded random.Random() — pass an explicit seed")
        if name in _STDLIB_GLOBAL:
            yield ctx.finding(
                "RPL001", node,
                f"{name}() uses the process-global stdlib RNG — construct "
                f"a seeded random.Random / np.random.default_rng instead")
        if name and (name.startswith("np.random.")
                     or name.startswith("numpy.random.")):
            tail = name.rsplit(".", 1)[1]
            if tail not in _NP_SEEDED_OK and tail[:1].islower():
                yield ctx.finding(
                    "RPL001", node,
                    f"{name}() draws from numpy's legacy global RNG — use "
                    f"a seeded np.random.default_rng(...) generator")


RPL001 = Rule(
    id="RPL001",
    title="unseeded or wall-clock-seeded RNG outside tests/",
    rationale="[seed, k] prefix-stable Monte-Carlo populations and "
              "bit-for-bit record/replay require every stream to descend "
              "from an explicit seed",
    scope=path_not_in("tests"),
    check_file=_check,
)
