"""RPL005 — engine-parity drift across the numpy and jax engines.

The repo ships two implementations of the same simulation contract: the
numpy reference engine (``core/c3sim.py`` + ``core/cluster.py`` /
``core/topology.py``) and the jax engine (``core/jax_engine.py``).  The
parity tests assert float-identical trajectories — but they can only
catch drift in behavior they exercise.  This rule catches the *config*
form of drift mechanically: a ``SimConfig`` / ``ClusterConfig`` /
``Workload`` field that one engine side reads and the other silently
ignores means a knob that changes one engine's output and not the
other's.

Usage is over-approximated per module (any ``x.field`` attribute read,
``d["field"]`` literal subscript, or ``getattr(x, "field")``), so a
field consumed under a different object of the same name still counts —
false negatives are preferred over false positives here.  Fields that
legitimately flow indirectly (e.g. comm parameters folded into the jax
engine's ``comm_const`` by ``make_topology``) are accepted in the
reviewed baseline with a reason, keyed ``Class.field`` so the entry
expires if the declaration disappears.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.linter import FileCtx, Finding
from repro.analysis.rules import (Rule, dataclass_fields, path_in,
                                  used_field_names)

# (class, declaring module, (side-A modules, label), (side-B modules, label))
CONTRACTS: List[Tuple[str, str, Tuple[Tuple[str, ...], str],
                      Tuple[Tuple[str, ...], str]]] = [
    ("SimConfig", "src/repro/core/c3sim.py",
     (("src/repro/core/c3sim.py",), "the numpy engine (c3sim)"),
     (("src/repro/core/jax_engine.py",), "the jax engine")),
    ("ClusterConfig", "src/repro/core/cluster.py",
     (("src/repro/core/cluster.py", "src/repro/core/topology.py"),
      "the numpy cluster engine (cluster/topology)"),
     (("src/repro/core/jax_engine.py",), "the jax engine")),
    ("Workload", "src/repro/core/workload.py",
     (("src/repro/core/c3sim.py",), "the numpy engine (c3sim)"),
     (("src/repro/core/jax_engine.py",), "the jax engine")),
]

_SCOPE_PATHS = sorted({p for _, decl, (a, _l1), (b, _l2) in CONTRACTS
                       for p in (decl, *a, *b)})


def _field_node(tree: ast.AST, class_name: str,
                field: str) -> Optional[ast.AST]:
    for n in ast.walk(tree):
        if isinstance(n, ast.ClassDef) and n.name == class_name:
            for s in n.body:
                if (isinstance(s, ast.AnnAssign)
                        and isinstance(s.target, ast.Name)
                        and s.target.id == field):
                    return s
            return n
    return None


def _check_project(ctxs: Dict[str, FileCtx]) -> Iterator[Finding]:
    for cls, decl, (a_paths, a_label), (b_paths, b_label) in CONTRACTS:
        needed = (decl, *a_paths, *b_paths)
        if any(p not in ctxs for p in needed):
            continue                    # partial lint run: contract n/a
        fields = dataclass_fields(ctxs[decl].tree, cls)
        if fields is None:
            yield ctxs[decl].finding(
                "RPL005", ctxs[decl].tree,
                f"parity contract expects class {cls} declared in {decl} "
                f"— it is gone; update CONTRACTS in rules/parity.py",
                snippet=f"{cls}")
            continue
        used_a = set()
        for p in a_paths:
            used_a |= used_field_names(ctxs[p].tree)
        used_b = set()
        for p in b_paths:
            used_b |= used_field_names(ctxs[p].tree)
        for f in fields:
            one, other = None, None
            if f in used_a and f not in used_b:
                one, other = a_label, b_label
            elif f in used_b and f not in used_a:
                one, other = b_label, a_label
            if one is None:
                continue
            anchor = _field_node(ctxs[decl].tree, cls, f) \
                or ctxs[decl].tree
            yield ctxs[decl].finding(
                "RPL005", anchor,
                f"{cls}.{f} is read by {one} but not by {other} — the "
                f"engines would diverge when it changes; consume it on "
                f"both sides or baseline it with the indirect-flow "
                f"justification",
                snippet=f"{cls}.{f}")


RPL005 = Rule(
    id="RPL005",
    title="config field consumed by one engine but not the other",
    rationale="float-identical engine parity requires every SimConfig/"
              "ClusterConfig/Workload knob to influence both engines; a "
              "one-sided read is silent divergence",
    scope=path_in(*_SCOPE_PATHS),
    check_project=_check_project,
)
