"""RPL002 + RPL008 — wall-clock discipline.

The simulation, serving, telemetry and observability layers run on an
*injectable simulated clock*: every timestamp in a trace is derived from
step counts and modeled durations, which is what makes record/replay
bit-for-bit and lets tests drive time deterministically.  A single
``time.time()`` smuggled into those layers produces traces that can never
replay.

RPL002 flags wall-clock *calls* inside the clocked layers.  References
(``clock=time.perf_counter`` as an injectable default) are fine — only
``time.time()``-style call sites are violations.  Benchmarks measure real
elapsed time by design and live in the baseline, file-scoped.

RPL008 flags watchdog-style classes (``__init__`` taking a ``clock``
parameter) that fall back to a wall-clock callable instead of requiring
injection — whether as the parameter default (``clock=time.monotonic``)
or as a body fallback (``self.clock = time.monotonic if clock is None
else clock``).  Either way, constructing the object without arguments
looks pure but silently binds real time.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.linter import FileCtx, Finding
from repro.analysis.rules import Rule, call_name, dotted_name, path_in

WALL_CLOCKS = {
    "time.time", "time.time_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "date.today", "datetime.date.today",
}

_CLOCKED_LAYERS = ("src/repro/core", "src/repro/serve",
                   "src/repro/telemetry", "src/repro/obs",
                   "src/repro/launch", "benchmarks")


def _check_calls(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        name = call_name(node)
        if name in WALL_CLOCKS:
            yield ctx.finding(
                "RPL002", node,
                f"{name}() inside a clocked layer — timestamps here must "
                f"come from the injectable simulated clock, or the layer "
                f"can never replay bit-for-bit")


RPL002 = Rule(
    id="RPL002",
    title="wall-clock call inside a simulated-clock layer",
    rationale="core/serve/telemetry/obs/launch derive all timestamps from "
              "the injectable simulated clock; wall-clock calls there "
              "produce traces that cannot replay",
    scope=path_in(*_CLOCKED_LAYERS),
    check_file=_check_calls,
)


_CLOCK_PARAMS = ("clock", "now", "time_fn", "clock_fn")


def _wall_clock_ref(expr: ast.AST) -> Optional[str]:
    """Dotted wall-clock name referenced anywhere inside ``expr``."""
    for sub in ast.walk(expr):
        name = dotted_name(sub)
        if name in WALL_CLOCKS:
            return name
    return None


def _default_clock_classes(ctx: FileCtx) -> Iterator[Finding]:
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        for fn in cls.body:
            if not (isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and fn.name == "__init__"):
                continue
            args = fn.args
            params = args.args + args.kwonlyargs
            defaults = ([None] * (len(args.args) - len(args.defaults))
                        + list(args.defaults) + list(args.kw_defaults))
            clock_params = [p.arg for p in params
                            if p.arg in _CLOCK_PARAMS]
            if not clock_params:
                continue
            # wall-clock as the parameter default
            for param, default in zip(params, defaults):
                if param.arg not in _CLOCK_PARAMS or default is None:
                    continue
                name = _wall_clock_ref(default)
                if name is not None:
                    yield ctx.finding(
                        "RPL008", fn,
                        f"{cls.name}.__init__ defaults {param.arg!r} to "
                        f"{name} — default the clock to None and require "
                        f"injection (or explicit dt) so construction "
                        f"stays deterministic",
                        snippet=f"{cls.name}.__init__.{param.arg}")
            # wall-clock as a body fallback
            # (self.clock = time.monotonic if clock is None else clock)
            for stmt in fn.body:
                for sub in ast.walk(stmt):
                    name = dotted_name(sub)
                    if name in WALL_CLOCKS:
                        yield ctx.finding(
                            "RPL008", sub,
                            f"{cls.name}.__init__ falls back to {name} "
                            f"for {clock_params[0]!r} — require an "
                            f"injected clock (or explicit dt) instead of "
                            f"a wall-clock default",
                            snippet=f"{cls.name}.__init__.{clock_params[0]}")


RPL008 = Rule(
    id="RPL008",
    title="class defaults its clock parameter to wall time",
    rationale="a clock parameter defaulting to time.monotonic makes the "
              "zero-argument constructor silently nondeterministic; "
              "default to None and require injection",
    scope=path_in("src/repro"),
    check_file=_default_clock_classes,
)
