"""RPL004 — iteration over unordered collections in deterministic code.

JSONL artifact rows, metric aggregations and checkpoint discovery must
not depend on filesystem or hash ordering: ``os.listdir`` order is
whatever the kernel returns, ``Path.glob`` order is platform-defined,
and set iteration order varies with insertion history.  Any of those
feeding an emission path silently reorders artifact bytes between runs
— the exact class of bug byte-determinism tests can't catch unless the
environment happens to disagree.  Wrap the producer in ``sorted(...)``.

(Dict iteration is fine — Python dicts preserve insertion order, which
the writers control.)
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.linter import FileCtx, Finding
from repro.analysis.rules import Rule, call_name, path_not_in

_LISTING_ATTRS = {"listdir", "iterdir", "glob", "rglob", "scandir"}


def _under_sorted(ctx: FileCtx, node: ast.AST) -> bool:
    """True when some enclosing expression already sorts the producer."""
    for anc in ctx.ancestors(node):
        if isinstance(anc, ast.stmt):
            return False
        name = call_name(anc)
        if name in ("sorted", "min", "max", "len", "set", "frozenset"):
            return True
    return False


def _listing_call(node: ast.AST) -> Optional[str]:
    if not isinstance(node, ast.Call):
        return None
    fn = node.func
    if isinstance(fn, ast.Attribute) and fn.attr in _LISTING_ATTRS:
        return fn.attr
    if isinstance(fn, ast.Name) and fn.id in ("listdir", "scandir"):
        return fn.id
    return None


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return call_name(node) in ("set", "frozenset")


def _check(ctx: FileCtx) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        attr = _listing_call(node)
        if attr is not None and not _under_sorted(ctx, node):
            yield ctx.finding(
                "RPL004", node,
                f"{attr}() order is filesystem-defined — wrap the listing "
                f"in sorted(...) before it feeds artifacts or aggregation")
        if isinstance(node, (ast.For, ast.AsyncFor, ast.comprehension)):
            it = node.iter
            if _is_set_expr(it):
                anchor = it if hasattr(it, "lineno") else node
                yield ctx.finding(
                    "RPL004", anchor,
                    "iterating a set — order varies with insertion "
                    "history; iterate sorted(...) of it instead")


RPL004 = Rule(
    id="RPL004",
    title="unordered collection iteration (set / unsorted directory "
          "listing)",
    rationale="JSONL rows and aggregated metrics must not inherit "
              "filesystem or hash ordering, or artifact bytes reorder "
              "between runs",
    scope=path_not_in("tests"),
    check_file=_check,
)
