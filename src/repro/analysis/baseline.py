"""Versioned suppression file for reviewed, accepted violations.

A baseline entry names a rule, a path and either a ``snippet`` (the
finding's matching identity — the stripped source line, or
``Class.field`` for the parity rule) or ``"scope": "file"`` to accept a
whole file (benchmark timing harnesses are wall-clock *by design*).
Every entry carries a human ``reason``; the file is itself a registered
artifact (``repro-lint-baseline`` v1) written NaN-free and key-sorted —
the discipline RPL003 enforces everywhere else.

Line numbers are deliberately not part of the identity, so entries
survive edits elsewhere in the file; an entry that stops matching
anything is *stale* and fails the lint run until pruned (run with
``--update-baseline``) — accepted violations cannot silently outlive the
code they excused.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.linter import Finding

BASELINE_FORMAT = "repro-lint-baseline"
BASELINE_VERSION = 1

_UNREVIEWED = "UNREVIEWED: justify or fix, then edit this entry"


@dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    snippet: str = ""               # "" with scope="file"
    scope: str = "line"             # "line" | "file"
    reason: str = _UNREVIEWED

    def matches(self, f: Finding) -> bool:
        if self.rule != f.rule or self.path != f.path:
            return False
        return self.scope == "file" or self.snippet == f.snippet

    def to_dict(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "reason": self.reason}
        if self.scope == "file":
            d["scope"] = "file"
        else:
            d["snippet"] = self.snippet
        return d

    @classmethod
    def from_dict(cls, d: dict, where: str) -> "BaselineEntry":
        unknown = set(d) - {"rule", "path", "snippet", "scope", "reason"}
        if unknown:
            raise ValueError(f"{where}: unknown baseline entry key(s) "
                             f"{sorted(unknown)}")
        for k in ("rule", "path"):
            if k not in d:
                raise ValueError(f"{where}: baseline entry missing {k!r}")
        scope = d.get("scope", "line")
        if scope not in ("line", "file"):
            raise ValueError(f"{where}: bad baseline scope {scope!r}")
        if scope == "line" and "snippet" not in d:
            raise ValueError(f"{where}: line-scoped baseline entry needs "
                             f"a snippet")
        return cls(rule=d["rule"], path=d["path"],
                   snippet=d.get("snippet", ""), scope=scope,
                   reason=d.get("reason", _UNREVIEWED))


@dataclass
class Baseline:
    entries: List[BaselineEntry] = field(default_factory=list)
    path: Optional[str] = None

    @classmethod
    def empty(cls) -> "Baseline":
        return cls(entries=[])

    def apply(self, findings: List[Finding]) -> Tuple[List[Finding],
                                                      List[Finding],
                                                      List[dict]]:
        """(kept, suppressed, stale_entries).  An entry may suppress any
        number of findings (file scope, or a repeated identical line);
        stale = matched zero findings this run."""
        hit: Dict[BaselineEntry, int] = {e: 0 for e in self.entries}
        kept, suppressed = [], []
        for f in findings:
            match = next((e for e in self.entries if e.matches(f)), None)
            if match is None:
                kept.append(f)
            else:
                hit[match] += 1
                suppressed.append(f)
        stale = [e.to_dict() for e in self.entries if hit[e] == 0]
        return kept, suppressed, stale

    def to_dict(self) -> dict:
        ordered = sorted(self.entries,
                         key=lambda e: (e.rule, e.path, e.scope, e.snippet))
        return {"format": BASELINE_FORMAT, "version": BASELINE_VERSION,
                "entries": [e.to_dict() for e in ordered]}

    def save(self, path: str) -> None:
        text = json.dumps(self.to_dict(), indent=2, sort_keys=True,
                          allow_nan=False)
        Path(path).write_text(text + "\n")


def load_baseline(path: str) -> Baseline:
    p = Path(path)
    if not p.exists():
        raise FileNotFoundError(f"baseline file does not exist: {path}")
    data = json.loads(p.read_text())
    if not isinstance(data, dict) or data.get("format") != BASELINE_FORMAT:
        raise ValueError(f"{path}: not a {BASELINE_FORMAT} document")
    if int(data.get("version", 0)) > BASELINE_VERSION:
        raise ValueError(f"{path}: baseline version {data.get('version')} "
                         f"is newer than supported {BASELINE_VERSION}")
    entries = [BaselineEntry.from_dict(e, f"{path}[{i}]")
               for i, e in enumerate(data.get("entries", []))]
    return Baseline(entries=entries, path=path)


def update_baseline(old: Baseline, findings: List[Finding]) -> Baseline:
    """Refresh a baseline against the current findings: keep entries that
    still match (reasons preserved), drop stale ones, add UNREVIEWED
    entries for new findings.  The add/expire round-trip the CLI's
    ``--update-baseline`` exposes."""
    kept = [e for e in old.entries
            if any(e.matches(f) for f in findings)]
    covered = list(kept)
    added: List[BaselineEntry] = []
    for f in findings:
        if any(e.matches(f) for e in covered):
            continue
        e = BaselineEntry(rule=f.rule, path=f.path, snippet=f.snippet)
        covered.append(e)
        added.append(e)
    return Baseline(entries=kept + added, path=old.path)
