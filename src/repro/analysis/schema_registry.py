"""Central registry of artifact schemas: every ``format`` string a writer
may put in a JSON/JSONL envelope, with its current version.

This is the single source of truth rule RPL006 checks artifact writers
against: a dict literal ``{"format": X, "version": Y}`` anywhere in the
linted tree must resolve to an entry here, at the registered version.
Runtime modules keep their own constants (``TRACE_FORMAT`` & co.) for
import-cycle hygiene; ``tests/test_analysis.py`` pins each of them to this
table so the two cannot drift.

Adding a new artifact kind is a two-line change here (name + version) —
which is the point: the diff review sees every new on-disk schema in one
place, next to the versions readers already promise to support.
"""
from __future__ import annotations

SCHEMAS = {
    # telemetry JSONL traces (repro.telemetry.trace_io) and the Chrome
    # trace export's otherData stamp
    "lit-silicon-telemetry": 1,
    # declarative scenario specs (repro.api.spec)
    "lit-silicon-scenario": 1,
    # Monte-Carlo sweep specs and their result artifacts (repro.api.sweep)
    "lit-silicon-sweep-spec": 1,
    "lit-silicon-sweep": 1,
    # observability snapshots (repro.obs.metrics / repro.obs.incidents)
    "lit-silicon-metrics": 1,
    "lit-silicon-incidents": 1,
    # repro-lint's own artifacts (repro.analysis.report / .baseline)
    "repro-lint-report": 1,
    "repro-lint-baseline": 1,
}


def schema_version(name: str) -> int:
    """Registered version for ``name``; KeyError with the catalog when the
    format is not declared (the runtime mirror of rule RPL006)."""
    try:
        return SCHEMAS[name]
    except KeyError:
        raise KeyError(f"artifact format {name!r} is not declared in "
                       f"repro.analysis.schema_registry (known: "
                       f"{sorted(SCHEMAS)})") from None
