"""Human + JSON reporters over a LintResult.

The JSON report is itself a versioned artifact (``repro-lint-report`` v1,
declared in the schema registry) written with the very discipline RPL003
enforces — ``sort_keys=True, allow_nan=False`` — so the CI artifact is
byte-deterministic for a given tree."""
from __future__ import annotations

import json
from collections import Counter
from typing import Dict

from repro.analysis.linter import LintResult

REPORT_FORMAT = "repro-lint-report"
REPORT_VERSION = 1


def render_json(result: LintResult) -> str:
    from repro.analysis.rules import RULES
    counts = Counter(f.rule for f in result.findings)
    doc: Dict = {
        "format": REPORT_FORMAT,
        "version": REPORT_VERSION,
        "root": result.root,
        "files_scanned": result.files_scanned,
        "rules_run": list(result.rules_run),
        "rule_titles": {rid: RULES[rid].title for rid in result.rules_run},
        "counts": dict(sorted(counts.items())),
        "findings": [f.to_dict() for f in result.findings],
        "suppressed_count": len(result.suppressed),
        "stale_baseline": result.stale_baseline,
        "clean": result.clean,
        "exit_code": result.exit_code(),
    }
    return json.dumps(doc, indent=2, sort_keys=True, allow_nan=False)


def render_text(result: LintResult) -> str:
    from repro.analysis.rules import RULES
    out = []
    by_rule: Dict[str, list] = {}
    for f in result.findings:
        by_rule.setdefault(f.rule, []).append(f)
    for rid in sorted(by_rule):
        out.append(f"{rid} — {RULES[rid].title} "
                   f"({len(by_rule[rid])} finding(s))")
        for f in by_rule[rid]:
            out.append(f"  {f.format()}")
            if f.snippet:
                out.append(f"      {f.snippet}")
    for e in result.stale_baseline:
        ident = e.get("snippet") or "scope=file"
        out.append(f"stale baseline entry: {e['rule']} {e['path']} "
                   f"[{ident}] matches nothing — prune it "
                   f"(repro lint --update-baseline)")
    n = len(result.findings)
    out.append(f"repro-lint: {result.files_scanned} file(s), "
               f"{len(result.rules_run)} rule(s): "
               f"{n} finding(s), {len(result.suppressed)} baselined, "
               f"{len(result.stale_baseline)} stale baseline entr"
               f"{'y' if len(result.stale_baseline) == 1 else 'ies'}")
    if result.clean:
        out.append("clean — every invariant holds")
    return "\n".join(out)
