"""repro-lint: AST-based invariant checker for the repo's determinism,
replay and engine-parity contracts.

Every headline guarantee of this reproduction — bit-for-bit offline replay
of cap schedules, drain decisions and alerts; float-identical traces across
the event/batched/vector/jax engines; ``[seed, k]`` prefix-stable
Monte-Carlo populations — rests on coding invariants.  This package
mechanizes them as lint rules so a violation is rejected before it can rot
a guarantee the equivalence tests only catch after the fact:

  RPL001  unseeded / wall-clock-seeded RNG outside tests/
  RPL002  wall-clock calls where only the injectable simulated clock is
          legal (src/repro/{core,serve,telemetry,obs,launch}, benchmarks)
  RPL003  json.dump(s) without allow_nan=False + sort_keys=True, and NaN /
          Inf literals bypassing the {"$float": ...} envelope
  RPL004  unordered-collection iteration (sets, os.listdir, glob) feeding
          emission or aggregation
  RPL005  engine-parity drift: config dataclass fields read by one engine
          family but not the other
  RPL006  artifact writers emitting format/version keys not declared in
          the central schema registry
  RPL007  bare float == comparisons in replay/equivalence paths
  RPL008  Watchdog-style classes taking a default wall clock instead of an
          injected one

Entry points: ``python -m repro lint`` and ``scripts/check_invariants.py``
(the CI hook).  See docs/analysis.md for the rule catalog, the baseline
workflow and the exit-code contract.
"""
from repro.analysis.baseline import (Baseline, BaselineEntry, load_baseline,
                                     update_baseline)
from repro.analysis.linter import Finding, LintResult, lint_paths, run_lint
from repro.analysis.report import render_json, render_text
from repro.analysis.rules import RULES
from repro.analysis.schema_registry import SCHEMAS, schema_version

__all__ = [
    "Baseline", "BaselineEntry", "Finding", "LintResult", "RULES",
    "SCHEMAS", "lint_paths", "load_baseline", "render_json", "render_text",
    "run_lint", "schema_version", "update_baseline",
]
