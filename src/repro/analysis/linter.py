"""The rule engine: collect files, parse once, run per-file and
cross-file rule visitors, fold the baseline in, and hand a deterministic
``LintResult`` to the reporters.

Paths are handled repo-root-relative (posix) throughout, so rule scopes
("only inside src/repro/core") and baseline entries are stable across
checkouts and usable against fixture trees in tests (pass ``root=``).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

# directories never worth parsing (generated/caches/vendored test shims)
_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", "_shims",
              ".pytest_cache", "build", "dist"}


@dataclass(frozen=True, order=True)
class Finding:
    """One invariant violation, anchored to a source line.

    ``snippet`` is the matching identity the baseline keys on: the
    stripped source line for line rules, a ``Class.field`` token for the
    cross-module parity rule — line numbers deliberately stay out of the
    baseline so entries survive unrelated edits above them."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    snippet: str = ""

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "snippet": self.snippet}

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} {self.message}")


@dataclass
class FileCtx:
    """One parsed file, as the rules see it."""

    path: str                       # repo-root-relative posix path
    tree: ast.Module
    source: str
    lines: List[str] = field(default_factory=list)
    _parents: Optional[Dict[ast.AST, ast.AST]] = None

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str,
                snippet: Optional[str] = None) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.path, line=line,
                       col=getattr(node, "col_offset", 0) + 1,
                       message=message,
                       snippet=(self.line_at(line)
                                if snippet is None else snippet))

    def parents(self) -> Dict[ast.AST, ast.AST]:
        """child -> parent map over the whole tree (built lazily once)."""
        if self._parents is None:
            self._parents = {}
            for parent in ast.walk(self.tree):
                for child in ast.iter_child_nodes(parent):
                    self._parents[child] = parent
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        p = self.parents()
        while node in p:
            node = p[node]
            yield node


@dataclass
class LintResult:
    root: str
    files_scanned: int
    findings: List[Finding]                 # non-baselined, sorted
    suppressed: List[Finding]               # matched a baseline entry
    stale_baseline: List[dict]              # entries that matched nothing
    rules_run: List[str]

    @property
    def clean(self) -> bool:
        return not self.findings and not self.stale_baseline

    def exit_code(self) -> int:
        return 0 if self.clean else 1


def _iter_py_files(targets: List[Path]) -> List[Path]:
    out: List[Path] = []
    for t in targets:
        if t.is_file() and t.suffix == ".py":
            out.append(t)
        elif t.is_dir():
            for p in sorted(t.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in p.parts):
                    out.append(p)
    return out


def _load_ctx(path: Path, rel: str) -> FileCtx:
    source = path.read_text()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        raise ValueError(f"{rel}: cannot parse: {e}") from e
    return FileCtx(path=rel, tree=tree, source=source,
                   lines=source.splitlines())


def run_lint(paths: Iterable[str], root: str,
             rules: Optional[Iterable[str]] = None,
             baseline: Optional["Baseline"] = None) -> LintResult:
    """Lint ``paths`` (files or directories, relative to or under
    ``root``) with the selected rules (default: all), returning a
    deterministic LintResult.  Unknown rule ids and unreadable paths raise
    ValueError / FileNotFoundError (CLI exit code 2)."""
    from repro.analysis.baseline import Baseline  # circular-import dance
    from repro.analysis.rules import RULES

    rootp = Path(root).resolve()
    rule_ids = list(rules) if rules is not None else list(RULES)
    unknown = [r for r in rule_ids if r not in RULES]
    if unknown:
        raise ValueError(f"unknown rule id(s) {unknown} "
                         f"(known: {sorted(RULES)})")

    targets: List[Path] = []
    for p in paths:
        cand = Path(p)
        if not cand.is_absolute():
            cand = rootp / cand
        if not cand.exists():
            raise FileNotFoundError(f"lint target does not exist: {p}")
        targets.append(cand)

    ctxs: Dict[str, FileCtx] = {}
    for f in _iter_py_files(targets):
        try:
            rel = f.resolve().relative_to(rootp).as_posix()
        except ValueError:
            rel = f.as_posix()
        if rel not in ctxs:
            ctxs[rel] = _load_ctx(f, rel)

    findings: List[Finding] = []
    for rid in rule_ids:
        rule = RULES[rid]
        if rule.check_file is not None:
            for rel in sorted(ctxs):
                if rule.scope(rel):
                    findings.extend(rule.check_file(ctxs[rel]))
        if rule.check_project is not None:
            findings.extend(rule.check_project(ctxs))
    findings.sort()

    bl = baseline if baseline is not None else Baseline.empty()
    kept, suppressed, stale = bl.apply(findings)
    return LintResult(root=str(rootp), files_scanned=len(ctxs),
                      findings=kept, suppressed=suppressed,
                      stale_baseline=stale, rules_run=rule_ids)


def default_targets(root: str) -> List[str]:
    """The repo surfaces the invariants cover, filtered by existence."""
    rootp = Path(root)
    return [d for d in ("src/repro", "scripts", "benchmarks", "examples")
            if (rootp / d).exists()]


def find_repo_root(start: Optional[str] = None) -> str:
    """Nearest ancestor holding src/repro (a checkout); falls back to the
    installed package's grandparent so ``repro lint`` still resolves."""
    cur = Path(start or Path.cwd()).resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return str(cand)
    pkg = Path(__file__).resolve().parents[2]   # .../src
    return str(pkg.parent)


def lint_paths(paths: Optional[Iterable[str]] = None,
               root: Optional[str] = None,
               rules: Optional[Iterable[str]] = None,
               baseline_path: Optional[str] = None) -> Tuple[LintResult,
                                                             "Baseline"]:
    """One-call front door used by the CLI and scripts/check_invariants:
    resolve root + default targets + default baseline, run, return both
    the result and the (possibly empty) baseline that was applied."""
    from repro.analysis.baseline import Baseline, load_baseline

    root = root or find_repo_root()
    targets = list(paths) if paths else default_targets(root)
    if baseline_path == "none":
        bl = Baseline.empty()
    elif baseline_path:
        bl = load_baseline(baseline_path)
    else:
        default = Path(root) / "lint_baseline.json"
        bl = load_baseline(str(default)) if default.exists() \
            else Baseline.empty()
    return run_lint(targets, root=root, rules=rules, baseline=bl), bl
