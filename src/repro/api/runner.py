"""`run_scenario`: one driver over the existing simulation layers.

The runner owns *composition only*: it builds the workload, the node or
cluster simulator, an optional telemetry collector and an optional
closed-loop manager from a :class:`~repro.api.spec.Scenario`, drives the
run with the same call sequence the hand-wired scripts used (so results
are bit-identical — tested), and condenses the outcome into a
:class:`ScenarioResult` whose ``metrics`` dict is flat, JSON-safe and
stable enough for the CI regression gate to diff.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.api.spec import Scenario, TelemetrySpec
from repro.core.escalate import (EscalationConfig, HealReport,
                                 run_healing_fleet)
from repro.core.backends import ClusterSimBackend, SimBackend
from repro.core.c3sim import IterationTrace, NodeSim
from repro.core.cluster import ClusterSim
from repro.core.detect import lead_value_detect, straggler_index
from repro.core.manager import (FleetPowerManager, run_closed_loop,
                                run_fleet_closed_loop)
from repro.obs.incidents import score_alerts
from repro.obs.pipeline import ObsPipeline
from repro.serve.engine import ServeReport, ServingFleet
from repro.telemetry.collector import TelemetryCollector
from repro.telemetry.replay import detection_report, fleet_lead_report
from repro.telemetry.sensors import SensorModel
from repro.telemetry.trace_io import (TelemetryTrace, export_chrome_trace,
                                      save_trace)

__all__ = ["BuiltScenario", "ScenarioResult", "build_scenario",
           "run_scenario"]


class _CapturingSimBackend(SimBackend):
    """`SimBackend` that remembers the last iteration's trace (the manager
    loop otherwise consumes and drops it); arithmetic untouched."""

    last_trace: Optional[IterationTrace] = None

    def run_iteration(self) -> IterationTrace:
        self.last_trace = super().run_iteration()
        return self.last_trace


class _CapturingClusterBackend(ClusterSimBackend):
    last_traces: Optional[List[IterationTrace]] = None

    def run_iteration(self) -> List[IterationTrace]:
        self.last_traces = super().run_iteration()
        return self.last_traces


@dataclass
class BuiltScenario:
    """The composed-but-not-yet-run simulation objects — what benchmarks
    use when they need to own the timing loop themselves."""

    scenario: Scenario
    workload: object
    node: Optional[NodeSim] = None          # single-node scenarios
    cluster: Optional[ClusterSim] = None    # fleet scenarios
    serving: Optional[ServingFleet] = None  # serve scenarios (cluster is
    #                                         the ServingFleet's embedded
    #                                         ClusterSim)
    collector: Optional[TelemetryCollector] = None
    obs: Optional[ObsPipeline] = None       # metrics + alerting observer

    @property
    def sim(self):
        return self.node if self.node is not None else self.cluster


@dataclass
class ScenarioResult:
    """What `run_scenario` hands back: summary metrics plus live handles
    to the simulation objects for study-specific post-processing.  Only
    the metric dict (via `to_json_dict`) is serializable."""

    scenario: Scenario
    iterations: int
    metrics: Dict[str, float] = field(default_factory=dict)
    # live object handles for study-specific reporting (not serialized)
    node: Optional[NodeSim] = None
    cluster: Optional[ClusterSim] = None
    manager: Optional[object] = None
    collector: Optional[TelemetryCollector] = None
    last_trace: Optional[IterationTrace] = None
    last_traces: Optional[List[IterationTrace]] = None
    trace_path: Optional[str] = None
    heal: Optional[HealReport] = None       # fault/escalation runs only
    serve: Optional[ServeReport] = None     # serve/* runs only
    obs: Optional[ObsPipeline] = None       # observability runs only

    def to_json_dict(self) -> dict:
        """JSON-safe summary (the `--json` CLI payload): name, seed,
        iterations, metrics, and the trace path if one was recorded."""
        return {"scenario": self.scenario.name or None,
                "iterations": self.iterations,
                "seed": self.scenario.seed,
                "metrics": self.metrics,
                "trace_path": self.trace_path}

    def trace(self) -> TelemetryTrace:
        """The recorded telemetry trace; raises if the scenario ran
        without a `TelemetrySpec`."""
        if self.collector is None:
            raise ValueError("scenario ran without telemetry; set "
                             "Scenario.telemetry to record a trace")
        return TelemetryTrace.from_collector(self.collector)


# --------------------------------------------------------------------------- #
# build
# --------------------------------------------------------------------------- #
def build_scenario(sc: Scenario,
                   iterations: Optional[int] = None) -> BuiltScenario:
    """Compose the simulation objects exactly as the pre-API scripts did
    (same constructor arguments, same ordering: build, cap, attach)."""
    sc.validate()
    iters = sc.iterations if iterations is None else int(iterations)
    wl = sc.workload.build()
    preset = sc.node.build_preset()
    collector = None
    if sc.telemetry is not None:
        t = sc.telemetry
        max_samples = (t.max_samples if t.max_samples is not None
                       else iters + 8)
        collector = TelemetryCollector(
            sensor_cfg=t.sensor, max_samples=max_samples,
            keep_truth=t.keep_truth, with_kernels=t.with_kernels)
    obs = None
    if sc.observability is not None:
        if collector is None:
            raise ValueError("observability requires Scenario.telemetry "
                             "(the pipeline observes the recorded stream; "
                             "run_scenario adds a lossless default)")
        obs = ObsPipeline(sc.observability, fleet_scope=sc.fleet is not None)
        obs.attach(collector)
    if sc.fleet is None:
        node = NodeSim(wl, preset, sc.sim, n_devices=sc.node.devices,
                       seed=sc.seed,
                       straggler_boost=sc.node.straggler_boost)
        if sc.node.caps_w is not None:
            node.set_power_caps(np.full(node.G, float(sc.node.caps_w)))
        if collector is not None:
            collector.attach_node(node)
        return BuiltScenario(sc, wl, node=node, collector=collector,
                             obs=obs)
    if sc.serve is not None:
        serving = ServingFleet(wl, preset, sc.sim, sc.fleet, sc.serve,
                               devices_per_node=sc.node.devices,
                               seed=sc.seed)
        cluster = serving.cluster
    else:
        serving = None
        cluster = ClusterSim(wl, preset, sc.sim, sc.fleet,
                             devices_per_node=sc.node.devices, seed=sc.seed)
    if sc.node.caps_w is not None:
        for n in range(cluster.N):
            cluster.set_node_caps(n, np.full(cluster.G,
                                             float(sc.node.caps_w)))
    if collector is not None:
        if serving is not None:
            serving.attach_collector(collector)
        else:
            collector.attach_cluster(cluster)
    return BuiltScenario(sc, wl, cluster=cluster, serving=serving,
                         collector=collector, obs=obs)


# --------------------------------------------------------------------------- #
# run
# --------------------------------------------------------------------------- #
def run_scenario(sc: Scenario, *, iterations: Optional[int] = None,
                 save_trace_path: Optional[str] = None,
                 chrome_trace_path: Optional[str] = None) -> ScenarioResult:
    """Build + drive + summarize one scenario.

    ``iterations`` overrides ``sc.iterations`` (CLI ``--iterations``;
    registry smoke tests run every scenario at 2).  ``save_trace_path`` /
    ``chrome_trace_path`` persist the recorded telemetry (requires
    ``sc.telemetry``; the CLI enables a lossless default when asked to
    save without one).
    """
    if (sc.faults is not None or sc.observability is not None) \
            and sc.telemetry is None:
        # fault and observability scenarios observe through telemetry: the
        # escalation policy and the alert pipeline both consume the
        # recorded (lossless by default) observed stream, so the same
        # trace replays their decisions offline
        sc = sc.replace(telemetry=TelemetrySpec())
    if (save_trace_path or chrome_trace_path) and sc.telemetry is None:
        raise ValueError("saving a trace requires Scenario.telemetry")
    iters = sc.iterations if iterations is None else int(iterations)
    built = build_scenario(sc, iterations=iters)
    result = ScenarioResult(scenario=sc, iterations=iters,
                            node=built.node, cluster=built.cluster,
                            collector=built.collector, obs=built.obs)

    if built.node is not None:
        _run_node(sc, built, iters, result)
    else:
        _run_fleet(sc, built, iters, result)

    result.metrics = _metrics(sc, iters, result)
    if save_trace_path:
        save_trace(built.collector, save_trace_path)
        result.trace_path = save_trace_path
    if chrome_trace_path:
        export_chrome_trace(built.collector, chrome_trace_path)
    return result


def _run_node(sc: Scenario, built: BuiltScenario, iters: int,
              result: ScenarioResult) -> None:
    node = built.node
    if sc.manager is not None:
        backend = _CapturingSimBackend(node)
        sensor = (SensorModel(sc.manager.sensor)
                  if sc.manager.sensor is not None else None)
        result.manager = run_closed_loop(
            backend, sc.manager.config, iters,
            tune_after=sc.manager.tune_after, sensor=sensor,
            collector=built.collector)
        result.last_trace = backend.last_trace
    else:
        for _ in range(iters):
            result.last_trace = node.step()


def _run_fleet(sc: Scenario, built: BuiltScenario, iters: int,
               result: ScenarioResult) -> None:
    if sc.serve is not None:
        _run_serve(sc, built, iters, result)
        return
    if sc.faults is not None or sc.escalation is not None:
        _run_healing(sc, built, iters, result)
        return
    cluster = built.cluster
    if sc.manager is not None:
        backend = _CapturingClusterBackend(cluster)
        result.manager = run_fleet_closed_loop(
            backend, sc.manager.config, iters,
            tune_after=sc.manager.tune_after, collector=built.collector)
        result.last_traces = backend.last_traces
    else:
        for _ in range(iters):
            result.last_traces = cluster.step()


def _run_serve(sc: Scenario, built: BuiltScenario, iters: int,
               result: ScenarioResult) -> None:
    """Serve scenarios drive the `ServingFleet` loop: ``iterations`` are
    engine rounds, the manager (if any) is the hierarchical fleet
    controller fed through its serving hook from ``tune_after`` on."""
    fleet = built.serving
    mgr = None
    tune_after = None
    if sc.manager is not None:
        mgr = FleetPowerManager(ClusterSimBackend(fleet.cluster),
                                sc.manager.config,
                                collector=built.collector)
        tune_after = sc.manager.tune_after
    rep = fleet.run(iters, manager=mgr, tune_after=tune_after)
    result.serve = rep
    result.manager = mgr


def _run_healing(sc: Scenario, built: BuiltScenario, iters: int,
                 result: ScenarioResult) -> None:
    """Fault/escalation scenarios run the elastic healing loop, which
    (re)builds its own fleet per membership epoch — ``built.cluster`` is
    discarded and the result handles point at the final epoch's objects.
    Faults without an escalation spec run under ``drain_mode="never"``
    (injected, observed, never drained — the ablation baseline)."""
    esc = (sc.escalation if sc.escalation is not None
           else EscalationConfig(drain_mode="never"))
    rep = run_healing_fleet(
        built.workload, sc.node.build_preset(), sc.sim, sc.fleet,
        iterations=iters, faults=sc.faults, escalation=esc,
        manager_cfg=(sc.manager.config if sc.manager is not None else None),
        tune_after=(sc.manager.tune_after if sc.manager is not None
                    else None),
        devices_per_node=sc.node.devices, seed=sc.seed,
        node_caps_w=sc.node.caps_w, collector=built.collector,
        alert_source=built.obs)
    result.heal = rep
    result.cluster = rep.cluster
    result.manager = rep.manager


def _num(x: float) -> float:
    """NaN-free metric value (the JSON payload stays valid everywhere):
    undefined durations report as -1.0."""
    return -1.0 if (x is None or x != x) else float(x)


# --------------------------------------------------------------------------- #
# metrics
# --------------------------------------------------------------------------- #
def _mean(xs) -> float:
    xs = list(xs)
    return float(np.mean(xs)) if xs else float("nan")


def _metrics(sc: Scenario, iters: int, r: ScenarioResult) -> Dict[str, float]:
    last = max(1, min(30, iters))
    m: Dict[str, float] = {"iterations": iters}
    if r.node is not None:
        h = r.node.history
        tail = h[-last:]
        m["throughput"] = _mean(x["throughput"] for x in tail)
        m["node_power_w"] = _mean(np.sum(x["power"]) for x in tail)
        st = r.node.state
        m["temp_ratio"] = float(st.temp.max() / st.temp.min())
        m["freq_ratio"] = float(st.freq.max() / st.freq.min())
        if r.last_trace is not None:
            m["straggler_device"] = straggler_index(r.last_trace.comp_start)
            lead = lead_value_detect(r.last_trace.comp_start)
            m["lead_span_ms"] = float((lead.max() - lead.min()) * 1e3)
        mgr = r.manager
        if mgr is not None:
            tune = (sc.manager.tune_after if sc.manager.tune_after
                    is not None else iters // 2)
            pre = h[max(0, tune - last):tune]
            if pre and tail:
                m["tput_ratio"] = (_mean(x["throughput"] for x in tail)
                                   / _mean(x["throughput"] for x in pre))
                m["power_ratio"] = (_mean(np.sum(x["power"]) for x in tail)
                                    / _mean(np.sum(x["power"])
                                            for x in pre))
            caps = mgr.backend.get_power_caps()
            m["cap_spread_w"] = float(caps.max() - caps.min())
            m["n_cap_adjustments"] = len(mgr.adjust_log)
    elif r.serve is not None:
        # the SLO summary is already flat, JSON-safe and NaN-free (the
        # -1.0 sentinel stands in for undefined quantiles)
        m.update({k: _num(v) for k, v in r.serve.summary.items()})
        m["t_fleet_s"] = _num(r.serve.t_fleet_s)
        m["n_generated"] = float(r.serve.n_generated)
        mgr = r.manager
        if mgr is not None:
            m["node0_budget_w"] = float(mgr.node_budgets[0])
            m["budget_spread_w"] = float(mgr.node_budgets.max()
                                         - mgr.node_budgets.min())
            m["n_budget_adjustments"] = len(mgr.budget_log)
    else:
        cl = r.cluster
        m["fleet_tput"] = cl.fleet_throughput(last=last)
        m["fleet_power_w"] = cl.fleet_power(last=last)
        tail = cl.history[-last:]
        if tail:
            slow = [x["slowest_node"] for x in tail]
            m["slowest_node_mode"] = int(np.bincount(slow).argmax())
            m["comm_time_ms"] = float(tail[-1]["comm_time"] * 1e3)
            m["straggler_node_named"] = int(np.argmin(tail[-1]["lead"]))
        mgr = r.manager
        if mgr is not None:
            m["node0_budget_w"] = float(mgr.node_budgets[0])
            m["budget_spread_w"] = float(mgr.node_budgets.max()
                                         - mgr.node_budgets.min())
            m["n_budget_adjustments"] = len(mgr.budget_log)
        if r.heal is not None:
            hp = r.heal
            m["goodput"] = _num(hp.goodput)
            m["useful_units"] = hp.useful_units
            m["lost_units"] = hp.lost_units
            m["t_total_s"] = hp.t_total_s
            m["energy_j"] = hp.energy_j
            m["n_drains"] = len(hp.drains)
            m["false_drains"] = hp.false_drains
            m["time_to_detect_s"] = _num(hp.time_to_detect_s)
            m["time_to_heal_s"] = _num(hp.time_to_heal_s)
            m["surviving_nodes"] = hp.surviving_nodes
            m["checkpoints"] = hp.checkpoints
            m["checkpoint_restores"] = hp.restores
    if r.collector is not None:
        m["telemetry_samples"] = len(r.collector.samples)
        m.update(_detection_metrics(sc, r))
    if r.obs is not None and r.collector is not None:
        m.update(_obs_metrics(sc, r))
    return m


def _obs_metrics(sc: Scenario, r: ScenarioResult) -> Dict[str, float]:
    """Alert quality of the run's observability pipeline, scored against
    the recorded fault ground truth (NaN-free like every other metric)."""
    trace = TelemetryTrace.from_collector(r.collector)
    patience = (sc.escalation.patience_s if sc.escalation is not None
                else float("nan"))
    score = score_alerts(trace, patience_s=patience)
    return {"obs_alerts_fired": score["n_alerts_firing"],
            "obs_false_alerts": score["false_positives"],
            "obs_time_to_alert_s": _num(score["time_to_alert_s"])}


def _detection_metrics(sc: Scenario, r: ScenarioResult) -> Dict[str, float]:
    """Straggler-detection quality of the recorded (possibly degraded)
    stream, when the trace carries enough to judge it.  At cluster scope
    the fleet-lead estimator is scored too (``fleet_lead_*`` keys): how far
    the lead a manager reconstructs from sensed per-node iteration times
    sits from the true topology lead the trace records losslessly."""
    col = r.collector
    trace = TelemetryTrace.from_collector(col)
    out: Dict[str, float] = {}
    if col.samples and sc.telemetry.with_kernels:
        node = int(trace.meta.get("straggler_node", 0)) if r.cluster else 0
        try:
            rep = detection_report(trace, node=node)
            out["detect_accuracy"] = rep.accuracy
            out["detect_lead_err"] = rep.lead_rel_error
            if rep.accuracy_imputed is not None:
                out["detect_accuracy_imputed"] = rep.accuracy_imputed
        except ValueError:
            pass
    if trace.fleet:
        try:
            frep = fleet_lead_report(trace)
            out["fleet_lead_accuracy"] = frep.accuracy
            out["fleet_lead_err"] = frep.lead_rel_error
        except ValueError:
            pass
    return out
