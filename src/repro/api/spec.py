"""Declarative scenario specs: the single serializable front door.

A :class:`Scenario` names everything one Lit Silicon experiment needs —
workload, simulator knobs, node, optional fleet (topology / heterogeneity /
churn), optional manager policy, optional telemetry, iteration count and
seed — as a composition of the repo's *existing* config dataclasses
(`SimConfig`, `ClusterConfig`, `ManagerConfig`/`FleetManagerConfig`,
`SensorConfig`).  Nothing is re-modeled: `run_scenario` (runner.py) hands
these configs to the same constructors the hand-wired scripts used, so a
spec-driven run is bit-for-bit the script it replaced (tested in
tests/test_scenario_api.py).

Serialization contract (all tested):

  * versioned envelope — ``{"format": "lit-silicon-scenario", "version": 1,
    "scenario": {...}}``; unknown newer versions and foreign formats are
    rejected on load;
  * exact float round-trip — JSON emits the shortest repr that parses back
    to the same IEEE-754 double; NaN/±Inf (invalid JSON) are encoded as
    ``{"$float": "nan" | "inf" | "-inf"}`` so ``allow_nan=False`` can be
    enforced;
  * unknown keys are errors, at every nesting level, with the dotted path
    in the message — a typo'd knob can never silently fall back to a
    default;
  * omitted keys take the dataclass defaults, so specs stay minimal.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig
from repro.core.escalate import EscalationConfig
from repro.core.faults import FaultEvent, FaultModel
from repro.core.manager import FleetManagerConfig, ManagerConfig
from repro.core.thermal import PRESETS, ChurnEvent, ChurnModel, DevicePreset
from repro.core.workload import Workload, fsdp_llm_iteration
from repro.obs.pipeline import ObservabilitySpec
from repro.obs.rules import AlertRule
from repro.serve.traffic import ARRIVAL_PROCESSES
from repro.telemetry.sensors import SensorConfig
from repro.train.fault import WatchdogConfig

SPEC_FORMAT = "lit-silicon-scenario"
SPEC_VERSION = 1

# spec-layer names for the injected-fault schedule and the escalation
# policy: both are plain dataclasses, so the scenario codec carries them
# like every other config section
FaultSpec = FaultModel
EscalationSpec = EscalationConfig

__all__ = [
    "SPEC_FORMAT", "SPEC_VERSION", "WorkloadSpec", "NodeSpec", "ManagerSpec",
    "TelemetrySpec", "FaultSpec", "EscalationSpec", "ServeSpec",
    "ObservabilitySpec", "Scenario", "scenario_from_dict", "with_overrides",
]


# --------------------------------------------------------------------------- #
# spec dataclasses
# --------------------------------------------------------------------------- #
@dataclass
class WorkloadSpec:
    """What the devices execute each iteration (workload.py builder args)."""

    arch: str = "llama3.1-8b"
    n_layers: Optional[int] = None      # None: the architecture's default
    batch: int = 2
    seq: int = 4096
    n_shards: int = 8

    def build(self) -> Workload:
        """Materialize the kernel-level `Workload` from the named model
        config (n_layers override applied first)."""
        from repro.configs import get_config
        cfg = get_config(self.arch)
        if self.n_layers is not None:
            cfg = cfg.replace(n_layers=self.n_layers)
        return fsdp_llm_iteration(cfg, batch=self.batch, seq=self.seq,
                                  n_shards=self.n_shards)


@dataclass
class NodeSpec:
    """Per-node hardware: preset, device count, the boosted hot device
    (single-node scenarios; fleets take theirs from `ClusterConfig`), and
    the initial per-device power cap applied before the run."""

    preset: str = "mi300x"              # PRESETS name
    devices: int = 8
    straggler_boost: float = 1.28
    caps_w: Optional[float] = None      # None: leave thermal-model default

    def build_preset(self) -> DevicePreset:
        """Resolve the preset name against `PRESETS` (with a listing of
        valid names on failure)."""
        if self.preset not in PRESETS:
            raise ValueError(f"unknown device preset {self.preset!r} "
                             f"(expected one of {sorted(PRESETS)})")
        return PRESETS[self.preset]


@dataclass
class ManagerSpec:
    """Closed-loop power management policy.

    ``scope`` selects the controller: ``"node"`` runs a `PowerManager`
    over a single node (`config` is a `ManagerConfig`); ``"fleet"`` runs
    the hierarchical `FleetPowerManager` over a cluster (`config` is a
    `FleetManagerConfig`).  ``tune_after`` is the iteration the loop is
    enabled from (None: halfway, the paper-Fig-9 default).  ``sensor``
    optionally routes the node manager's detection through a noisy
    `SensorModel` instead of the oracle kernel-start matrices.
    """

    scope: str = "node"                 # node | fleet
    config: ManagerConfig = field(default_factory=ManagerConfig)
    tune_after: Optional[int] = None
    sensor: Optional[SensorConfig] = None

    def validate(self, has_fleet: bool) -> None:
        if self.scope not in ("node", "fleet"):
            raise ValueError(f"manager.scope must be 'node' or 'fleet', "
                             f"got {self.scope!r}")
        if self.scope == "fleet" and not has_fleet:
            raise ValueError("manager.scope='fleet' requires a fleet spec")
        if self.scope == "node" and has_fleet:
            raise ValueError("fleet scenarios take manager.scope='fleet' "
                             "(per-node managers are nested inside the "
                             "FleetPowerManager)")
        if self.scope == "fleet" and not isinstance(self.config,
                                                    FleetManagerConfig):
            raise ValueError("manager.scope='fleet' needs a "
                             "FleetManagerConfig")


@dataclass
class TelemetrySpec:
    """Trace recording through a `TelemetryCollector`."""

    sensor: SensorConfig = field(default_factory=SensorConfig)
    max_samples: Optional[int] = None   # None: sized to hold the whole run
    keep_truth: bool = False
    with_kernels: bool = True


@dataclass
class ServeSpec:
    """Production-traffic serving on top of a fleet (serve/* scenarios).

    Arrival process + scale, request shape distributions, continuous-
    batching geometry, and the SLO deadlines the goodput metrics are
    scored against — the `ServingFleet` / `generate_requests` inputs
    (docs/serving.md)."""

    # ------------------------------------------------------ arrival process
    process: str = "poisson"            # poisson | diurnal
    rate_rps: float = 8.0               # mean arrival rate (fleet-wide)
    users_m: float = 0.0                # millions of users; > 0 overrides
    #                                     rate_rps via user_req_per_day
    user_req_per_day: float = 8.0       # requests per user per day
    horizon_s: float = 20.0             # arrivals stop here (sim s)
    max_requests: int = 4096            # hard cap on generated requests
    diurnal_amp: float = 0.6            # peak/trough swing (0 <= amp < 1)
    diurnal_period_s: float = 30.0      # "a day", compressed
    # ------------------------------------------------------- request shapes
    prompt_mean: float = 512.0          # lognormal mean prompt tokens
    prompt_sigma: float = 0.8
    prompt_max: int = 4096
    output_mean: float = 64.0           # lognormal mean output tokens
    output_sigma: float = 0.6
    output_max: int = 512
    # ------------------------------------------------- continuous batching
    batch_slots: int = 16               # static batch slots per node
    prefill_chunk: int = 512            # prompt tokens prefilled per step
    # ------------------------------------------------------- SLO deadlines
    ttft_deadline_s: float = 2.0        # goodput: first token within this
    tpot_deadline_s: float = 0.25       # and per-token latency within this

    def arrival_rate(self) -> float:
        """Effective mean rate (req/s): the millions-of-users knob wins
        when set, spread uniformly over a day."""
        if self.users_m > 0:
            return self.users_m * 1e6 * self.user_req_per_day / 86400.0
        return self.rate_rps

    def validate(self) -> None:
        if self.process not in ARRIVAL_PROCESSES:
            raise ValueError(f"serve.process must be one of "
                             f"{ARRIVAL_PROCESSES}, got {self.process!r}")
        if self.arrival_rate() <= 0:
            raise ValueError("serve arrival rate must be > 0 (set rate_rps "
                             "or users_m)")
        if not 0 <= self.diurnal_amp < 1:
            raise ValueError(f"serve.diurnal_amp must be in [0, 1), got "
                             f"{self.diurnal_amp}")
        if self.horizon_s <= 0 or self.max_requests < 1:
            raise ValueError("serve.horizon_s must be > 0 and "
                             "serve.max_requests >= 1")
        if self.batch_slots < 1 or self.prefill_chunk < 1:
            raise ValueError("serve.batch_slots and serve.prefill_chunk "
                             "must be >= 1")
        if self.ttft_deadline_s <= 0 or self.tpot_deadline_s <= 0:
            raise ValueError("serve SLO deadlines must be > 0")
        for nm in ("prompt_mean", "prompt_sigma", "prompt_max",
                   "output_mean", "output_sigma", "output_max"):
            if getattr(self, nm) <= 0:
                raise ValueError(f"serve.{nm} must be > 0")


@dataclass
class Scenario:
    """One reproducible experiment, end to end."""

    name: str = ""
    description: str = ""
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    sim: SimConfig = field(default_factory=SimConfig)
    node: NodeSpec = field(default_factory=NodeSpec)
    fleet: Optional[ClusterConfig] = None     # None: single-node scenario
    manager: Optional[ManagerSpec] = None     # None: unmanaged run
    telemetry: Optional[TelemetrySpec] = None  # None: no recording
    faults: Optional[FaultModel] = None        # None: no injected faults
    escalation: Optional[EscalationConfig] = None  # None: no drain policy
    serve: Optional[ServeSpec] = None          # None: training-shaped run
    observability: Optional[ObservabilitySpec] = None  # None: no alerting
    iterations: int = 60
    seed: int = 5                       # NodeSim / ClusterSim thermal seed

    # -------------------------------------------------------------- helpers
    def validate(self) -> "Scenario":
        """Cross-field checks (preset exists, manager scope matches fleet
        presence); returns self so it chains."""
        self.node.build_preset()
        if self.manager is not None:
            self.manager.validate(self.fleet is not None)
        if self.faults is not None:
            if self.fleet is None:
                raise ValueError("faults require a fleet spec (injection "
                                 "targets cluster nodes)")
            self.faults.validate()
        if self.escalation is not None:
            if self.fleet is None:
                raise ValueError("escalation requires a fleet spec")
            self.escalation.validate()
        if self.serve is not None:
            if self.fleet is None:
                raise ValueError("serve requires a fleet spec (requests "
                                 "are routed across cluster replicas)")
            if self.faults is not None or self.escalation is not None:
                raise ValueError("serve scenarios do not support "
                                 "faults/escalation (the healing loop is "
                                 "training-shaped)")
            self.serve.validate()
        if self.observability is not None:
            self.observability.validate()
        if (self.manager is not None
                and getattr(self.manager.config, "objective", "throughput")
                == "tail-latency" and self.serve is None):
            raise ValueError("manager objective 'tail-latency' needs a "
                             "serve spec (the tail signal comes from the "
                             "serving engine)")
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")
        return self

    def replace(self, **kw) -> "Scenario":
        """`dataclasses.replace` shorthand — derive a variant scenario."""
        return dataclasses.replace(self, **kw)

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """JSON-safe nested dict (NaN/Inf escaped as ``{"$float": ...}``)."""
        return _encode(self)

    def to_json(self, indent: Optional[int] = 2) -> str:
        """Versioned spec document: ``{format, version, scenario}``."""
        return json.dumps({"format": SPEC_FORMAT, "version": SPEC_VERSION,
                           "scenario": self.to_dict()},
                          indent=indent, sort_keys=True, allow_nan=False)

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        """Inverse of `to_dict`; unknown keys are rejected, the result is
        validated."""
        return _decode_dataclass(cls, d, "scenario").validate()

    @classmethod
    def from_json(cls, text: str) -> "Scenario":
        """Parse a spec document, checking the format/version envelope."""
        data = json.loads(text)
        if not isinstance(data, dict) or data.get("format") != SPEC_FORMAT:
            raise ValueError(f"not a {SPEC_FORMAT} document "
                             f"(format={data.get('format') if isinstance(data, dict) else None!r})")
        if "version" not in data:
            raise ValueError("scenario document carries no version")
        if int(data["version"]) > SPEC_VERSION:
            raise ValueError(f"scenario version {data['version']} is newer "
                             f"than supported version {SPEC_VERSION}")
        unknown = sorted(set(data) - {"format", "version", "scenario"})
        if unknown:
            raise ValueError(f"unknown envelope key(s) {unknown} "
                             f"(expected format/version/scenario)")
        if "scenario" not in data:
            raise ValueError("scenario document carries no 'scenario' body")
        return cls.from_dict(data["scenario"])

    def save(self, path: str) -> None:
        """Write the `to_json` document to ``path``."""
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "Scenario":
        """Read a spec document from ``path`` (see `from_json`)."""
        with open(path) as f:
            return cls.from_json(f.read())


def scenario_from_dict(d: dict) -> Scenario:
    """Module-level alias for `Scenario.from_dict`."""
    return Scenario.from_dict(d)


# --------------------------------------------------------------------------- #
# codec: dataclasses <-> JSON-safe dicts (NaN-safe, unknown keys rejected)
# --------------------------------------------------------------------------- #
def _encode(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _encode(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, float):
        if math.isnan(obj):
            return {"$float": "nan"}
        if math.isinf(obj):
            return {"$float": "inf" if obj > 0 else "-inf"}
        return obj
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    return obj


_SPECIAL_FLOATS = {"nan": math.nan, "inf": math.inf, "-inf": -math.inf}


def _decode_value(v: Any, path: str) -> Any:
    """Plain JSON values: undo the ``$float`` escape, recurse containers."""
    if isinstance(v, dict):
        if set(v) == {"$float"}:
            if v["$float"] not in _SPECIAL_FLOATS:
                raise ValueError(f"{path}: bad $float {v['$float']!r}")
            return _SPECIAL_FLOATS[v["$float"]]
        return {k: _decode_value(x, f"{path}.{k}") for k, x in v.items()}
    if isinstance(v, list):
        return [_decode_value(x, f"{path}[{i}]") for i, x in enumerate(v)]
    return v


# nested dataclass-typed fields (Optional nesting handled by None checks)
_NESTED: Dict[type, Dict[str, type]] = {
    Scenario: {"workload": WorkloadSpec, "sim": SimConfig, "node": NodeSpec,
               "fleet": ClusterConfig, "manager": ManagerSpec,
               "telemetry": TelemetrySpec, "faults": FaultModel,
               "escalation": EscalationConfig, "serve": ServeSpec,
               "observability": ObservabilitySpec},
    ManagerSpec: {"sensor": SensorConfig},
    TelemetrySpec: {"sensor": SensorConfig},
    EscalationConfig: {"watchdog": WatchdogConfig},
}


def _decode_dataclass(cls: type, data: Any, path: str) -> Any:
    if data is None:
        return None
    if not isinstance(data, dict):
        raise ValueError(f"{path}: expected an object for "
                         f"{cls.__name__}, got {type(data).__name__}")
    names = [f.name for f in dataclasses.fields(cls)]
    unknown = sorted(set(data) - set(names))
    if unknown:
        raise ValueError(f"{path}: unknown key(s) {unknown} for "
                         f"{cls.__name__} (known: {sorted(names)})")
    kw: Dict[str, Any] = {}
    for f in dataclasses.fields(cls):
        if f.name not in data:
            continue
        v = data[f.name]
        sub = _NESTED.get(cls, {}).get(f.name)
        p = f"{path}.{f.name}"
        if cls is ManagerSpec and f.name == "config":
            sub = (FleetManagerConfig if data.get("scope", "node") == "fleet"
                   else ManagerConfig)
            kw[f.name] = _decode_dataclass(sub, v, p)
        elif cls is ClusterConfig and f.name == "churn" and v is not None:
            kw[f.name] = {int(k): _decode_dataclass(ChurnModel, cm,
                                                    f"{p}[{k}]")
                          for k, cm in v.items()}
        elif cls is ClusterConfig and f.name == "node_presets" \
                and v is not None:
            kw[f.name] = [(_decode_dataclass(DevicePreset, e, f"{p}[{i}]")
                           if isinstance(e, dict) else e)
                          for i, e in enumerate(v)]
        elif cls is ChurnModel and f.name == "events":
            kw[f.name] = [_decode_dataclass(ChurnEvent, e, f"{p}[{i}]")
                          for i, e in enumerate(v)]
        elif cls is FaultModel and f.name == "events":
            kw[f.name] = [_decode_dataclass(FaultEvent, e, f"{p}[{i}]")
                          for i, e in enumerate(v)]
        elif cls is ObservabilitySpec and f.name == "rules" \
                and v is not None:
            kw[f.name] = [_decode_dataclass(AlertRule, e, f"{p}[{i}]")
                          for i, e in enumerate(v)]
        elif sub is not None:
            kw[f.name] = _decode_dataclass(sub, v, p)
        else:
            kw[f.name] = _decode_value(v, p)
    try:
        return cls(**kw)
    except TypeError as e:                    # frozen/slot mismatches etc.
        raise ValueError(f"{path}: cannot build {cls.__name__}: {e}") from e


# --------------------------------------------------------------------------- #
# dotted-path overrides (CLI --set, sweep grids)
# --------------------------------------------------------------------------- #
def _section_class(cls: Optional[type], cur: dict,
                   part: str) -> Optional[type]:
    """The dataclass a section key decodes into, when known (mirrors the
    decoder's dispatch so null sections can be materialized with real
    defaults rather than empty dicts)."""
    if cls is None:
        return None
    if cls is ManagerSpec and part == "config":
        return (FleetManagerConfig if cur.get("scope", "node") == "fleet"
                else ManagerConfig)
    return _NESTED.get(cls, {}).get(part)


def with_overrides(sc: Scenario, overrides: Dict[str, Any]) -> Scenario:
    """A new Scenario with dotted-path keys replaced, re-validated through
    the normal decoder (so types and unknown keys are checked the same way
    a JSON spec is).  Example: ``{"sim.noise": 0.01, "fleet.n_nodes": 8}``.

    Setting a key under an optional section that is currently null (e.g.
    ``telemetry.sensor.dropout_p`` on an unrecorded scenario) materializes
    the section with its dataclass defaults first, however deep the path
    goes.
    """
    d = sc.to_dict()
    for dotted, value in overrides.items():
        parts = dotted.split(".")
        cur = d
        cls: Optional[type] = Scenario
        for part in parts[:-1]:
            if part not in cur:
                raise KeyError(f"override {dotted!r}: no section {part!r}")
            sub_cls = _section_class(cls, cur, part)
            if cur[part] is None:
                cur[part] = _encode(sub_cls()) if sub_cls else {}
            cur = cur[part]
            if not isinstance(cur, dict):
                raise KeyError(f"override {dotted!r}: {part!r} is not a "
                               "section")
            cls = sub_cls
        cur[parts[-1]] = _encode(value)
    return Scenario.from_dict(d)


def parse_set_arg(arg: str) -> Tuple[str, Any]:
    """``key=value`` with the value parsed as JSON when possible (so
    ``--set sim.noise=0.01`` is a float and ``--set node.caps_w=null``
    clears a knob), else kept as a string."""
    if "=" not in arg:
        raise ValueError(f"--set expects key=value, got {arg!r}")
    key, raw = arg.split("=", 1)
    try:
        value = json.loads(raw)
    except json.JSONDecodeError:
        value = raw
    return key.strip(), value


def grid_variants(base: Scenario,
                  grid: Dict[str, List[Any]]) -> List[Tuple[str, Scenario]]:
    """Cartesian sweep over dotted-path value lists.

    Returns ``(label, scenario)`` pairs in row-major order of the given
    keys; each scenario re-validates through the decoder.
    """
    items: List[Tuple[str, List[Any]]] = [(k, list(vs))
                                          for k, vs in grid.items()]
    combos: List[List[Tuple[str, Any]]] = [[]]
    for key, values in items:
        combos = [c + [(key, v)] for c in combos for v in values]
    out = []
    for combo in combos:
        label = ",".join(f"{k}={v}" for k, v in combo)
        out.append((label, with_overrides(base, dict(combo))))
    return out
