"""Unified scenario API: declarative experiment specs, a registry of the
paper's scenarios, and one driver that composes the existing simulation
layers (`C3Sim`/`ClusterSim`/`PowerManager`/`FleetPowerManager`/
`TelemetryCollector`).  ``python -m repro`` is the CLI over this package.
"""
from repro.api.registry import (SCENARIOS, get_scenario, list_scenarios,
                                register, scenario_names, variants)
from repro.api.runner import (BuiltScenario, ScenarioResult, build_scenario,
                              run_scenario)
from repro.api.spec import (SPEC_FORMAT, SPEC_VERSION, EscalationSpec,
                            FaultSpec, ManagerSpec, NodeSpec,
                            ObservabilitySpec, Scenario, TelemetrySpec,
                            WorkloadSpec, grid_variants, with_overrides)

__all__ = [
    "Scenario", "WorkloadSpec", "NodeSpec", "ManagerSpec", "TelemetrySpec",
    "FaultSpec", "EscalationSpec", "ObservabilitySpec",
    "SPEC_FORMAT", "SPEC_VERSION", "with_overrides", "grid_variants",
    "register", "get_scenario", "list_scenarios", "scenario_names",
    "variants", "SCENARIOS",
    "build_scenario", "run_scenario", "BuiltScenario", "ScenarioResult",
]
