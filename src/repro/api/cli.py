"""``python -m repro`` — the single entry point over the scenario API.

Commands:

  list                       table of registered scenarios
  show NAME                  print a scenario's JSON spec
  run NAME|--spec FILE       run a scenario, print metrics (or --json)
  sweep NAME --grid k=v1,v2  grid sweep over dotted-path overrides
  sweep NAME --samples N     Monte-Carlo fleet sweep (versioned artifact)
  replay TRACE.jsonl         offline detect/mitigate over a recorded trace
  monitor NAME|--trace FILE  run with metrics + alert rules (or evaluate
                             the rules offline over a recorded trace) and
                             emit dashboards / incident timelines
  lint [PATHS...]            check the repo's determinism / replay /
                             engine-parity invariants (repro.analysis)

Exit codes: 0 success, 1 runtime failure (for ``lint``: findings or stale
baseline entries), 2 unknown scenario / bad usage (matching
``benchmarks/run.py --only``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.api.registry import get_scenario, list_scenarios, variants
from repro.api.runner import run_scenario
from repro.api.spec import Scenario, TelemetrySpec, parse_set_arg, \
    with_overrides


def _load_scenario(args) -> Scenario:
    """Resolve NAME / --spec into a Scenario; SystemExit(2) on unknown."""
    if getattr(args, "spec", None):
        sc = Scenario.load(args.spec)
    else:
        if not args.name:
            print("error: give a scenario NAME or --spec FILE",
                  file=sys.stderr)
            raise SystemExit(2)
        try:
            sc = get_scenario(args.name)
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            raise SystemExit(2)
    overrides = dict(parse_set_arg(s) for s in (args.set or []))
    if getattr(args, "engine", None):
        key = "fleet.engine" if sc.fleet is not None else "sim.engine"
        overrides.setdefault(key, args.engine)
    if getattr(args, "seed", None) is not None:
        overrides.setdefault("seed", args.seed)
    if overrides:
        sc = with_overrides(sc, overrides)
    return sc


def _add_scenario_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("name", nargs="?", help="registered scenario name")
    p.add_argument("--spec", help="run a JSON scenario file instead")
    p.add_argument("--iterations", type=int, default=None,
                   help="override the scenario's iteration count")
    p.add_argument("--seed", type=int, default=None)
    p.add_argument("--engine", choices=["event", "batched", "vector", "jax"],
                   help="override the simulation engine")
    p.add_argument("--set", action="append", metavar="KEY=VALUE",
                   help="dotted-path override, e.g. --set sim.noise=0.01")


def cmd_list(args) -> int:
    rows = list_scenarios()
    if args.json:
        print(json.dumps([{"name": n, "scope": s, "description": d}
                          for n, s, d in rows], indent=2,
                         sort_keys=True, allow_nan=False))
        return 0
    width = max(len(n) for n, _, _ in rows)
    for name, scope, desc in rows:
        print(f"{name:<{width}s}  {scope:<5s}  {desc}")
    return 0


def cmd_show(args) -> int:
    sc = _load_scenario(args)
    print(sc.to_json())
    return 0


def cmd_run(args) -> int:
    sc = _load_scenario(args)
    if (args.save_trace or args.chrome_trace) and sc.telemetry is None:
        sc = sc.replace(telemetry=TelemetrySpec())   # lossless default
    res = run_scenario(sc, iterations=args.iterations,
                       save_trace_path=args.save_trace,
                       chrome_trace_path=args.chrome_trace)
    payload = res.to_json_dict()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, allow_nan=False)
    if args.json:
        print(json.dumps(payload, indent=2, sort_keys=True,
                         allow_nan=False))
    else:
        from repro.api.reports import format_result
        print(format_result(res))
        if res.trace_path:
            print(f"trace written to {res.trace_path}")
    return 0


def cmd_sweep(args) -> int:
    if args.samples is not None or args.sweep_spec:
        return _cmd_sweep_mc(args)
    sc = _load_scenario(args)
    grid = {}
    for s in args.grid or []:
        key, raw = s.split("=", 1)
        grid[key.strip()] = [parse_set_arg(f"x={v}")[1]
                             for v in raw.split(",")]
    if not grid:
        print("error: sweep needs --samples N (Monte-Carlo), --sweep-spec "
              "FILE, or at least one --grid KEY=V1,V2,...", file=sys.stderr)
        return 2
    rows = []
    for label, variant in variants(sc, grid):
        res = run_scenario(variant, iterations=args.iterations)
        rows.append({"variant": label, **res.metrics})
        if not args.json:
            keys = [k for k in res.metrics
                    if k in ("fleet_tput", "throughput", "detect_accuracy")]
            brief = "  ".join(f"{k}={res.metrics[k]:.4f}" for k in keys)
            print(f"{label:<48s} {brief}")
    if args.json:
        print(json.dumps(rows, indent=2, sort_keys=True, allow_nan=False))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2, sort_keys=True, allow_nan=False)
    return 0


def _cmd_sweep_mc(args) -> int:
    """Monte-Carlo (or spec-file) sweep → versioned artifact.

    ``--samples N`` builds a default `SweepSpec` over the named scenario
    (per-sample thermal lotteries, plus any ``--dist`` distributions);
    ``--sweep-spec FILE`` loads a full spec instead.  The artifact schema
    is documented in docs/sweeps.md.
    """
    from repro.api.sweep import Dist, SweepSpec, run_sweep
    if args.sweep_spec:
        spec = SweepSpec.load(args.sweep_spec)
        if args.name and args.name != spec.scenario:
            print(f"error: --sweep-spec names scenario "
                  f"{spec.scenario!r}, not {args.name!r}", file=sys.stderr)
            return 2
        if args.samples is not None:
            spec = SweepSpec.from_dict({**spec.to_dict(),
                                        "samples": args.samples})
    else:
        if not args.name:
            print("error: give a scenario NAME (or --sweep-spec FILE)",
                  file=sys.stderr)
            return 2
        dists = {}
        for s in args.dist or []:
            key, raw = s.split("=", 1)
            body = json.loads(raw)
            if not isinstance(body, dict):
                raise ValueError(f"--dist {key}: expected a JSON object "
                                 f"like {{\"kind\":\"uniform\",...}}")
            dists[key.strip()] = Dist(**body)
        spec = SweepSpec(scenario=args.name, samples=args.samples,
                         dists=dists, seed=args.seed or 0,
                         iterations=args.iterations)
    artifact = run_sweep(spec)
    text = json.dumps(artifact, indent=2, sort_keys=True, allow_nan=False)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.json:
        print(text)
    else:
        s = artifact["summary"]
        print(f"{artifact['scenario']}  mode={artifact['mode']}  "
              f"engine={artifact['engine']}  n={artifact['n_samples']}")
        for name in ("t_fleet_s", "throughput", "lead_max_s", "recovery"):
            q = s[name]
            print(f"  {name:<13s} mean={q['mean']:.5g}  p10={q['p10']:.5g}"
                  f"  p50={q['p50']:.5g}  p90={q['p90']:.5g}")
        if args.out:
            print(f"artifact written to {args.out}")
    return 0


def cmd_replay(args) -> int:
    import numpy as np

    from repro.core.manager import FleetManagerConfig, ManagerConfig
    from repro.telemetry import (detection_report, fleet_lead_report,
                                 load_trace, replay_fleet, replay_node)
    trace = load_trace(args.trace)
    scope = args.scope
    if scope == "auto":
        scope = "fleet" if trace.fleet else "node"
    out = {"trace": args.trace, "scope": scope}
    esc_trace = ("escalation" in (trace.meta or {})
                 or any(e.source == "escalation" for e in trace.events))
    if scope == "fleet" and esc_trace:
        # healing traces change fleet width across drain epochs, so the
        # budget replay does not apply; re-run the escalation decisions
        # instead and check them bit-for-bit against the recording
        from repro.telemetry import (escalation_replay_matches,
                                     replay_escalation)
        rp = replay_escalation(trace)
        mismatches: List[str] = []
        out["escalation_events"] = len(rp.events)
        out["drained_nodes"] = rp.drained_nodes
        out["replay_matches"] = bool(
            escalation_replay_matches(trace, rp, log=mismatches))
        if mismatches:
            out["mismatches"] = mismatches
    elif scope == "fleet":
        cfg = FleetManagerConfig(use_case=args.use_case, sampling_period=2,
                                 warmup=2, window_size=2, node_window_size=2,
                                 power_cap=700.0)
        rp = replay_fleet(trace, cfg, tune_after=args.tune_after or 0)
        out["budget_adjustments"] = len(rp.budget_log)
        out["final_caps"] = np.asarray(rp.final_caps).tolist()
    else:
        cfg = ManagerConfig(use_case=args.use_case, sampling_period=2,
                            warmup=3, window_size=2, power_cap=700.0)
        rp = replay_node(trace, cfg, node=args.node,
                         tune_after=args.tune_after)
        out["cap_adjustments"] = len(rp.cap_schedule)
        out["final_caps"] = np.asarray(rp.final_caps).tolist()
        if args.export_caps:
            rp.export_caps(args.export_caps)
            out["caps_file"] = args.export_caps
    try:
        rep = detection_report(trace, node=args.node)
        out["detect"] = {"accuracy": rep.accuracy,
                         "accuracy_imputed": rep.accuracy_imputed,
                         "lead_rel_error": rep.lead_rel_error,
                         "majority_correct": rep.majority_correct}
    except ValueError:
        pass
    try:
        frep = fleet_lead_report(trace)
        out["fleet_lead"] = {"accuracy": frep.accuracy,
                             "lead_rel_error": frep.lead_rel_error,
                             "majority_correct": frep.majority_correct}
    except ValueError:
        pass
    if args.json:
        print(json.dumps(out, indent=2, sort_keys=True, allow_nan=False))
    else:
        for k, v in out.items():
            print(f"{k}: {v}")
    return 0


def _nanless(obj):
    """JSON-safe copy: NaN/Inf become None (the monitor payload mixes
    score dicts that legally carry NaN)."""
    import math
    if isinstance(obj, dict):
        return {k: _nanless(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_nanless(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


def cmd_monitor(args) -> int:
    """Live observability run, or offline rule evaluation over a recorded
    trace.  ``--check-replay`` re-evaluates the rules from the trace and
    exits 1 unless the firings match the recorded ones bit-for-bit."""
    import math

    from repro.api.spec import ObservabilitySpec
    from repro.obs import (alert_replay_matches, render_dashboard,
                           replay_alerts, save_incidents, score_alerts,
                           terminal_summary, transitions_to_records)
    from repro.telemetry import load_trace
    from repro.telemetry.trace_io import TelemetryTrace

    out = {}
    if args.trace:
        trace = load_trace(args.trace)
        pipe = replay_alerts(trace)
        out["trace"] = args.trace
        if not any(e.source == "alert" for e in trace.events):
            if args.check_replay:
                print("error: --check-replay needs a trace recorded with "
                      "observability (no alert rows found)", file=sys.stderr)
                return 2
            # recorded without alert rows (record_alerts off, or a
            # degraded copy): inject the replayed firings so incidents
            # and the dashboard have something to annotate
            trace.events = sorted(
                trace.events + transitions_to_records(pipe.transitions),
                key=lambda e: e.iteration)
    else:
        sc = _load_scenario(args)
        if sc.observability is None:
            sc = sc.replace(observability=ObservabilitySpec())
        if sc.telemetry is None:
            sc = sc.replace(telemetry=TelemetrySpec())
        res = run_scenario(sc, iterations=args.iterations,
                           save_trace_path=args.save_trace)
        trace = TelemetryTrace.from_collector(res.collector)
        pipe = res.obs
        out["scenario"] = sc.name or None
        out["metrics"] = res.metrics
        if args.save_trace:
            out["trace_path"] = args.save_trace
    patience = float((trace.meta.get("escalation") or {}).get(
        "patience_s", math.nan))
    out["transitions"] = len(pipe.transitions)
    out["alerts"] = score_alerts(trace, patience_s=patience)
    if args.check_replay:
        mismatches: List[str] = []
        out["replay_matches"] = bool(
            alert_replay_matches(trace, log=mismatches))
        if mismatches:
            out["mismatches"] = mismatches[:20]
    if args.dashboard:
        render_dashboard(trace, args.dashboard)
        out["dashboard"] = args.dashboard
    if args.incidents:
        save_incidents(trace, args.incidents)
        out["incidents_file"] = args.incidents
    if args.metrics:
        if args.metrics.endswith(".jsonl"):
            pipe.registry.snapshot_jsonl(args.metrics)
        else:
            with open(args.metrics, "w") as f:
                f.write(pipe.registry.exposition())
        out["metrics_file"] = args.metrics
    if args.out:
        with open(args.out, "w") as f:
            json.dump(_nanless(out), f, indent=2, sort_keys=True,
                      allow_nan=False)
    if args.json:
        print(json.dumps(_nanless(out), indent=2, sort_keys=True,
                         allow_nan=False))
    else:
        print(terminal_summary(trace, patience_s=patience))
        for key in ("dashboard", "incidents_file", "metrics_file",
                    "trace_path"):
            if key in out:
                print(f"{key.replace('_file', '')} written to {out[key]}")
    return 1 if out.get("replay_matches") is False else 0


def cmd_lint(args) -> int:
    from pathlib import Path

    from repro.analysis import (RULES, lint_paths, render_json, render_text,
                                update_baseline)
    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid].title}")
        return 0
    rules = ([s.strip() for s in args.rules.split(",") if s.strip()]
             if args.rules else None)
    result, baseline = lint_paths(paths=args.paths or None, root=args.root,
                                  rules=rules, baseline_path=args.baseline)
    if args.update_baseline:
        raw = sorted(result.findings + result.suppressed)
        refreshed = update_baseline(baseline, raw)
        path = baseline.path or str(Path(result.root) / "lint_baseline.json")
        refreshed.save(path)
        print(f"baseline rewritten: {path} ({len(refreshed.entries)} "
              f"entr{'y' if len(refreshed.entries) == 1 else 'ies'}; review "
              f"any UNREVIEWED reasons before committing)")
        return 0
    report = render_json(result)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report + "\n")
    if args.json:
        print(report)
    else:
        print(render_text(result))
    return result.exit_code()


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Lit Silicon scenario runner (see README 'Scenario "
                    "API')")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("list", help="list registered scenarios")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_list)

    p = sub.add_parser("show", help="print a scenario's JSON spec")
    _add_scenario_args(p)
    p.set_defaults(fn=cmd_show)

    p = sub.add_parser("run", help="run a scenario and print its metrics")
    _add_scenario_args(p)
    p.add_argument("--json", action="store_true",
                   help="print the result as JSON")
    p.add_argument("--out", help="also write the result JSON to a file")
    p.add_argument("--save-trace", metavar="PATH",
                   help="record + write a telemetry JSONL trace")
    p.add_argument("--chrome-trace", metavar="PATH",
                   help="also write a Perfetto-loadable Chrome trace")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("sweep",
                       help="grid or Monte-Carlo sweep a scenario")
    _add_scenario_args(p)
    p.add_argument("--grid", action="append", metavar="KEY=V1,V2,...",
                   help="dotted-path grid axis (repeatable)")
    p.add_argument("--samples", type=int, default=None, metavar="N",
                   help="Monte-Carlo mode: N samples over the fleet "
                        "distributions (emits a sweep artifact)")
    p.add_argument("--dist", action="append", metavar="KEY=JSON",
                   help="Monte-Carlo distribution for a dotted path, e.g. "
                        "--dist fleet.straggler_boost="
                        "'{\"kind\":\"uniform\",\"low\":1.1,\"high\":1.5}'")
    p.add_argument("--sweep-spec", metavar="FILE",
                   help="load a full SweepSpec JSON instead of --samples")
    p.add_argument("--json", action="store_true")
    p.add_argument("--out", help="write rows / sweep artifact JSON to FILE")
    p.set_defaults(fn=cmd_sweep)

    p = sub.add_parser("replay",
                       help="offline detect/mitigate over a recorded trace")
    p.add_argument("trace", help="telemetry JSONL file (save_trace output)")
    p.add_argument("--scope", choices=["auto", "node", "fleet"],
                   default="auto")
    p.add_argument("--use-case", default="gpu-realloc")
    p.add_argument("--tune-after", type=int, default=None)
    p.add_argument("--node", type=int, default=0)
    p.add_argument("--export-caps", metavar="PATH",
                   help="write the replayed converged caps file")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_replay)

    p = sub.add_parser("monitor",
                       help="run with the observability pipeline, or "
                            "evaluate alert rules offline over a trace")
    _add_scenario_args(p)
    p.add_argument("--trace", metavar="FILE",
                   help="offline mode: evaluate the rules over this "
                        "recorded telemetry JSONL instead of running")
    p.add_argument("--check-replay", action="store_true",
                   help="verify offline rule evaluation reproduces the "
                        "recorded alert firings bit-for-bit (exit 1 on "
                        "mismatch)")
    p.add_argument("--dashboard", metavar="PATH",
                   help="write the HTML fleet-health dashboard")
    p.add_argument("--incidents", metavar="PATH",
                   help="write the incident timeline JSONL")
    p.add_argument("--metrics", metavar="PATH",
                   help="write the metrics snapshot (Prometheus text, or "
                        "JSONL when PATH ends in .jsonl)")
    p.add_argument("--save-trace", metavar="PATH",
                   help="record + write the telemetry JSONL trace "
                        "(live mode)")
    p.add_argument("--json", action="store_true")
    p.add_argument("--out", help="also write the JSON payload to a file")
    p.set_defaults(fn=cmd_monitor)

    p = sub.add_parser("lint",
                       help="check the repo's determinism / replay / "
                            "engine-parity invariants (static analysis)")
    p.add_argument("paths", nargs="*",
                   help="files or directories to lint (default: src/repro, "
                        "scripts, benchmarks, examples under the repo root)")
    p.add_argument("--root", help="repo root for scope/baseline path "
                                  "resolution (default: auto-detected)")
    p.add_argument("--baseline", metavar="FILE|none",
                   help="baseline file of reviewed, accepted findings "
                        "(default: <root>/lint_baseline.json if present; "
                        "'none' disables suppression)")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline from the current findings: "
                        "keep still-matching entries, drop stale ones, add "
                        "UNREVIEWED entries for new findings")
    p.add_argument("--rules", metavar="CSV",
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    p.add_argument("--json", action="store_true",
                   help="print the JSON report instead of text")
    p.add_argument("--out", help="also write the JSON report to a file")
    p.set_defaults(fn=cmd_lint)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except SystemExit as e:                      # _load_scenario usage errors
        return int(e.code or 0)
    except (ValueError, KeyError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except Exception as e:                       # genuine runtime failure
        print(f"error: {type(e).__name__}: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
