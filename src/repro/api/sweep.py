"""Monte-Carlo and cartesian fleet sweeps over scenario distributions.

A :class:`SweepSpec` turns one registered fleet scenario into a *population*
of runs: distributions over scalar knobs (``fleet.straggler_boost``, sim
noise, …), per-node device-preset draws, and per-sample thermal-lottery
seeds (fresh ``r_th`` spreads — the silicon lottery variability studies
sample over).  Sampling is Monte-Carlo (``samples`` draws from ``seed``) or
cartesian (``grid`` axes, same dotted-path format as the CLI ``--grid``).

Execution compiles the whole population into as few device programs as
possible: every sample whose *static* shape (fleet size, topology, workload
plan, iteration count) matches runs inside one batched
:func:`repro.core.jax_engine.run_fleet_scan` — a single ``vmap``-ed XLA
program over the sample axis.  Without JAX the sweep falls back to
per-sample ``ClusterSim`` stepping (same physics, numpy speed).  Both paths
drop any closed-loop manager: sweeps measure the *open-loop* fleet
dynamics, so the distribution reflects thermal imbalance rather than the
mitigation policy.

The result is a versioned JSON artifact (``format: lit-silicon-sweep``,
schema documented in docs/sweeps.md): per-sample fleet metrics — tail-mean
``t_fleet``, throughput, worst node lead, fleet power, and ``recovery``
(throughput relative to a healthy reference fleet with every boost and
churn multiplier at 1.0) — plus summary quantiles over the population.

Reproducibility contract (tested in tests/test_scenario_api.py):

  * the same `SweepSpec` always produces the same sample overrides, the
    same thermal lotteries, and the same per-iteration noise keys;
  * `SweepSpec` round-trips through JSON exactly (the scenario codec's
    ``{"$float": ...}`` discipline for NaN/±Inf);
  * sample ``k`` of an N-sample sweep equals sample ``k`` of an M-sample
    sweep for ``k < min(N, M)`` — draws are keyed per sample, not shared.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.api.spec import (Scenario, _decode_value, _encode,
                            with_overrides)

SWEEP_SPEC_FORMAT = "lit-silicon-sweep-spec"
SWEEP_FORMAT = "lit-silicon-sweep"
SWEEP_VERSION = 1

__all__ = ["Dist", "SweepSpec", "run_sweep", "summarize",
           "SWEEP_FORMAT", "SWEEP_SPEC_FORMAT", "SWEEP_VERSION"]


# --------------------------------------------------------------------------- #
# sampling spec
# --------------------------------------------------------------------------- #
@dataclass
class Dist:
    """One scalar sampling distribution for a dotted scenario path.

    ``kind``: ``"uniform"`` (low/high), ``"loguniform"`` (low/high > 0),
    ``"normal"`` (mean/std), or ``"choice"`` (uniform over ``choices``,
    which may hold any JSON value — preset names, bools, …).
    """

    kind: str = "uniform"
    low: float = 0.0
    high: float = 1.0
    mean: float = 0.0
    std: float = 1.0
    choices: Optional[List[Any]] = None

    def validate(self, path: str) -> None:
        """Check kind-specific invariants; ``path`` labels the error."""
        if self.kind not in ("uniform", "loguniform", "normal", "choice"):
            raise ValueError(f"{path}: unknown Dist kind {self.kind!r}")
        if self.kind == "choice" and not self.choices:
            raise ValueError(f"{path}: kind='choice' needs choices")
        if self.kind == "loguniform" and self.low <= 0:
            raise ValueError(f"{path}: loguniform needs low > 0")

    def sample(self, rng: np.random.Generator) -> Any:
        """One draw from the distribution using ``rng``."""
        if self.kind == "uniform":
            return float(rng.uniform(self.low, self.high))
        if self.kind == "loguniform":
            return float(math.exp(rng.uniform(math.log(self.low),
                                              math.log(self.high))))
        if self.kind == "normal":
            return float(self.mean + self.std * rng.standard_normal())
        return self.choices[int(rng.integers(len(self.choices)))]


@dataclass
class SweepSpec:
    """A population of runs over one registered fleet scenario.

    Monte-Carlo mode (``grid`` unset): ``samples`` draws, each sampling
    every entry of ``dists`` (dotted scenario path → `Dist`), optionally
    redrawing per-node presets iid from ``node_preset_pool``, and — when
    ``vary_thermal_seed`` — taking a fresh thermal-lottery seed
    (``scenario seed + sample index``) so each sample is a different
    silicon/cooling draw.  Cartesian mode (``grid`` set): one run per cell
    of the axes' cartesian product; ``samples``/``dists`` are ignored.

    ``seed`` drives the override sampling *and* the per-sample iteration
    noise keys; two sweeps with the same spec are identical populations.
    """

    scenario: str = ""
    samples: int = 16
    seed: int = 0
    iterations: Optional[int] = None        # None: the scenario's own count
    dists: Dict[str, Dist] = field(default_factory=dict)
    node_preset_pool: Optional[List[str]] = None
    vary_thermal_seed: bool = True
    grid: Optional[Dict[str, List[Any]]] = None

    # -------------------------------------------------------------- checks
    def validate(self) -> "SweepSpec":
        """Check the spec is runnable (scenario named, sane counts, every
        Dist valid); returns self so it chains."""
        if not self.scenario:
            raise ValueError("SweepSpec.scenario must name a registered "
                             "scenario")
        if self.grid is None and self.samples < 1:
            raise ValueError("SweepSpec.samples must be >= 1")
        for path, dist in self.dists.items():
            dist.validate(f"dists[{path!r}]")
        return self

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """JSON-safe nested dict (same NaN/Inf escaping as `Scenario`)."""
        return _encode(self)

    def to_json(self, indent: Optional[int] = 2) -> str:
        """Versioned sweep-spec document: ``{format, version, sweep}``."""
        return json.dumps({"format": SWEEP_SPEC_FORMAT,
                           "version": SWEEP_VERSION,
                           "sweep": self.to_dict()},
                          indent=indent, sort_keys=True, allow_nan=False)

    @classmethod
    def from_dict(cls, d: dict) -> "SweepSpec":
        """Inverse of `to_dict`; unknown keys rejected at both the spec
        and the per-Dist level, result validated."""
        if not isinstance(d, dict):
            raise ValueError("sweep: expected an object")
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - names)
        if unknown:
            raise ValueError(f"sweep: unknown key(s) {unknown} "
                             f"(known: {sorted(names)})")
        kw = {k: _decode_value(v, f"sweep.{k}") for k, v in d.items()
              if k != "dists"}
        dists = {}
        for path, dd in (d.get("dists") or {}).items():
            if not isinstance(dd, dict):
                raise ValueError(f"sweep.dists[{path!r}]: expected an "
                                 "object")
            dnames = {f.name for f in dataclasses.fields(Dist)}
            unknown = sorted(set(dd) - dnames)
            if unknown:
                raise ValueError(f"sweep.dists[{path!r}]: unknown key(s) "
                                 f"{unknown}")
            dists[path] = Dist(**{k: _decode_value(v,
                                                   f"sweep.dists[{path}].{k}")
                                  for k, v in dd.items()})
        kw["dists"] = dists
        return cls(**kw).validate()

    @classmethod
    def from_json(cls, text: str) -> "SweepSpec":
        """Parse a sweep-spec document, checking format/version."""
        data = json.loads(text)
        if not isinstance(data, dict) or data.get("format") != SWEEP_SPEC_FORMAT:
            raise ValueError(f"not a {SWEEP_SPEC_FORMAT} document")
        if int(data.get("version", 0)) > SWEEP_VERSION:
            raise ValueError(f"sweep-spec version {data['version']} is "
                             f"newer than supported {SWEEP_VERSION}")
        if "sweep" not in data:
            raise ValueError("sweep-spec document carries no 'sweep' body")
        return cls.from_dict(data["sweep"])

    def save(self, path: str) -> None:
        """Write the `to_json` document to ``path``."""
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "SweepSpec":
        """Read a sweep-spec document from ``path``."""
        with open(path) as f:
            return cls.from_json(f.read())


# --------------------------------------------------------------------------- #
# sample materialization
# --------------------------------------------------------------------------- #
_HEALTHY = {"fleet.straggler_boost": 1.0, "fleet.healthy_boost": 1.0,
            "fleet.churn": None}


def _sample_overrides(spec: SweepSpec, base: Scenario
                      ) -> List[Tuple[str, Dict[str, Any], int]]:
    """(label, overrides, thermal_seed) per sample, deterministically.

    Each sample gets its own child generator (seeded ``(spec.seed, k)``) so
    the population is prefix-stable: growing ``samples`` never changes
    earlier draws.
    """
    out = []
    if spec.grid is not None:
        combos: List[List[Tuple[str, Any]]] = [[]]
        for key, values in spec.grid.items():
            combos = [c + [(key, v)] for c in combos for v in values]
        for combo in combos:
            label = ",".join(f"{k}={_fmt(v)}" for k, v in combo)
            out.append((label, dict(combo), base.seed))
        return out
    n_nodes = base.fleet.n_nodes if base.fleet is not None else 0
    for k in range(spec.samples):
        rng = np.random.default_rng([spec.seed, k])
        ov: Dict[str, Any] = {}
        for path in sorted(spec.dists):
            ov[path] = spec.dists[path].sample(rng)
        if spec.node_preset_pool:
            pool = spec.node_preset_pool
            ov["fleet.node_presets"] = [
                pool[int(i)] for i in rng.integers(len(pool), size=n_nodes)]
        seed = base.seed + k if spec.vary_thermal_seed else base.seed
        label = f"sample={k}" + "".join(
            f",{p}={_fmt(v)}" for p, v in sorted(ov.items()))
        out.append((label, ov, seed))
    return out


def _fmt(v: Any) -> str:
    return f"{v:.4g}" if isinstance(v, float) else str(v)


# --------------------------------------------------------------------------- #
# execution
# --------------------------------------------------------------------------- #
def _tail(x: np.ndarray, n: int = 30) -> np.ndarray:
    return x[-min(n, len(x)):]


def _series_metrics(t_fleet: np.ndarray, lead_max: np.ndarray,
                    power: np.ndarray) -> Dict[str, float]:
    return {
        "t_fleet_s": float(np.mean(_tail(t_fleet))),
        "throughput": float(np.mean(1.0 / _tail(t_fleet))),
        "lead_max_s": float(np.mean(_tail(lead_max))),
        "fleet_power_w": float(np.mean(_tail(power))),
    }


def _run_batch_jax(variants: List[Scenario],
                   seeds: List[int], noise_seeds: List[int],
                   iterations: int) -> Optional[List[Dict[str, float]]]:
    """All samples whose static shape matches, as one vmapped scan program;
    None when shapes diverge (caller falls back to per-sample runs)."""
    from repro.core.jax_engine import (HAS_JAX, build_fleet_arrays,
                                       fleet_scan_spec, run_fleet_scan)
    if not HAS_JAX:
        return None
    specs, arrays = [], []
    for sc, seed, nseed in zip(variants, seeds, noise_seeds):
        wl = sc.workload.build()
        if sc.fleet.topology not in ("dp", "pp", "tp"):
            return None
        specs.append(fleet_scan_spec(wl, sc.sim, sc.fleet, iterations,
                                     collect="summary",
                                     devices_per_node=sc.node.devices))
        arrays.append(build_fleet_arrays(
            wl, sc.node.build_preset(), sc.sim, sc.fleet, sc.node.caps_w,
            seed, devices_per_node=sc.node.devices, rng_seed=nseed))
    if len(set(specs)) != 1:
        return None                       # mixed shapes: no single program
    stacked = {k: np.stack([a[k] for a in arrays]) for k in arrays[0]}
    out = run_fleet_scan(specs[0], stacked)
    return [_series_metrics(out["t_fleet"][i], out["lead_max"][i],
                            out["fleet_power"][i])
            for i in range(len(variants))]


def _run_one_python(sc: Scenario, seed: int,
                    iterations: int) -> Dict[str, float]:
    """Per-sample fallback: plain ClusterSim stepping (numpy engines)."""
    from repro.api.runner import build_scenario
    built = build_scenario(sc.replace(seed=seed), iterations=iterations)
    for _ in range(iterations):
        built.cluster.step()
    h = built.cluster.history
    return _series_metrics(
        np.array([x["t_fleet"] for x in h]),
        np.array([np.max(x["lead"]) for x in h]),
        np.array([x["power"] for x in h]))


def summarize(values: Dict[str, List[float]]) -> Dict[str, Dict[str, float]]:
    """Mean + p10/p50/p90 per metric over the sample population."""
    out = {}
    for name, xs in values.items():
        arr = np.asarray(xs, float)
        out[name] = {
            "mean": float(np.mean(arr)),
            "p10": float(np.percentile(arr, 10)),
            "p50": float(np.percentile(arr, 50)),
            "p90": float(np.percentile(arr, 90)),
        }
    return out


def run_sweep(spec: SweepSpec) -> dict:
    """Execute the sweep and return the artifact dict (see docs/sweeps.md).

    Raises ``ValueError`` for non-fleet scenarios — sweeps are fleet
    populations by definition (node-level studies sweep via the CLI
    ``--grid`` rows instead).
    """
    from repro.api.registry import get_scenario
    spec.validate()
    base = get_scenario(spec.scenario)
    if base.fleet is None:
        raise ValueError(f"sweep requires a fleet scenario; "
                         f"{spec.scenario!r} is node-scoped")
    base = base.replace(manager=None)       # open-loop population
    iters = (base.iterations if spec.iterations is None
             else int(spec.iterations))
    mode = "grid" if spec.grid is not None else "mc"

    cells = _sample_overrides(spec, base)
    # the healthy reference rides the same batch as its final row
    variants = [with_overrides(base, ov) for _, ov, _ in cells]
    variants.append(with_overrides(base, dict(_HEALTHY)))
    seeds = [s for _, _, s in cells] + [base.seed]
    noise_seeds = [spec.seed * 1_000_003 + k for k in range(len(cells))]
    # the reference's noise stream sits far past any realistic sample index
    noise_seeds.append(spec.seed * 1_000_003 + 999_999_937)

    rows = _run_batch_jax(variants, seeds, noise_seeds, iters)
    engine = "jax-scan"
    if rows is None:
        engine = "python"
        rows = [_run_one_python(sc, seed, iters)
                for sc, seed in zip(variants, seeds)]
    ref = rows.pop()
    ref_tput = max(ref["throughput"], 1e-12)

    samples = []
    for (label, ov, seed), row in zip(cells, rows):
        samples.append({
            "sample": len(samples), "label": label,
            "overrides": _encode(ov), "thermal_seed": seed,
            **row, "recovery": row["throughput"] / ref_tput,
        })
    names = ("t_fleet_s", "throughput", "lead_max_s", "fleet_power_w",
             "recovery")
    summary = summarize({n: [s[n] for s in samples] for n in names})
    return {
        "format": SWEEP_FORMAT, "version": SWEEP_VERSION,
        "scenario": spec.scenario, "mode": mode, "engine": engine,
        "seed": spec.seed, "iterations": iters,
        "n_samples": len(samples),
        "sweep_spec": spec.to_dict(),
        "reference": ref,
        "samples": samples,
        "summary": summary,
    }
