"""Registry of named scenarios: the paper's experiments as specs.

Every entry is a zero-argument factory returning a *fresh* `Scenario`
(factories, not singletons, so callers can mutate freely), registered under
a ``family/name`` key.  The configurations are the exact ones the pre-API
example scripts and benchmarks hand-wired — the equivalence tests in
tests/test_scenario_api.py pin several of them bit-for-bit against the old
glue — so `python -m repro run <name>` reproduces the corresponding study.

`variants` sweeps a registered scenario over dotted-path grids (the CLI
``sweep`` command and examples/telemetry_study.py ride it).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from repro.api.spec import (ManagerSpec, NodeSpec, ObservabilitySpec,
                            Scenario, ServeSpec, TelemetrySpec,
                            WorkloadSpec, grid_variants)
from repro.core.c3sim import SimConfig
from repro.core.cluster import ClusterConfig
from repro.core.escalate import EscalationConfig
from repro.core.faults import FaultEvent, FaultModel
from repro.core.manager import FleetManagerConfig, ManagerConfig
from repro.core.thermal import ChurnEvent, ChurnModel
from repro.telemetry.sensors import ROCM_SMI_LIKE

__all__ = ["register", "get_scenario", "list_scenarios", "scenario_names",
           "variants", "SCENARIOS"]

SCENARIOS: Dict[str, Callable[[], Scenario]] = {}

CAP_W = 700.0


def register(factory: Callable[[], Scenario]) -> Callable[[], Scenario]:
    """Register a scenario factory under the name it assigns."""
    sc = factory()
    if not sc.name:
        raise ValueError("registered scenarios must set Scenario.name")
    if sc.name in SCENARIOS:
        raise ValueError(f"duplicate scenario name {sc.name!r}")
    sc.validate()
    SCENARIOS[sc.name] = factory
    return factory


def get_scenario(name: str) -> Scenario:
    """A fresh instance of the named scenario; KeyError lists what exists."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{', '.join(scenario_names())}")
    return SCENARIOS[name]()


def scenario_names() -> List[str]:
    """Sorted registry names (the `python -m repro list` order)."""
    return sorted(SCENARIOS)


def list_scenarios() -> List[Tuple[str, str, str]]:
    """(name, scope, description) rows for the CLI table."""
    rows = []
    for name in scenario_names():
        sc = SCENARIOS[name]()
        scope = "fleet" if sc.fleet is not None else "node"
        rows.append((name, scope, sc.description))
    return rows


def variants(name_or_scenario, grid: Dict[str, list]
             ) -> List[Tuple[str, Scenario]]:
    """Grid sweep over a named (or given) scenario; see `grid_variants`."""
    base = (get_scenario(name_or_scenario)
            if isinstance(name_or_scenario, str) else name_or_scenario)
    return grid_variants(base, grid)


# --------------------------------------------------------------------------- #
# shared building blocks (paper Table II defaults)
# --------------------------------------------------------------------------- #
def _sim() -> SimConfig:
    # calibrated defaults every study uses: seed 1, 40 GB/s collectives,
    # the batched engine (trace-identical to the event reference)
    return SimConfig(seed=1, comm_gbps=40.0, engine="batched")


def _wl8() -> WorkloadSpec:
    # the cluster studies' reduced 8-layer Llama (fast, same dynamics)
    return WorkloadSpec(arch="llama3.1-8b", n_layers=8)


def _node_mgr(use_case: str) -> ManagerSpec:
    return ManagerSpec(scope="node", config=ManagerConfig(
        use_case=use_case, sampling_period=2, warmup=3, window_size=2,
        power_cap=CAP_W, cpu_budget=20.0))


def _fleet_mgr(n_nodes: int) -> ManagerSpec:
    return ManagerSpec(scope="fleet", tune_after=20,
                       config=FleetManagerConfig(
                           use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=CAP_W,
                           cluster_power_budget=n_nodes * 8 * CAP_W))


def _managed_fleet(topology: str) -> Scenario:
    return Scenario(
        name=f"cluster/{topology}",
        description=(f"4-node {topology} fleet, one hot GPU on node 0, "
                     "hierarchical FleetPowerManager under a fixed "
                     "cluster budget"),
        workload=_wl8(), sim=_sim(),
        node=NodeSpec(caps_w=CAP_W),
        fleet=ClusterConfig(n_nodes=4, straggler_boost=1.28,
                            topology=topology),
        manager=_fleet_mgr(4), iterations=120, seed=5)


# --------------------------------------------------------------------------- #
# paper/* — the node-level studies (Table I / Figs 3-9)
# --------------------------------------------------------------------------- #
@register
def paper_characterization() -> Scenario:
    return Scenario(
        name="paper/characterization",
        description="settle one node at TDP and expose the straggler / "
                    "lead-wave structure (paper Figs 3-7)",
        workload=WorkloadSpec(), sim=_sim(),
        node=NodeSpec(), iterations=45, seed=1)


def _paper_use_case(name: str, use_case: str, blurb: str) -> Scenario:
    return Scenario(
        name=name,
        description=f"closed-loop {use_case} on one node ({blurb})",
        workload=WorkloadSpec(), sim=_sim(), node=NodeSpec(),
        manager=_node_mgr(use_case), iterations=200, seed=1)


@register
def paper_table1_tdp() -> Scenario:
    return _paper_use_case("paper/table1-tdp", "gpu-red",
                           "no node cap: leaders capped down, power drops")


@register
def paper_node_cap() -> Scenario:
    return _paper_use_case("paper/node-cap", "gpu-realloc",
                           "node cap below provisioned: straggler boosted "
                           "at equal node power")


@register
def paper_cpu_slosh() -> Scenario:
    return _paper_use_case("paper/cpu-slosh", "cpu-slosh",
                           "idle-CPU budget sloshed to the devices")


# --------------------------------------------------------------------------- #
# cluster/* — fleet-scale scenarios
# --------------------------------------------------------------------------- #
@register
def cluster_dp() -> Scenario:
    return _managed_fleet("dp")


@register
def cluster_pp() -> Scenario:
    return _managed_fleet("pp")


@register
def cluster_tp() -> Scenario:
    return _managed_fleet("tp")


@register
def cluster_hetero_cooling() -> Scenario:
    return Scenario(
        name="cluster/hetero-cooling",
        description="mixed air-/liquid-cooled fleet: the preset, not a "
                    "boosted device, creates the straggler",
        workload=_wl8(), sim=_sim(), node=NodeSpec(caps_w=CAP_W),
        fleet=ClusterConfig(n_nodes=4, straggler_boost=1.0,
                            inter_node_gbps=100.0,
                            node_presets=["mi300x", "mi300x-air",
                                          "mi300x", "mi300x"]),
        iterations=50, seed=5)


@register
def cluster_churn() -> Scenario:
    # event times pinned to the benchmark's probed schedule (~0.395 s per
    # fleet iteration at 100 GB/s): emerge on node 0 at t=0, migrate to
    # node 2 at ~40% of an 80-iteration horizon
    return Scenario(
        name="cluster/churn",
        description="cooling churn: a straggler emerges on node 0 and "
                    "migrates to node 2 mid-run",
        workload=_wl8(), sim=_sim(), node=NodeSpec(caps_w=CAP_W),
        fleet=ClusterConfig(
            n_nodes=4, straggler_boost=1.0, inter_node_gbps=100.0,
            churn={0: ChurnModel(events=[ChurnEvent(0.0, 3, 1.35)]),
                   2: ChurnModel(events=[ChurnEvent(12.6, 5, 1.8)])}),
        iterations=80, seed=5)


def _heal_faults() -> FaultModel:
    # the pinned fault schedule (seed 5, ~0.4 s healthy steps): a transient
    # kernel hang on node 1 the patience window must ride out, then a
    # thermal runaway on node 2 device 3 whose chip falls off the bus 10 s
    # later — the unrecoverable straggler no cap schedule can fix
    return FaultModel(events=[
        FaultEvent(t=4.0, kind="kernel_hang", node=1, magnitude=2.2,
                   duration=2.5),
        FaultEvent(t=12.0, kind="thermal_runaway", node=2, device=3,
                   magnitude=0.4),
        FaultEvent(t=22.0, kind="device_loss", node=2, device=3),
    ])


def _fault_fleet(name: str, blurb: str, escalation,
                 observability=None) -> Scenario:
    return Scenario(
        name=name, description=blurb,
        workload=_wl8(), sim=_sim(), node=NodeSpec(caps_w=CAP_W),
        fleet=ClusterConfig(n_nodes=4, straggler_boost=1.28,
                            inter_node_gbps=100.0),
        manager=_fleet_mgr(4), telemetry=TelemetrySpec(),
        faults=_heal_faults(), escalation=escalation,
        observability=observability, iterations=160, seed=5)


@register
def cluster_fault_heal() -> Scenario:
    return _fault_fleet(
        "cluster/fault-heal",
        "transient hang + thermal runaway ending in device loss; the "
        "escalation policy detects, drains node 2 and elastically "
        "restarts on 3 nodes (goodput-scored); the default alert rules "
        "watch the same run",
        EscalationConfig(), observability=ObservabilitySpec())


@register
def cluster_fault_ignored() -> Scenario:
    return _fault_fleet(
        "cluster/fault-ignored",
        "the same fault schedule with drain_mode='never': the fleet "
        "limps behind the dead chip — the ablation fault-heal must beat",
        EscalationConfig(drain_mode="never"))


# --------------------------------------------------------------------------- #
# serve/* — inference serving under production traffic
# --------------------------------------------------------------------------- #
SERVE_CAP_W = 600.0        # initial per-GPU cap: every node cap-bound, so
#                            budget reallocation has real frequency authority
SERVE_BUDGET_W = 20000.0   # cluster budget (625 W/GPU avg): slack above the
#                            uniform split, below the 4*8*750 TDP sum


def _serve_wl() -> WorkloadSpec:
    # decode-shaped iteration: few layers, modest batch, long context —
    # one engine step ~0.19 s on a healthy node (probed at seed 5)
    return WorkloadSpec(arch="llama3.1-8b", n_layers=4, batch=2, seq=4096)


def _serve_fleet() -> ClusterConfig:
    # the pinned hot-node preset: node 0 sits in the air-cooled chassis
    # (same silicon, worse heat path) with the paper-default per-node
    # straggler device — at 600 W caps it serves ~6% slower than its
    # liquid-cooled peers, and stays *cap-bound* (no hard-throttle
    # spiral), so the fleet manager can actually buy the speed back
    return ClusterConfig(n_nodes=4, straggler_boost=1.28,
                         inter_node_gbps=100.0,
                         node_presets=["mi300x-air", "mi300x",
                                       "mi300x", "mi300x"])


def _serve_mgr(objective: str) -> ManagerSpec:
    return ManagerSpec(scope="fleet", tune_after=60,
                       config=FleetManagerConfig(
                           use_case="gpu-realloc", sampling_period=2,
                           warmup=2, window_size=2, node_window_size=2,
                           power_cap=SERVE_CAP_W,
                           cluster_power_budget=SERVE_BUDGET_W,
                           objective=objective, tail_quantile=0.95,
                           tail_window_s=10.0, tail_target_s=2.0))


@register
def serve_poisson() -> Scenario:
    return Scenario(
        name="serve/poisson",
        description="steady Poisson traffic on a 4-node fleet with one "
                    "air-cooled node: unmanaged baseline showing the "
                    "per-node TTFT-tail spread a thermal straggler causes",
        workload=_serve_wl(), sim=_sim(), node=NodeSpec(caps_w=SERVE_CAP_W),
        fleet=_serve_fleet(),
        serve=ServeSpec(process="poisson", rate_rps=4.0, horizon_s=45.0),
        telemetry=TelemetrySpec(), iterations=300, seed=5)


@register
def serve_diurnal() -> Scenario:
    return Scenario(
        name="serve/diurnal",
        description="diurnal traffic (sinusoid-modulated Poisson) sized "
                    "from the users_m knob: peaks overload the hot node, "
                    "troughs let it drain — tail inflation concentrates "
                    "at peak hours",
        workload=_serve_wl(), sim=_sim(), node=NodeSpec(caps_w=SERVE_CAP_W),
        fleet=_serve_fleet(),
        serve=ServeSpec(process="diurnal", users_m=0.045,
                        user_req_per_day=8.0, diurnal_amp=0.6,
                        diurnal_period_s=30.0, horizon_s=60.0),
        telemetry=TelemetrySpec(), iterations=450, seed=5)


@register
def serve_straggler_slo() -> Scenario:
    return Scenario(
        name="serve/straggler-slo",
        description="the SLO benchmark: overloaded hot node inflates p99 "
                    "TTFT; the fleet manager's tail-latency objective "
                    "overdrives it past speed parity until the backlog "
                    "drains (compare objective=throughput on the same "
                    "seed: it stops at parity and the backlog persists)",
        workload=_serve_wl(), sim=_sim(), node=NodeSpec(caps_w=SERVE_CAP_W),
        fleet=_serve_fleet(), manager=_serve_mgr("tail-latency"),
        serve=ServeSpec(process="poisson", rate_rps=4.8, horizon_s=60.0),
        telemetry=TelemetrySpec(), observability=ObservabilitySpec(),
        iterations=450, seed=5)


# --------------------------------------------------------------------------- #
# telemetry/* — recording / sensor-fidelity scenarios
# --------------------------------------------------------------------------- #
@register
def telemetry_rocm_smi_like() -> Scenario:
    return Scenario(
        name="telemetry/rocm-smi-like",
        description="record one hot node through the rocm-smi-style "
                    "sensor preset and report detection quality",
        workload=_wl8(), sim=_sim(), node=NodeSpec(),
        telemetry=TelemetrySpec(sensor=ROCM_SMI_LIKE, keep_truth=True),
        iterations=60, seed=1)


@register
def telemetry_replay() -> Scenario:
    return Scenario(
        name="telemetry/replay",
        description="managed 2-node cluster recorded losslessly — the "
                    "record/replay bit-for-bit reference (CI smoke + "
                    "telemetry_bench share it)",
        workload=_wl8(), sim=_sim(), node=NodeSpec(caps_w=CAP_W),
        fleet=ClusterConfig(n_nodes=2, straggler_boost=1.28),
        manager=ManagerSpec(scope="fleet", tune_after=10,
                            config=FleetManagerConfig(
                                use_case="gpu-realloc", sampling_period=2,
                                warmup=2, window_size=2, node_window_size=2,
                                power_cap=CAP_W,
                                cluster_power_budget=2 * 8 * CAP_W)),
        telemetry=TelemetrySpec(), iterations=40, seed=5)
