"""Study drivers + human-readable reports over scenario results.

The pre-API example scripts each carried ~50 lines of study-specific
composition and printing; that logic lives here now, shared by the thin
`examples/*.py` wrappers and the `python -m repro run` human output, so a
study reads identically from either entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.api.registry import CAP_W, get_scenario
from repro.api.runner import ScenarioResult, run_scenario
from repro.api.spec import Scenario
from repro.core.detect import (classify_overlap, lead_value_detect,
                               overlap_duration_correlation, straggler_index)
from repro.telemetry.replay import detection_report
from repro.telemetry.sensors import SensorConfig, SensorModel
from repro.telemetry.trace_io import TelemetryTrace

__all__ = ["characterization_report", "use_case_table", "recovery_study",
           "sensor_fidelity_report", "metrics_table", "format_result"]


def metrics_table(metrics: Dict[str, float]) -> str:
    width = max((len(k) for k in metrics), default=0)
    lines = []
    for k in sorted(metrics):
        v = metrics[k]
        val = f"{v:.6g}" if isinstance(v, float) else str(v)
        lines.append(f"  {k:<{width}s}  {val}")
    return "\n".join(lines)


def format_result(res: ScenarioResult) -> str:
    sc = res.scenario
    scope = ("fleet" if sc.fleet is not None else "node")
    head = (f"== {sc.name or 'scenario'} ({scope}, "
            f"{res.iterations} iterations, seed {sc.seed}) ==")
    return head + "\n" + metrics_table(res.metrics)


# --------------------------------------------------------------------------- #
# paper/characterization (thermal_study)
# --------------------------------------------------------------------------- #
def characterization_report(res: ScenarioResult) -> str:
    """Paper Figs 3-7 on a settled node: straggler / overlap / lead-wave
    structure (the old examples/thermal_study.py output)."""
    node, tr = res.node, res.last_trace
    st = node.state
    s = straggler_index(tr.comp_start)
    out = [f"== {res.scenario.workload.arch}: node settled after "
           f"{res.iterations} iterations ==",
           f"temps  (°C):  {np.round(st.temp, 1)}  "
           f"ratio {st.temp.max() / st.temp.min():.3f}  (paper: 1.155x)",
           f"freqs  (GHz): {np.round(st.freq, 3)}  "
           f"ratio {st.freq.max() / st.freq.min():.3f}  (paper: 1.062x)",
           f"straggler: GPU{s} (hottest & slowest)"]

    w = tr.comp_dur
    ov = (tr.overlap_ratio * w).sum(1) / w.sum(1)
    out += [f"\nweighted overlap ratio per GPU: {np.round(ov, 3)}",
            f"straggler has the lowest overlap: "
            f"{ov[s] == ov.min()} (paper Insight 1)"]

    const = classify_overlap(tr.overlap_ratio)
    dv = tr.comp_dur[:, ~const]
    dc = tr.comp_dur[:, const]
    out.append(f"\nconstant-overlap kernels: {const.sum()}/{len(const)}")
    if (~const).sum():
        out.append(f"straggler vs leaders on VARYING-overlap kernels: "
                   f"{dv[s].mean() / np.delete(dv, s, 0).mean():.2f}x "
                   f"duration (<1: straggler faster — paper Insight 3)")
    out.append(f"straggler vs leaders on CONSTANT-overlap kernels: "
               f"{dc[s].mean() / np.delete(dc, s, 0).mean():.2f}x duration "
               f"(>1: straggler slower)")

    idx = [i for i, n in enumerate(tr.comp_names) if n == "f_qkv_ip"]
    if idx:
        p, c = overlap_duration_correlation(tr.overlap_ratio[:, idx],
                                            tr.comp_dur[:, idx])
        out.append(f"\noverlap-vs-duration correlation (f_qkv_ip): "
                   f"pearson={p:.3f} cosine={c:.3f} (paper Fig 4: strong)")

    lead = lead_value_detect(tr.comp_start)
    out += [f"\naggregate lead values (ms): {np.round(lead * 1e3, 1)}",
            "straggler lead ~ 0 (everyone waits for it) — paper Fig 7"]
    return "\n".join(out)


# --------------------------------------------------------------------------- #
# paper/table1-* (power_management)
# --------------------------------------------------------------------------- #
def use_case_table(results: Dict[str, ScenarioResult]) -> str:
    """Table-I comparison over the three managed node scenarios."""
    out = [f"{'use case':14s} {'throughput':>11s} {'node power':>11s}  "
           f"(paper: Red ~0%/-4%, Realloc +3%/0%, Slosh +4%/+3%)"]
    for uc, res in results.items():
        caps = np.round(res.node.history[-1]["cap"], 0).astype(int)
        out.append(f"{uc:14s} {res.metrics['tput_ratio'] - 1:+10.2%} "
                   f"{res.metrics['power_ratio'] - 1:+10.2%}   caps={caps}")
    return "\n".join(out)


# --------------------------------------------------------------------------- #
# cluster/* recovery comparison (cluster_study)
# --------------------------------------------------------------------------- #
def recovery_study(topology: str = "dp", n_nodes: int = 4,
                   iterations: int = 60) -> Tuple[str, dict]:
    """Healthy vs one-hot-GPU vs managed fleet under one provisioned
    budget (the old examples/cluster_study.py).  Returns (report, data).

    The managed leg *is* the registered ``cluster/<topology>`` scenario
    (resized to ``n_nodes``); the healthy/straggler legs are the same
    scenario with the manager stripped and the boost varied.
    """
    base = get_scenario(f"cluster/{topology}")
    base = base.replace(
        fleet=dataclasses.replace(base.fleet, n_nodes=n_nodes),
        manager=dataclasses.replace(
            base.manager, config=dataclasses.replace(
                base.manager.config,
                cluster_power_budget=n_nodes * 8 * CAP_W)))
    healthy = base.replace(
        manager=None, iterations=iterations,
        fleet=dataclasses.replace(base.fleet, straggler_boost=1.0))
    strag = base.replace(manager=None, iterations=iterations)
    managed = base.replace(iterations=2 * iterations)
    managed.manager.tune_after = iterations // 3

    r_h, r_s = run_scenario(healthy), run_scenario(strag)
    r_m = run_scenario(managed)
    tp_h, tp_s = r_h.metrics["fleet_tput"], r_s.metrics["fleet_tput"]
    tp_m = r_m.metrics["fleet_tput"]
    rec = (tp_m - tp_s) / max(tp_h - tp_s, 1e-12)
    budget = n_nodes * 8 * CAP_W

    wait_kind = {"dp": "every node waits at the barrier",
                 "pp": "downstream stages ride the bubble",
                 "tp": "every layer's collective drags"}[topology]
    out = [f"== {n_nodes}-node {topology} fleet, one hot GPU on node 0 ==",
           f"exposed inter-node comm: "
           f"{r_s.cluster.history[-1]['comm_time'] * 1e3:.1f} ms per "
           f"iteration",
           f"healthy fleet:   {tp_h:.4f} iter/s",
           f"with straggler:  {tp_s:.4f} iter/s "
           f"({(tp_s - tp_h) / tp_h:+.2%} — {wait_kind})",
           f"slowest node (last 20 iters): "
           f"{int(np.bincount([h['slowest_node'] for h in r_s.cluster.history[-20:]]).argmax())}",
           f"\n== FleetPowerManager (cluster budget {budget:.0f} W) ==",
           f"managed fleet:   {tp_m:.4f} iter/s  "
           f"(recovers {rec:.0%} of the straggler gap)",
           f"node budgets (W): "
           f"{np.round(r_m.manager.node_budgets).astype(int)}  "
           f"<- the topology's lead signal steers budget to the straggler",
           f"node 0 caps (W):  "
           f"{np.round(r_m.cluster.get_node_caps(0)).astype(int)}",
           f"fleet power:      {r_m.metrics['fleet_power_w']:.0f} W "
           f"(budget {budget:.0f} W)"]
    data = {"healthy": r_h, "straggler": r_s, "managed": r_m,
            "recovered": rec}
    return "\n".join(out), data


# --------------------------------------------------------------------------- #
# telemetry sensor-fidelity sweep (telemetry_study)
# --------------------------------------------------------------------------- #
def sensor_fidelity_report(trace: TelemetryTrace, node: int,
                           noises: Iterable[float] = (0.0, 0.002, 0.01,
                                                      0.05, 0.2),
                           periods: Iterable[int] = (1, 10, 25),
                           n_seeds: int = 5) -> str:
    """Degrade one recorded trace through a noise × period sensor grid and
    tabulate straggler-detection accuracy / lead error (the old
    examples/telemetry_study.py sweep)."""
    from repro.telemetry.replay import degrade
    noises, periods = list(noises), list(periods)
    out = ["  noise_s   "
           + "  ".join(f"period={p:<3d} " for p in periods)
           + "  (straggler-detection accuracy / lead error)"]
    for sigma in noises:
        cells = []
        for period in periods:
            accs, errs = [], []
            for s in range(n_seeds):
                d = degrade(trace, SensorModel(SensorConfig(
                    noise_time_s=sigma, sample_period=period,
                    quant_time_s=1e-5, seed=s)))
                rep = detection_report(d, node=node)
                accs.append(rep.accuracy)
                errs.append(rep.lead_rel_error)
            cells.append(f"{np.mean(accs):.2f}/{np.mean(errs):6.2f}")
        out.append(f"  {sigma:<8g}  " + "  ".join(cells))
    slow = [int(np.argmin(fs.lead)) for fs in trace.fleet[-20:]]
    if slow:
        named = int(np.bincount(slow).argmax())
        strag = int(trace.meta.get("straggler_node", 0))
        out.append(f"  fleet lead signal names node {named} "
                   f"({'correct' if named == strag else 'WRONG'})")
    return "\n".join(out)
