"""End-to-end training driver.

CPU-scale example (the real thing, shrunk):
  python -m repro.launch.train --arch llama3.1-8b --reduced --steps 200 \
      --use-case gpu-red

Runs the full stack: synthetic data pipeline -> pjit'd FSDP train step ->
AdamW -> atomic checkpoints -> watchdog -> Lit Silicon power-management
co-sim hook (detect+mitigate per paper §V).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.1-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--use-case", default="",
                    choices=["", "gpu-red", "gpu-realloc", "cpu-slosh"],
                    help="enable the Lit Silicon power-management hook")
    ap.add_argument("--preset", default="mi300x", choices=["mi300x", "v5e"])
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    from repro.configs import (ParallelConfig, TrainConfig, get_config,
                               get_reduced_config)
    from repro.core.manager import ManagerConfig
    from repro.train.data import DataConfig
    from repro.train.train_loop import LitSiliconHook, Trainer, TrainerConfig

    model_cfg = (get_reduced_config(args.arch) if args.reduced
                 else get_config(args.arch))
    tc = TrainerConfig(
        model=model_cfg,
        train=TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps,
                          checkpoint_every=args.checkpoint_every,
                          checkpoint_dir=args.checkpoint_dir),
        parallel=ParallelConfig(),
        data=DataConfig(global_batch=args.global_batch,
                        seq_len=args.seq_len),
    )
    hooks = []
    if args.use_case:
        hooks.append(LitSiliconHook(
            get_config(args.arch),       # sim runs the FULL arch workload
            ManagerConfig(use_case=args.use_case, sampling_period=2,
                          warmup=3, window_size=2),
            preset=args.preset))
    trainer = Trainer(tc, hooks=hooks)
    log = trainer.run(args.steps)
    print(f"step {log[-1]['step']}: loss {log[-1]['loss']:.4f} "
          f"(start {log[0]['loss']:.4f})")
    if args.use_case:
        h = hooks[0]
        caps = h.backend.get_power_caps()
        print(f"lit-silicon[{args.use_case}]: converged caps = "
              f"{np.round(caps, 0).tolist()}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(log, f, indent=1, sort_keys=True, allow_nan=False)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
