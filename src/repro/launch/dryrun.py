import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract memory / cost / collective analyses.

This is the proof that the distribution config is coherent without real
hardware: the 16x16 single-pod pass sizes the roofline table, the (2,16,16)
multi-pod pass proves the 'pod' axis shards.  Results are cached as JSON
under results/dryrun/ (one file per cell) for the roofline reports.

Usage:
  python -m repro.launch.dryrun --arch llama3.1-8b --shape train_4k
  python -m repro.launch.dryrun --arch grok-1-314b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 4]
Variants (perf hillclimbing): --remat dots --no-seq-parallel --scan-off
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             remat: str = "nothing", sequence_parallel: bool = True,
             scan_layers: bool = True, fsdp_over_pod=None,
             grad_compression: str = "none", variant: str = "",
             attention: str = "chunked", moe_dispatch: str = "scatter",
             verbose: bool = True,
             clock=time.perf_counter) -> dict:
    # clock is injectable so the lower/compile latency fields stay
    # testable without real elapsed time (RPL002)
    from repro.models.attention import set_attention_impl
    from repro.parallel.moe_shard_map import set_moe_dispatch
    set_attention_impl(attention)
    set_moe_dispatch(moe_dispatch)
    from repro.configs import (ParallelConfig, TrainConfig, get_config,
                               get_shape, shape_applicable)
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model, input_specs
    from repro.models.common import abstract_params
    from repro.parallel.fsdp import (abstract_train_state, build_decode_step,
                                     build_prefill_step, build_train_step)
    from repro.parallel.sharding import ShardingRules
    from repro.roofline.analyze import analyze, model_flops_for

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": True,
                "reason": "long_500k needs sub-quadratic attention"}

    # shape-driven config adjustments (documented in DESIGN.md):
    #  * decode caches sized to the shape's seq_len;
    #  * hymba long-context serving uses SWA everywhere (global layers
    #    windowed) so the ring-buffer cache stays homogeneous under scan.
    eff = cfg
    if shape.kind != "train" and cfg.max_seq_len < shape.seq_len:
        eff = eff.replace(max_seq_len=shape.seq_len)
    if shape.name == "long_500k" and cfg.global_attn_layers:
        eff = eff.replace(global_attn_layers=())

    parallel = ParallelConfig(multi_pod=multi_pod, remat_policy=remat,
                              sequence_parallel=sequence_parallel,
                              scan_layers=scan_layers,
                              fsdp_over_pod=fsdp_over_pod,
                              grad_compression=grad_compression)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(jnp.prod(jnp.asarray(list(mesh.shape.values()))))
    pod_size = 256

    model = build_model(eff, max_cache_len=shape.seq_len, remat=remat,
                        scan_layers=scan_layers)
    rules = ShardingRules(mesh, eff, parallel)
    specs = input_specs(eff, shape)
    t0 = clock()

    with mesh:
        if shape.kind == "train":
            step, st_shard = build_train_step(model, TrainConfig(), rules,
                                              parallel)
            state = abstract_train_state(model, parallel)
            lowered = step.lower(state, specs)
        elif shape.kind == "prefill":
            step, _ = build_prefill_step(model, rules)
            params = abstract_params(model.param_specs(),
                                     jnp.dtype(eff.serve_dtype))
            lowered = step.lower(params, specs)
        else:                                   # decode
            params = abstract_params(model.param_specs(),
                                     jnp.dtype(eff.serve_dtype))
            cache = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch))
            step, _, _ = build_decode_step(model, rules, cache)
            lowered = step.lower(params, specs["tokens"], cache)
        t_lower = clock() - t0
        compiled = lowered.compile()
        t_compile = clock() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    rl = analyze(dict(cost), hlo, chips, pod_size,
                 model_flops_for(eff, shape))

    mem_rec = {k: int(getattr(mem, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
               if hasattr(mem, k)}
    # memory_analysis stats are already per-device (partitioned module)
    per_dev = (mem_rec.get("argument_size_in_bytes", 0)
               + mem_rec.get("temp_size_in_bytes", 0))
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant,
        "mesh": dict(mesh.shape), "chips": chips,
        "kind": shape.kind,
        "sharding": rules.describe(),
        "memory": mem_rec,
        "bytes_per_device": per_dev,
        "fits_hbm": per_dev < 16e9,
        "cost": {k: float(v) for k, v in dict(cost).items()
                 if isinstance(v, (int, float))},
        "roofline": rl.to_dict(),
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
    }
    if verbose:
        print(f"== {arch} x {shape_name} mesh={dict(mesh.shape)} "
              f"variant={variant or 'baseline'}")
        print(f"   memory_analysis: {mem}")
        print(f"   bytes/device: {per_dev/1e9:.2f} GB  fits<16GB: "
              f"{rec['fits_hbm']}")
        print(f"   cost_analysis: flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"   roofline: T_comp={rl.t_comp*1e3:.2f}ms "
              f"T_mem={rl.t_mem*1e3:.2f}ms T_coll={rl.t_coll*1e3:.2f}ms "
              f"dominant={rl.dominant} frac={rl.roofline_fraction:.3f}")
    return rec


def cell_path(outdir, arch, shape, multi_pod, variant=""):
    tag = "mp" if multi_pod else "sp"
    v = f"-{variant}" if variant else ""
    return os.path.join(outdir, f"{arch}-{shape}-{tag}{v}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-paper-archs", action="store_true")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--scan-off", action="store_true")
    ap.add_argument("--fsdp-over-pod", type=int, default=-1,
                    help="-1 auto, 0 off, 1 on")
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--variant", default="")
    ap.add_argument("--attention", default="chunked",
                    choices=["chunked", "xla", "stub"])
    ap.add_argument("--moe-dispatch", default="scatter",
                    choices=["scatter", "shard_map"])
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    os.makedirs(args.outdir, exist_ok=True)

    from repro.configs import iter_cells
    cells = []
    if args.all:
        for arch, shape, ok in iter_cells(args.include_paper_archs):
            cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    fop = None if args.fsdp_over_pod < 0 else bool(args.fsdp_over_pod)
    failures = 0
    for arch, shape in cells:
        path = cell_path(args.outdir, arch, shape, args.multi_pod,
                         args.variant)
        if os.path.exists(path) and not args.force:
            print(f"cached: {path}")
            continue
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           remat=args.remat,
                           sequence_parallel=not args.no_seq_parallel,
                           scan_layers=not args.scan_off,
                           fsdp_over_pod=fop,
                           grad_compression=args.grad_compression,
                           attention=args.attention,
                           moe_dispatch=args.moe_dispatch,
                           variant=args.variant)
        except Exception as e:
            failures += 1
            print(f"FAILED {arch} x {shape}: {e}")
            traceback.print_exc()
            continue
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True, allow_nan=False)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
