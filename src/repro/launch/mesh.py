"""Production mesh construction (a FUNCTION, not a module-level constant, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU smoke runs)."""
    import numpy as np
    devs = np.array(jax.devices())
    assert devs.size % model_parallel == 0
    return jax.sharding.Mesh(
        devs.reshape(-1, model_parallel), ("data", "model"))
