"""Launchers: mesh construction, train/serve entry points, multi-pod
dry-run planner.  A regular package (not an implicit namespace package) so
src-layout discovery and editable installs always ship it."""
