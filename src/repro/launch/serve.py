"""Batched serving driver (reduced-scale on CPU):

  python -m repro.launch.serve --arch qwen3-4b --reduced --batch 4 \
      --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_config, get_reduced_config
    from repro.models import batch_extras, build_model
    from repro.models.common import init_params
    from repro.serve.decode import ServeConfig, ServingLoop

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = build_model(cfg, max_cache_len=args.prompt_len + args.new_tokens)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    loop = ServingLoop(model, params, args.batch, args.prompt_len,
                       ServeConfig(max_new_tokens=args.new_tokens,
                                   temperature=args.temperature))
    # modality stubs ride along via the prefill batch
    extras = batch_extras(cfg, args.batch)
    if extras:
        import jax.numpy as jnp
        batch = {"tokens": jnp.asarray(prompts), **extras}
        from repro.serve.decode import generate
        out = generate(model, params, batch, loop.cfg)
    else:
        out = loop.serve(prompts)
    print(f"arch={cfg.name} generated {out.shape} tokens:")
    print(out[:, :12])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
