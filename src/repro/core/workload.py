"""Iteration workload builder: the FSDP kernel schedule of Figure 2.

Produces the per-iteration kernel lists the C3 simulator executes:
  * compute stream — ordered compute kernels (GFLOP or GB of work), each
    optionally gated on a communication kernel's completion;
  * comm stream — ordered collectives (bytes), each optionally gated on a
    producer compute kernel.
Forward: AG(l) gates layer-l compute; AG(l+1) streams behind it (overlap
window = qkv_ip .. attn_op, emergent).  Backward: RS(l) waits on b_mlp_dp(l)
then AG(l-1) queues immediately after — exactly Fig 2.  MoE mode adds
non-overlapped all-to-alls that gate the next compute kernel (paper §VII-C:
per-layer sync, small leads + spikes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.configs.base import ModelConfig


@dataclass
class CompKernel:
    name: str
    gflop: float = 0.0                 # compute-bound work (scales with f)
    gbyte: float = 0.0                 # memory-bound work (f-independent)
    wait_comm: Optional[int] = None    # comm index that must finish first


@dataclass
class CommKernel:
    name: str
    bytes: float                       # payload per device
    producer: Optional[int] = None     # compute index that must finish first
    blocking: bool = False             # MoE a2a: consumer compute waits on it


@dataclass
class Workload:
    comp: List[CompKernel]
    comm: List[CommKernel]
    name: str = ""
    act_bytes: float = 0.0             # per-boundary activation payload —
    #                                    the PP point-to-point / TP per-sync
    #                                    link-model default (topology.py)
    n_layers: int = 0                  # layers represented; TP defaults to
    #                                    2 sync points per layer (AG + RS)

    @property
    def total_gflop(self) -> float:
        return sum(k.gflop for k in self.comp)

    @property
    def total_bytes(self) -> float:
        return sum(k.bytes for k in self.comm)


def fsdp_llm_iteration(cfg: ModelConfig, *, batch: int = 2,
                       seq: int = 4096, n_shards: int = 8,
                       dtype_bytes: int = 2) -> Workload:
    """One training iteration of ``cfg`` under FSDP across ``n_shards``."""
    T = batch * seq
    d, dff = cfg.d_model, cfg.d_ff
    qd, kvd = cfg.q_dim, cfg.kv_dim
    L = cfg.n_layers
    moe = cfg.moe is not None
    layer_bytes = cfg.layer_params(max(cfg.moe.first_k_dense if moe else 0,
                                       0)) * dtype_bytes
    ag_bytes = layer_bytes * (n_shards - 1) / n_shards
    rs_bytes = ag_bytes                       # grads, same payload

    comp: List[CompKernel] = []
    comm: List[CommKernel] = []

    def gemm_flops_fwd():
        """Per-layer forward GEMM+attention GFLOPs (split per Fig 2 names)."""
        eff_s = min(seq, cfg.window) if cfg.window else seq
        fa = 2 * 2 * T * eff_s * d / 2 / 1e9          # causal flash attention
        out = {
            "attn_n": 0.0,                            # vec kernel: bytes only
            "qkv_ip": 2 * T * d * (qd + 2 * kvd) / 1e9,
            "attn_fa": fa,
            "attn_op": 2 * T * qd * d / 1e9,
            "mlp_n": 0.0,
            "mlp_gp": 2 * T * d * dff / 1e9,
            "mlp_up": 2 * T * d * dff / 1e9 if cfg.gated_mlp else 0.0,
            "mlp_dp": 2 * T * dff * d / 1e9,
        }
        if moe:
            m = cfg.moe
            act = (m.top_k + m.n_shared)
            e_flops = 2 * T * d * m.d_expert * act * (3 if cfg.gated_mlp
                                                      else 2) / 1e9
            out["mlp_gp"] = e_flops * 0.4
            out["mlp_up"] = e_flops * 0.3
            out["mlp_dp"] = e_flops * 0.3
        return out

    vec_gb = T * d * dtype_bytes * 4 / 1e9           # norm read+write x2

    fwd = gemm_flops_fwd()
    # ---------------- forward ------------------------------------------------
    for l in range(L):
        ag = len(comm)
        comm.append(CommKernel(f"ag_f{l}", ag_bytes))
        first = True
        for kname, gf in fwd.items():
            wait = ag if first else None
            first = False
            comp.append(CompKernel(f"f_{kname}", gflop=gf,
                                   gbyte=vec_gb if kname.endswith("_n")
                                   else 0.0, wait_comm=wait))
        if moe:
            # dispatch a2a after router (gates expert gemms), combine after
            disp = len(comm)
            a2a_bytes = T * d * dtype_bytes * (n_shards - 1) / n_shards
            # router ran inside mlp_n position; dispatch gates mlp_gp
            comm.append(CommKernel(f"a2a_fd{l}", a2a_bytes,
                                   producer=len(comp) - 4, blocking=True))
            comp[-3].wait_comm = disp            # expert gemm waits dispatch
            comb = len(comm)
            comm.append(CommKernel(f"a2a_fc{l}", a2a_bytes,
                                   producer=len(comp) - 1, blocking=True))
            comp.append(CompKernel(f"f_moe_comb{l}", gbyte=vec_gb / 2,
                                   wait_comm=comb))

    # ---------------- backward (reverse layer order) -------------------------
    for l in reversed(range(L)):
        ag = len(comm)
        comm.append(CommKernel(f"ag_b{l}", ag_bytes))
        first = True
        # backward ~2x forward flops, dp/up first then attention (Fig 2)
        order = list(fwd.items())[::-1]
        for kname, gf in order:
            wait = ag if first else None
            first = False
            comp.append(CompKernel(f"b_{kname}", gflop=2 * gf,
                                   gbyte=2 * vec_gb if kname.endswith("_n")
                                   else 0.0, wait_comm=wait))
        if moe:
            a2a_bytes = T * d * dtype_bytes * (n_shards - 1) / n_shards
            disp = len(comm)
            comm.append(CommKernel(f"a2a_bd{l}", a2a_bytes,
                                   producer=len(comp) - 8, blocking=True))
        rs = len(comm)
        comm.append(CommKernel(f"rs_b{l}", rs_bytes,
                               producer=len(comp) - 1))

    # optimizer step after the last reduce-scatter
    comp.append(CompKernel("opt_step", gbyte=3 * layer_bytes * L / n_shards
                           / 1e9, wait_comm=len(comm) - 1))
    return Workload(comp, comm, name=f"{cfg.name}-b{batch}s{seq // 1024}k",
                    act_bytes=float(T * d * dtype_bytes), n_layers=L)
