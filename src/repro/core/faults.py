"""Typed fault injection for the cluster simulator.

The paper's power managers *tune around* thermal stragglers; a production
fleet also faces stragglers no cap schedule can fix.  ``FaultModel`` is the
ChurnModel-style injector for those: a seeded schedule of typed
``FaultEvent``s that ``ClusterSim`` consults every step and applies to the
layer each fault physically lives in:

  * ``thermal_runaway`` — the device's thermal resistance *grows* from the
    onset (``magnitude`` = fractional r_th growth per simulated second), so
    temperature keeps climbing past any cap's reach and DVFS pins the
    device at f_min: the unrecoverable cousin of a ChurnEvent's one-shot
    degradation.  Applied through ``ThermalModel.rth_fault``.
  * ``perf_degrade`` — the device computes at ``magnitude`` x its clocked
    rate (ECC storms, row-remap retirements) while drawing normal power.
    Applied as a compute-rate scale in ``NodeSim.run_only``.
  * ``kernel_hang`` — the node's local step time is multiplied by
    ``magnitude`` while active (hung collective, network blip).  Applied to
    ``t_local`` before the topology couples the fleet.
  * ``sensor_death`` — the node's observed telemetry goes NaN/stale while
    active; the simulator itself is unaffected (only observers are blind).
  * ``device_loss`` — the device stops doing useful work (rate pinned to
    ``LOST_DEVICE_RATE``); only draining the node helps.

Events with a finite ``duration`` are *transient* (recoverable: ride them
out); ``thermal_runaway`` / ``device_loss`` / ``sensor_death`` — and any
fault left active forever — are *unrecoverable*: the EscalationPolicy
(escalate.py) is expected to drain the node, and draining a node with no
active unrecoverable fault counts as a false drain.

Node indices in events are **global** (position in the original fleet):
a rebuilt post-drain ClusterSim passes its surviving-node id map so
faults keep following the physical node they were scheduled on.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["FAULT_KINDS", "UNRECOVERABLE_KINDS", "FaultEvent", "FaultModel",
           "random_faults", "LOST_DEVICE_RATE"]

FAULT_KINDS = ("thermal_runaway", "perf_degrade", "kernel_hang",
               "sensor_death", "device_loss")

# kinds that never heal on their own, whatever their duration says
UNRECOVERABLE_KINDS = ("thermal_runaway", "device_loss", "sensor_death")

# compute-rate multiplier of a lost device: not 0 (the coupled step would
# never finish) but slow enough that the node is unambiguously dead weight
LOST_DEVICE_RATE = 0.05


@dataclass
class FaultEvent:
    """One injected fault: ``kind`` on ``node``/``device`` from simulated
    second ``t`` for ``duration`` seconds (default: forever)."""

    t: float
    kind: str
    node: int = 0
    device: int = 0                    # ignored by node-scoped kinds
    magnitude: float = 1.0             # kind-specific (see module docstring)
    duration: float = math.inf

    def active(self, t: float) -> bool:
        return self.t <= t < self.t + self.duration

    @property
    def unrecoverable(self) -> bool:
        return (self.kind in UNRECOVERABLE_KINDS
                or math.isinf(self.duration))

    def validate(self) -> "FaultEvent":
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {FAULT_KINDS})")
        if self.duration <= 0:
            raise ValueError(f"fault duration must be > 0, got "
                             f"{self.duration}")
        return self


@dataclass
class FaultModel:
    """A seeded schedule of fault events (ChurnModel-style: pure data, all
    queries are functions of simulated time — no hidden state, so live runs
    and offline replays agree)."""

    events: List[FaultEvent] = field(default_factory=list)

    def validate(self) -> "FaultModel":
        for ev in self.events:
            ev.validate()
        return self

    # ------------------------------------------------------ per-step queries
    def _active(self, t: float, node: int, kind: str):
        return (ev for ev in self.events
                if ev.node == node and ev.kind == kind and ev.active(t))

    def rth_multipliers(self, t: float, node: int,
                        n_devices: int) -> np.ndarray:
        """thermal_runaway: r_th multiplier per device, growing linearly
        with time since onset (composes multiplicatively, like churn)."""
        m = np.ones(n_devices)
        for ev in self._active(t, node, "thermal_runaway"):
            m[ev.device] *= 1.0 + ev.magnitude * (t - ev.t)
        return m

    def perf_scale(self, t: float, node: int,
                   n_devices: int) -> Optional[np.ndarray]:
        """perf_degrade + device_loss: per-device compute-rate multiplier;
        None when nothing is active (keeps the hot path allocation-free)."""
        m = None
        for ev in self._active(t, node, "perf_degrade"):
            if m is None:
                m = np.ones(n_devices)
            m[ev.device] *= ev.magnitude
        for ev in self._active(t, node, "device_loss"):
            if m is None:
                m = np.ones(n_devices)
            m[ev.device] = min(m[ev.device], LOST_DEVICE_RATE)
        return m

    def hang_multiplier(self, t: float, node: int) -> float:
        """kernel_hang: node-level step-time multiplier (composes)."""
        m = 1.0
        for ev in self._active(t, node, "kernel_hang"):
            m *= max(ev.magnitude, 1.0)
        return m

    def sensor_dead(self, t: float, node: int) -> bool:
        return any(True for _ in self._active(t, node, "sensor_death"))

    # --------------------------------------------------------- introspection
    def events_for(self, node: int) -> List[FaultEvent]:
        return [ev for ev in self.events if ev.node == node]

    def onset_of_unrecoverable(self, node: int,
                               before: float = math.inf) -> Optional[float]:
        """Earliest onset of an unrecoverable fault on ``node`` that has
        started by simulated time ``before`` (None: the node is healthy —
        draining it would be a false drain)."""
        times = [ev.t for ev in self.events
                 if ev.node == node and ev.unrecoverable and ev.t <= before]
        return min(times) if times else None

    def activated_between(self, t0: float, t1: float,
                          nodes: Optional[Sequence[int]] = None
                          ) -> List[FaultEvent]:
        """Events whose onset falls in (t0, t1] — what a step that advanced
        the clock from t0 to t1 should report to the trace."""
        keep = None if nodes is None else set(nodes)
        return [ev for ev in self.events
                if t0 < ev.t <= t1 and (keep is None or ev.node in keep)]


def random_faults(seed: int, n_nodes: int, horizon_s: float,
                  rate_per_node_hour: float,
                  n_devices: int = 8,
                  kinds: Sequence[str] = FAULT_KINDS) -> List[FaultEvent]:
    """A seeded Poisson schedule of faults — the fleet-scale hazard model
    ("Not All GPUs Are Created Equal": hard faults arrive independently per
    node).  Magnitudes are drawn per kind in plausible ranges; transient
    kinds get finite durations."""
    rng = np.random.default_rng(seed)
    events: List[FaultEvent] = []
    rate_s = rate_per_node_hour / 3600.0
    for node in range(n_nodes):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / rate_s) if rate_s > 0 else math.inf
            if t >= horizon_s:
                break
            kind = str(rng.choice(list(kinds)))
            device = int(rng.integers(n_devices))
            if kind == "thermal_runaway":
                ev = FaultEvent(t, kind, node, device,
                                magnitude=float(rng.uniform(0.02, 0.10)))
            elif kind == "perf_degrade":
                ev = FaultEvent(t, kind, node, device,
                                magnitude=float(rng.uniform(0.4, 0.8)),
                                duration=float(rng.uniform(5.0, 60.0)))
            elif kind == "kernel_hang":
                ev = FaultEvent(t, kind, node,
                                magnitude=float(rng.uniform(1.5, 4.0)),
                                duration=float(rng.uniform(1.0, 10.0)))
            elif kind == "sensor_death":
                ev = FaultEvent(t, kind, node)
            else:                                           # device_loss
                ev = FaultEvent(t, kind, node, device)
            events.append(ev)
    return sorted(events, key=lambda e: e.t)
