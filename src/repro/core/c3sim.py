"""Two-stream discrete-event node simulator: the Lit Silicon coupling engine.

Per device: a *compute stream* (ordered kernels, rate ∝ frequency for
FLOP-bound work, frequency-independent for HBM-bound work) and a *comm
stream* (ordered collectives).  Collectives are synchronization points: a
device's collective occupies its comm stream from its *local* arrival until
the *global* completion (leaders arrive early and wait — their comm kernels
stretch).  While the comm stream is busy, compute on that device is slowed by
the contention factor κ (paper §II-B: up to 40 %, avg 18.9 % kernel slowdown
under C3).  These two rules alone generate the paper's dynamics:

  ① identical start → ② leads grow on constant-overlap kernels →
  ③ leaders wait at collectives, overlap ↑, contention slows them,
    equilibrium → ④ leaders idle at the iteration barrier.

The simulator emits per-kernel (start, end, overlap) traces — the exact
interface Algorithm 1 consumes, and the same record a TPU profiler hook
would produce on real hardware.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.thermal import DevicePreset, DeviceState, ThermalModel
from repro.core.workload import Workload


@dataclass
class SimConfig:
    kappa_comp: float = 0.45        # compute slowdown factor while comm busy
    kappa_mem: float = 0.75         # memory-bound slowdown while comm busy
    gemm_eff: float = 0.45          # fraction of peak for GEMM kernels
    comm_gbps: float = 62.0         # per-device effective collective GB/s
    comm_spike_p: float = 0.0       # probability of a latency spike per comm
    comm_spike_mult: float = 8.0    # spike multiplier (paper Fig 16 MoE)
    noise: float = 0.008            # per-kernel duration noise (lognormal σ)
    seed: int = 0


@dataclass
class IterationTrace:
    """Per-iteration telemetry: the Algorithm-1 input format."""

    comp_names: List[str]
    comm_names: List[str]
    comp_start: np.ndarray          # (G, Kc) s
    comp_end: np.ndarray            # (G, Kc)
    comp_overlap: np.ndarray        # (G, Kc) seconds overlapped with comm
    comm_start: np.ndarray          # (G, Km) local starts
    comm_end: np.ndarray            # (Km,) global ends
    t_iter: float
    util: np.ndarray                # (G,) compute busy fraction

    @property
    def comp_dur(self) -> np.ndarray:
        return self.comp_end - self.comp_start

    @property
    def overlap_ratio(self) -> np.ndarray:
        return self.comp_overlap / np.maximum(self.comp_dur, 1e-12)

    @property
    def comm_dur(self) -> np.ndarray:
        return self.comm_end[None, :] - self.comm_start


class C3Sim:
    """Event-driven execution of one Workload iteration on G devices."""

    def __init__(self, workload: Workload, preset: DevicePreset,
                 sim_cfg: SimConfig, n_devices: int):
        self.wl = workload
        self.preset = preset
        self.cfg = sim_cfg
        self.G = n_devices
        self.rng = np.random.default_rng(sim_cfg.seed + 104729)
        # comm waiters: comp index -> list of comm indices it produces
        self.producers: Dict[int, List[int]] = {}
        for j, ck in enumerate(workload.comm):
            if ck.producer is not None:
                self.producers.setdefault(ck.producer, []).append(j)
        # comp waiters: comm index -> list of comp indices gated on it
        self.comm_gates: Dict[int, List[int]] = {}
        for i, k in enumerate(workload.comp):
            if k.wait_comm is not None:
                self.comm_gates.setdefault(k.wait_comm, []).append(i)

    # ------------------------------------------------------------------ run
    def run_iteration(self, freq: np.ndarray) -> IterationTrace:
        wl, G, cfg, p = self.wl, self.G, self.cfg, self.preset
        Kc, Km = len(wl.comp), len(wl.comm)
        comp_rate_f = p.peak_gflops * cfg.gemm_eff * (freq / p.f_max)  # GF/s
        mem_rate = p.hbm_gbps                                          # GB/s

        noise_c = np.exp(self.rng.normal(0, cfg.noise, (G, Kc)))
        dur_comm = np.empty(Km)
        for j, ck in enumerate(wl.comm):
            d = ck.bytes / (cfg.comm_gbps * 1e9)
            if cfg.comm_spike_p and self.rng.random() < cfg.comm_spike_p:
                d *= cfg.comm_spike_mult * (1 + self.rng.random())
            dur_comm[j] = d * np.exp(self.rng.normal(0, cfg.noise))

        comp_start = np.full((G, Kc), np.nan)
        comp_end = np.full((G, Kc), np.nan)
        comp_ovl = np.zeros((G, Kc))
        comm_lstart = np.full((G, Km), np.nan)
        comm_gend = np.full(Km, np.nan)
        busy_time = np.zeros(G)

        # per-device runtime state
        ci = np.zeros(G, int)               # current compute kernel
        gf_rem = np.zeros(G)
        gb_rem = np.zeros(G)
        t_upd = np.zeros(G)
        comm_busy = np.zeros(G, bool)
        blocked = np.zeros(G, bool)         # compute gated on a comm kernel
        cj = 0                              # global comm cursor
        arrived = np.zeros(G, bool)
        comm_active = False                 # current collective in flight
        seqs = np.zeros(G, int)             # event staleness counters

        heap: list = []
        ctr = 0

        def rates(g):
            if comm_busy[g]:
                return (comp_rate_f[g] / (1 + cfg.kappa_comp),
                        mem_rate / (1 + cfg.kappa_mem))
            return comp_rate_f[g], mem_rate

        def load_kernel(g, t):
            """Load compute kernel ci[g]; returns False if stream done."""
            i = ci[g]
            if i >= Kc:
                return False
            k = wl.comp[i]
            if k.wait_comm is not None and not np.isfinite(
                    comm_gend[k.wait_comm]) :
                blocked[g] = True
                return False
            if k.wait_comm is not None and comm_gend[k.wait_comm] > t:
                blocked[g] = True
                return False
            gf_rem[g] = k.gflop * noise_c[g, i]
            gb_rem[g] = k.gbyte * noise_c[g, i]
            comp_start[g, i] = t
            t_upd[g] = t
            push_done(g, t)
            return True

        def push_done(g, t):
            nonlocal ctr
            rf, rm = rates(g)
            dt = gf_rem[g] / rf + gb_rem[g] / rm
            seqs[g] += 1
            ctr += 1
            heapq.heappush(heap, (t + dt, ctr, "cdone", g, seqs[g]))

        def advance(g, t):
            """Account progress of g's current kernel up to time t."""
            if ci[g] >= Kc or blocked[g] or np.isnan(comp_start[g, ci[g]]) \
                    or not np.isnan(comp_end[g, ci[g]]):
                t_upd[g] = t
                return
            dt = t - t_upd[g]
            if dt <= 0:
                return
            rf, rm = rates(g)
            if comm_busy[g]:
                comp_ovl[g, ci[g]] += dt
            # gflop portion first, then gbyte portion
            use = min(dt, gf_rem[g] / rf if rf > 0 else np.inf)
            gf_rem[g] -= use * rf
            rem_dt = dt - use
            gb_rem[g] = max(0.0, gb_rem[g] - rem_dt * rm)
            t_upd[g] = t

        def try_arrive(g, t):
            """Device g tries to arrive at the current collective cj."""
            nonlocal comm_active, ctr
            if cj >= Km or arrived[g] or comm_active:
                pass
            if cj >= Km or arrived[g]:
                return
            ck = wl.comm[cj]
            if ck.producer is not None and (
                    np.isnan(comp_end[g, ck.producer])):
                return
            arrived[g] = True
            comm_lstart[g, cj] = t
            advance(g, t)
            comm_busy[g] = True
            if ci[g] < Kc and not blocked[g]:
                push_done(g, t)
            if arrived.all():
                comm_active = True
                ctr += 1
                heapq.heappush(heap, (t + dur_comm[cj], ctr, "gend", cj, 0))

        def finish_kernel(g, t):
            nonlocal ctr
            i = ci[g]
            comp_end[g, i] = t
            busy_time[g] += comp_end[g, i] - comp_start[g, i]
            # producers: comm kernels waiting on this compute
            for j in self.producers.get(i, ()):
                if j == cj:
                    try_arrive(g, t)
            ci[g] += 1
            if load_kernel(g, t):
                pass
            # a newly loaded (or blocked) kernel might also be a producer edge
            if cj < Km:
                try_arrive(g, t)

        # ---- bootstrap ------------------------------------------------------
        for g in range(G):
            load_kernel(g, 0.0)
        for g in range(G):
            try_arrive(g, 0.0)

        # ---- event loop -----------------------------------------------------
        guard = 0
        while heap:
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("C3Sim: event budget exceeded (deadlock?)")
            t, _, kind, a, s = heapq.heappop(heap)
            if kind == "cdone":
                g = a
                if s != seqs[g] or ci[g] >= Kc or blocked[g]:
                    continue
                advance(g, t)
                if gf_rem[g] > 1e-9 or gb_rem[g] > 1e-9:
                    push_done(g, t)          # rate changed mid-flight
                    continue
                finish_kernel(g, t)
            elif kind == "gend":
                j = a
                comm_gend[j] = t
                comm_active = False
                arrived[:] = False
                for g in range(G):
                    advance(g, t)
                    comm_busy[g] = False
                # unblock compute kernels gated on j
                for g in range(G):
                    if blocked[g] and ci[g] < Kc:
                        k = wl.comp[ci[g]]
                        if k.wait_comm == j:
                            blocked[g] = False
                            load_kernel(g, t)
                    elif ci[g] < Kc and not np.isnan(comp_start[g, ci[g]]) \
                            and np.isnan(comp_end[g, ci[g]]):
                        push_done(g, t)      # rate changed: reschedule
                cj += 1
                if cj < Km:
                    for g in range(G):
                        try_arrive(g, t)

        t_iter = float(np.nanmax(comp_end))
        if Km:
            t_iter = max(t_iter, float(np.nanmax(comm_gend)))
        return IterationTrace(
            comp_names=[k.name for k in wl.comp],
            comm_names=[k.name for k in wl.comm],
            comp_start=comp_start, comp_end=comp_end, comp_overlap=comp_ovl,
            comm_start=comm_lstart, comm_end=comm_gend,
            t_iter=t_iter, util=busy_time / max(t_iter, 1e-12))


class NodeSim:
    """Closed-loop node: C3 execution × thermal/DVFS physics per iteration."""

    def __init__(self, workload: Workload, preset: DevicePreset,
                 sim_cfg: SimConfig, n_devices: int = 8, seed: int = 0,
                 straggler_boost: float = 1.28):
        self.thermal = ThermalModel(preset, n_devices, seed=seed,
                                    straggler_boost=straggler_boost)
        self.sim = C3Sim(workload, preset, sim_cfg, n_devices)
        self.state = self.thermal.init_state()
        self.G = n_devices
        self.history: List[dict] = []
        self.iteration = 0
        # warm up thermals: a few iterations to reach operating temperature
        for _ in range(30):
            self.step()
        self.history.clear()

    def set_power_caps(self, caps: np.ndarray) -> None:
        self.state.cap = np.asarray(caps, float).copy()

    def step(self) -> IterationTrace:
        freq_used = self.state.freq.copy()
        trace = self.sim.run_iteration(freq_used)
        self.thermal.update(self.state, trace.util, trace.t_iter)
        self.history.append({
            "iter": self.iteration,
            "freq_used": freq_used,
            "t_iter": trace.t_iter,
            "freq": self.state.freq.copy(),
            "temp": self.state.temp.copy(),
            "power": self.state.power.copy(),
            "cap": self.state.cap.copy(),
            "throughput": 1.0 / trace.t_iter,
            "energy": float(np.sum(self.state.power) * trace.t_iter),
        })
        self.iteration += 1
        return trace
