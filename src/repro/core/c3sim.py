"""Two-stream discrete-event node simulator: the Lit Silicon coupling engine.

Per device: a *compute stream* (ordered kernels, rate ∝ frequency for
FLOP-bound work, frequency-independent for HBM-bound work) and a *comm
stream* (ordered collectives).  Collectives are synchronization points: a
device's collective occupies its comm stream from its *local* arrival until
the *global* completion (leaders arrive early and wait — their comm kernels
stretch).  While the comm stream is busy, compute on that device is slowed by
the contention factor κ (paper §II-B: up to 40 %, avg 18.9 % kernel slowdown
under C3).  These two rules alone generate the paper's dynamics:

  ① identical start → ② leads grow on constant-overlap kernels →
  ③ leaders wait at collectives, overlap ↑, contention slows them,
    equilibrium → ④ leaders idle at the iteration barrier.

The simulator emits per-kernel (start, end, overlap) traces — the exact
interface Algorithm 1 consumes, and the same record a TPU profiler hook
would produce on real hardware.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.thermal import ChurnModel, DevicePreset, DeviceState, ThermalModel
from repro.core.workload import Workload


@dataclass
class SimConfig:
    """Knobs of the C3 (concurrent-execution coupling) iteration model:
    contention factors, link rates, stochastic jitter, and which engine
    executes the window arithmetic (docs/engines.md)."""

    kappa_comp: float = 0.45        # compute slowdown factor while comm busy
    kappa_mem: float = 0.75         # memory-bound slowdown while comm busy
    gemm_eff: float = 0.45          # fraction of peak for GEMM kernels
    comm_gbps: float = 62.0         # per-device effective collective GB/s
    comm_spike_p: float = 0.0       # probability of a latency spike per comm
    comm_spike_mult: float = 8.0    # spike multiplier (paper Fig 16 MoE)
    noise: float = 0.008            # per-kernel duration noise (lognormal σ)
    seed: int = 0
    engine: str = "event"           # "event" (heap reference) | "batched"
    #                                 | "vector" (numpy, batches node groups)
    #                                 | "jax" (XLA, jitted; see jax_engine)


def workload_arrays(wl: Workload) -> dict:
    """Vectorized kernel tables + producer/gate maps, cached on the Workload.

    Building these per C3Sim instance is wasteful once a cluster holds N
    nodes over the same workload; the cache keys on the Workload object so
    every C3Sim sharing it reuses one set of arrays.
    """
    cached = getattr(wl, "_c3_arrays", None)
    if cached is not None:
        return cached
    producers: Dict[int, List[int]] = {}
    for j, ck in enumerate(wl.comm):
        if ck.producer is not None:
            producers.setdefault(ck.producer, []).append(j)
    comm_gates: Dict[int, List[int]] = {}
    for i, k in enumerate(wl.comp):
        if k.wait_comm is not None:
            comm_gates.setdefault(k.wait_comm, []).append(i)
    arrays = {
        "gflop": np.array([k.gflop for k in wl.comp], float),
        "gbyte": np.array([k.gbyte for k in wl.comp], float),
        "wait": np.array([-1 if k.wait_comm is None else k.wait_comm
                          for k in wl.comp], int),
        "cbytes": np.array([c.bytes for c in wl.comm], float),
        "cprod": np.array([-1 if c.producer is None else c.producer
                           for c in wl.comm], int),
        "producers": producers,
        "comm_gates": comm_gates,
        "comp_names": [k.name for k in wl.comp],
        "comm_names": [c.name for c in wl.comm],
    }
    wl._c3_arrays = arrays
    return arrays


@dataclass
class IterationTrace:
    """Per-iteration telemetry: the Algorithm-1 input format."""

    comp_names: List[str]
    comm_names: List[str]
    comp_start: np.ndarray          # (G, Kc) s
    comp_end: np.ndarray            # (G, Kc)
    comp_overlap: np.ndarray        # (G, Kc) seconds overlapped with comm
    comm_start: np.ndarray          # (G, Km) local starts
    comm_end: np.ndarray            # (Km,) global ends
    t_iter: float
    util: np.ndarray                # (G,) compute busy fraction

    @property
    def comp_dur(self) -> np.ndarray:
        return self.comp_end - self.comp_start

    @property
    def overlap_ratio(self) -> np.ndarray:
        return self.comp_overlap / np.maximum(self.comp_dur, 1e-12)

    @property
    def comm_dur(self) -> np.ndarray:
        return self.comm_end[None, :] - self.comm_start


class C3Sim:
    """Event-driven execution of one Workload iteration on G devices."""

    def __init__(self, workload: Workload, preset: DevicePreset,
                 sim_cfg: SimConfig, n_devices: int):
        self.wl = workload
        self.preset = preset
        self.cfg = sim_cfg
        self.G = n_devices
        self.rng = np.random.default_rng(sim_cfg.seed + 104729)
        self.arrays = workload_arrays(workload)
        # comm waiters: comp index -> list of comm indices it produces
        self.producers: Dict[int, List[int]] = self.arrays["producers"]
        # comp waiters: comm index -> list of comp indices gated on it
        self.comm_gates: Dict[int, List[int]] = self.arrays["comm_gates"]

    # ---------------------------------------------------------------- noise
    def _draw_noise(self):
        """Per-iteration stochastic draws, shared by both engines so the
        same seed consumes the same RNG stream regardless of engine."""
        cfg, G = self.cfg, self.G
        Kc, Km = len(self.wl.comp), len(self.wl.comm)
        noise_c = np.exp(self.rng.normal(0, cfg.noise, (G, Kc)))
        base = self.arrays["cbytes"] / (cfg.comm_gbps * 1e9)
        if not cfg.comm_spike_p:
            dur_comm = base * np.exp(self.rng.normal(0, cfg.noise, Km))
        else:
            dur_comm = np.empty(Km)
            for j in range(Km):
                d = base[j]
                if self.rng.random() < cfg.comm_spike_p:
                    d *= cfg.comm_spike_mult * (1 + self.rng.random())
                dur_comm[j] = d * np.exp(self.rng.normal(0, cfg.noise))
        return noise_c, dur_comm

    # ------------------------------------------------------------------ run
    def run_iteration(self, freq: np.ndarray,
                      engine: Optional[str] = None) -> IterationTrace:
        """Execute one iteration at per-device frequencies ``freq`` (G,)
        and return its `IterationTrace`.

        The engine entry point: ``engine`` (default ``cfg.engine``) picks
        the execution strategy — ``"event"`` (heap reference),
        ``"batched"`` (per-window numpy), ``"vector"`` / ``"jax"``
        (all-lanes batched; this sim becomes a single-group call).  All
        engines consume the same RNG draws (`_draw_noise` runs first), so
        the choice never changes the physics — see docs/engines.md for the
        per-pair equivalence guarantees."""
        engine = engine or self.cfg.engine
        noise_c, dur_comm = self._draw_noise()
        if engine == "batched":
            return self._run_batched(freq, noise_c, dur_comm)
        if engine == "event":
            return self._run_event(freq, noise_c, dur_comm)
        if engine == "vector":
            return vector_iteration([self], [np.asarray(freq, float)],
                                    [(noise_c, dur_comm)])[0]
        if engine == "jax":
            from repro.core.jax_engine import jax_iteration
            return jax_iteration([self], [np.asarray(freq, float)],
                                 [(noise_c, dur_comm)])[0]
        raise ValueError(f"unknown engine {engine!r}")

    # ----------------------------------------------------- event (reference)
    def _run_event(self, freq: np.ndarray, noise_c: np.ndarray,
                   dur_comm: np.ndarray) -> IterationTrace:
        wl, G, cfg, p = self.wl, self.G, self.cfg, self.preset
        Kc, Km = len(wl.comp), len(wl.comm)
        comp_rate_f = p.peak_gflops * cfg.gemm_eff * (freq / p.f_max)  # GF/s
        mem_rate = p.hbm_gbps                                          # GB/s

        comp_start = np.full((G, Kc), np.nan)
        comp_end = np.full((G, Kc), np.nan)
        comp_ovl = np.zeros((G, Kc))
        comm_lstart = np.full((G, Km), np.nan)
        comm_gend = np.full(Km, np.nan)
        busy_time = np.zeros(G)

        # per-device runtime state
        ci = np.zeros(G, int)               # current compute kernel
        gf_rem = np.zeros(G)
        gb_rem = np.zeros(G)
        t_upd = np.zeros(G)
        comm_busy = np.zeros(G, bool)
        blocked = np.zeros(G, bool)         # compute gated on a comm kernel
        cj = 0                              # global comm cursor
        arrived = np.zeros(G, bool)
        comm_active = False                 # current collective in flight
        seqs = np.zeros(G, int)             # event staleness counters

        heap: list = []
        ctr = 0

        def rates(g):
            if comm_busy[g]:
                return (comp_rate_f[g] / (1 + cfg.kappa_comp),
                        mem_rate / (1 + cfg.kappa_mem))
            return comp_rate_f[g], mem_rate

        def load_kernel(g, t):
            """Load compute kernel ci[g]; returns False if stream done."""
            i = ci[g]
            if i >= Kc:
                return False
            k = wl.comp[i]
            if k.wait_comm is not None and not np.isfinite(
                    comm_gend[k.wait_comm]) :
                blocked[g] = True
                return False
            if k.wait_comm is not None and comm_gend[k.wait_comm] > t:
                blocked[g] = True
                return False
            gf_rem[g] = k.gflop * noise_c[g, i]
            gb_rem[g] = k.gbyte * noise_c[g, i]
            comp_start[g, i] = t
            t_upd[g] = t
            push_done(g, t)
            return True

        def push_done(g, t):
            nonlocal ctr
            rf, rm = rates(g)
            dt = gf_rem[g] / rf + gb_rem[g] / rm
            seqs[g] += 1
            ctr += 1
            heapq.heappush(heap, (t + dt, ctr, "cdone", g, seqs[g]))

        def advance(g, t):
            """Account progress of g's current kernel up to time t."""
            if ci[g] >= Kc or blocked[g] or np.isnan(comp_start[g, ci[g]]) \
                    or not np.isnan(comp_end[g, ci[g]]):
                t_upd[g] = t
                return
            dt = t - t_upd[g]
            if dt <= 0:
                return
            rf, rm = rates(g)
            if comm_busy[g]:
                comp_ovl[g, ci[g]] += dt
            # gflop portion first, then gbyte portion
            use = min(dt, gf_rem[g] / rf if rf > 0 else np.inf)
            gf_rem[g] -= use * rf
            rem_dt = dt - use
            gb_rem[g] = max(0.0, gb_rem[g] - rem_dt * rm)
            t_upd[g] = t

        def try_arrive(g, t):
            """Device g tries to arrive at the current collective cj."""
            nonlocal comm_active, ctr
            if cj >= Km or arrived[g] or comm_active:
                pass
            if cj >= Km or arrived[g]:
                return
            ck = wl.comm[cj]
            if ck.producer is not None and (
                    np.isnan(comp_end[g, ck.producer])):
                return
            arrived[g] = True
            comm_lstart[g, cj] = t
            advance(g, t)
            comm_busy[g] = True
            if ci[g] < Kc and not blocked[g]:
                push_done(g, t)
            if arrived.all():
                comm_active = True
                ctr += 1
                heapq.heappush(heap, (t + dur_comm[cj], ctr, "gend", cj, 0))

        def finish_kernel(g, t):
            nonlocal ctr
            i = ci[g]
            comp_end[g, i] = t
            busy_time[g] += comp_end[g, i] - comp_start[g, i]
            # producers: comm kernels waiting on this compute
            for j in self.producers.get(i, ()):
                if j == cj:
                    try_arrive(g, t)
            ci[g] += 1
            if load_kernel(g, t):
                pass
            # a newly loaded (or blocked) kernel might also be a producer edge
            if cj < Km:
                try_arrive(g, t)

        # ---- bootstrap ------------------------------------------------------
        for g in range(G):
            load_kernel(g, 0.0)
        for g in range(G):
            try_arrive(g, 0.0)

        # ---- event loop -----------------------------------------------------
        guard = 0
        while heap:
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("C3Sim: event budget exceeded (deadlock?)")
            t, _, kind, a, s = heapq.heappop(heap)
            if kind == "cdone":
                g = a
                if s != seqs[g] or ci[g] >= Kc or blocked[g]:
                    continue
                advance(g, t)
                if gf_rem[g] > 1e-9 or gb_rem[g] > 1e-9:
                    push_done(g, t)          # rate changed mid-flight
                    continue
                finish_kernel(g, t)
            elif kind == "gend":
                j = a
                comm_gend[j] = t
                comm_active = False
                arrived[:] = False
                for g in range(G):
                    advance(g, t)
                    comm_busy[g] = False
                # unblock compute kernels gated on j
                for g in range(G):
                    if blocked[g] and ci[g] < Kc:
                        k = wl.comp[ci[g]]
                        if k.wait_comm == j:
                            blocked[g] = False
                            load_kernel(g, t)
                    elif ci[g] < Kc and not np.isnan(comp_start[g, ci[g]]) \
                            and np.isnan(comp_end[g, ci[g]]):
                        push_done(g, t)      # rate changed: reschedule
                cj += 1
                if cj < Km:
                    for g in range(G):
                        try_arrive(g, t)

        return self._make_trace(comp_start, comp_end, comp_ovl,
                                comm_lstart, comm_gend, busy_time)

    def _make_trace(self, comp_start, comp_end, comp_ovl, comm_lstart,
                    comm_gend, busy_time) -> IterationTrace:
        """Shared trace assembly — both engines must produce the identical
        record (property-tested), so it lives in exactly one place."""
        t_iter = float(np.nanmax(comp_end))
        if comm_gend.size:
            t_iter = max(t_iter, float(np.nanmax(comm_gend)))
        return IterationTrace(
            comp_names=list(self.arrays["comp_names"]),
            comm_names=list(self.arrays["comm_names"]),
            comp_start=comp_start, comp_end=comp_end, comp_overlap=comp_ovl,
            comm_start=comm_lstart, comm_end=comm_gend,
            t_iter=t_iter, util=np.asarray(busy_time) / max(t_iter, 1e-12))

    # ------------------------------------------------------- batched engine
    def _run_batched(self, freq: np.ndarray, noise_c: np.ndarray,
                     dur_comm: np.ndarray) -> IterationTrace:
        """Fast path: exploit that collectives are processed strictly in
        order with a global barrier each — so the iteration decomposes into
        one window per collective.  Per window: (1) advance each device at
        full rate until its producer kernel completes (= its local arrival),
        (2) the global end is max(arrival) + duration, (3) advance each
        device slowed from its arrival to the global end.  No event heap,
        no re-push churn; kernel work tables are precomputed numpy arrays.
        Produces the same trace as the event engine (same RNG stream, same
        piecewise-rate integration at the same boundaries)."""
        wl, G, cfg, p = self.wl, self.G, self.cfg, self.preset
        A = self.arrays
        Kc, Km = len(wl.comp), len(wl.comm)
        k_wait = A["wait"].tolist()
        cprod = A["cprod"].tolist()
        rate_f = (p.peak_gflops * cfg.gemm_eff * (freq / p.f_max)).tolist()
        rate_f_s = [r / (1 + cfg.kappa_comp) for r in rate_f]
        rm, rm_s = p.hbm_gbps, p.hbm_gbps / (1 + cfg.kappa_mem)
        work_f = (A["gflop"][None, :] * noise_c).tolist()   # (G, Kc)
        work_b = (A["gbyte"][None, :] * noise_c).tolist()
        dur_comm_l = dur_comm.tolist()
        nan = float("nan")
        inf = float("inf")

        # hot-loop state lives in Python lists: scalar numpy indexing would
        # dominate the runtime
        comp_start = [[nan] * Kc for _ in range(G)]
        comp_end = [[nan] * Kc for _ in range(G)]
        comp_ovl = [[0.0] * Kc for _ in range(G)]
        comm_lstart = np.full((G, Km), np.nan)
        comm_gend = [nan] * Km
        busy_time = [0.0] * G

        ci = [0] * G                          # compute cursor per device
        tdev = [0.0] * G                      # compute-frontier time
        gfr = [0.0] * G                       # in-flight kernel residues
        gbr = [0.0] * G
        started = [False] * G

        def advance(g, t_stop, slowed, until=-1):
            """Advance g's compute stream to t_stop (window mode) or until
            kernel `until` completes (target mode, t_stop=inf)."""
            t = tdev[g]
            i = ci[g]
            rf = rate_f_s[g] if slowed else rate_f[g]
            rmm = rm_s if slowed else rm
            cs, ce, ov = comp_start[g], comp_end[g], comp_ovl[g]
            wf, wb = work_f[g], work_b[g]
            ran_out = True
            while i < Kc:
                if not started[g]:
                    w = k_wait[i]
                    if w >= 0:
                        ge = comm_gend[w]
                        if ge != ge:          # NaN: gated on a future comm
                            if until >= 0:
                                raise RuntimeError(
                                    "C3Sim[batched]: deadlock — producer "
                                    "kernel gated on an unfinished comm")
                            t = t_stop
                            ran_out = False
                            break
                        if ge >= t_stop:      # gate opens at/after window end
                            t = t_stop
                            ran_out = False
                            break
                        if ge > t:
                            t = ge            # idle until the gate opens
                    cs[i] = t
                    gfr[g] = wf[i]
                    gbr[g] = wb[i]
                    started[g] = True
                dt = gfr[g] / rf + gbr[g] / rmm
                if t + dt <= t_stop:
                    if slowed:
                        ov[i] += dt
                    t = t + dt
                    ce[i] = t
                    busy_time[g] += t - cs[i]
                    started[g] = False
                    i += 1
                    if until >= 0 and i > until:
                        ran_out = False
                        break
                else:                          # partial progress to t_stop
                    dt_avail = t_stop - t
                    if dt_avail > 0:
                        if slowed:
                            ov[i] += dt_avail
                        use = min(dt_avail, gfr[g] / rf)
                        gfr[g] -= use * rf
                        gbr[g] = max(0.0, gbr[g] - (dt_avail - use) * rmm)
                    t = t_stop
                    ran_out = False
                    break
            if ran_out and t_stop != inf:      # stream exhausted this window
                t = t_stop
            ci[g] = i
            tdev[g] = t

        prev_end = 0.0
        arr = [0.0] * G
        for j in range(Km):
            prod = cprod[j]
            for g in range(G):
                if prod >= 0 and comp_end[g][prod] != comp_end[g][prod]:
                    advance(g, inf, slowed=False, until=prod)
                    if comp_end[g][prod] != comp_end[g][prod]:
                        raise RuntimeError("C3Sim[batched]: producer of comm "
                                           f"{j} never completed (deadlock)")
                    arr[g] = comp_end[g][prod]
                else:
                    arr[g] = prev_end
            comm_lstart[:, j] = arr
            prev_end = max(arr) + dur_comm_l[j]
            comm_gend[j] = prev_end
            for g in range(G):
                advance(g, prev_end, slowed=True)
        for g in range(G):                     # drain after the last barrier
            advance(g, inf, slowed=False)

        return self._make_trace(np.asarray(comp_start), np.asarray(comp_end),
                                np.asarray(comp_ovl), comm_lstart,
                                np.asarray(comm_gend), busy_time)


# --------------------------------------------------------------------------- #
# vector engine: the batched window algorithm, numpy-vectorized over lanes
# --------------------------------------------------------------------------- #
def vector_iteration(sims: Sequence["C3Sim"], freqs: Sequence[np.ndarray],
                     noises: Sequence[tuple]) -> List[IterationTrace]:
    """Run one iteration for B node-groups in a single vectorized pass.

    Every sim must share the same Workload (all devices execute the same
    kernel schedule — true for every fleet this repo builds), but presets
    and frequencies may differ per group (heterogeneous fleets).  Comm
    barriers are *per group*: group b's collective j globally ends at
    max over b's lanes only, exactly as if each group ran alone — so the
    traces are the batched/event engine's traces, computed over B*G numpy
    lanes instead of a Python loop per device.  This is the ROADMAP
    "vectorize the per-window device loop" speedup: per-kernel cost is one
    set of (B, G) array ops instead of B*G scalar loop bodies, which keeps
    topology sweeps over 8-32 nodes tractable.

    ``noises`` carries each sim's own `_draw_noise()` output so per-node
    RNG streams stay identical to a per-node run.
    """
    wl = sims[0].wl
    A = sims[0].arrays
    cfg = sims[0].cfg
    for s in sims[1:]:
        if s.arrays is not A:
            raise ValueError("vector_iteration: all sims must share one "
                             "Workload (kernel schedules must be identical)")
    B, G = len(sims), sims[0].G
    Kc, Km = len(wl.comp), len(wl.comm)
    k_wait = A["wait"]                               # (Kc,)
    cprod = A["cprod"]                               # (Km,)

    rate_f = np.empty((B, G))
    rm = np.empty((B, 1))
    for b, (s, f) in enumerate(zip(sims, freqs)):
        p = s.preset
        rate_f[b] = p.peak_gflops * cfg.gemm_eff * (np.asarray(f) / p.f_max)
        rm[b, 0] = p.hbm_gbps
    rate_f_s = rate_f / (1 + cfg.kappa_comp)
    rm_s = rm / (1 + cfg.kappa_mem)

    noise_c = np.stack([n for n, _ in noises])       # (B, G, Kc)
    dur_comm = np.stack([d for _, d in noises])      # (B, Km)
    work_f = A["gflop"][None, None, :] * noise_c
    work_b = A["gbyte"][None, None, :] * noise_c

    comp_start = np.full((B, G, Kc), np.nan)
    comp_end = np.full((B, G, Kc), np.nan)
    comp_ovl = np.zeros((B, G, Kc))
    comm_lstart = np.full((B, G, Km), np.nan)
    comm_gend = np.full((B, Km), np.nan)
    busy = np.zeros((B, G))

    t = np.zeros((B, G))
    ci = np.zeros((B, G), int)                       # compute cursor per lane
    started = np.zeros((B, G), bool)
    gfr = np.zeros((B, G))                           # in-flight residues
    gbr = np.zeros((B, G))

    def advance_full(until: int, need: np.ndarray,
                     allow_gate_stall: bool = False) -> None:
        """Complete kernels up to `until` at full rate on `need` lanes
        (batched engine's target mode).  `allow_gate_stall` is the drain
        semantics: a lane hitting an unopened gate stops instead of
        raising (its remaining kernels never ran)."""
        live = need.copy()
        while True:
            active = live & (ci <= until)
            if not active.any():
                return
            i = int(ci[active].min())
            m = active & (ci == i)
            ns = m & ~started
            if ns.any():
                w = int(k_wait[i])
                if w >= 0:
                    ge = comm_gend[:, w][:, None]    # (B, 1) broadcast
                    stalled = ns & np.isnan(ge)
                    if stalled.any():
                        if not allow_gate_stall:
                            raise RuntimeError(
                                "C3Sim[vector]: deadlock — producer kernel "
                                "gated on an unfinished comm")
                        live &= ~stalled
                        ns &= ~stalled
                        m &= ~stalled
                    t[ns] = np.maximum(t, np.broadcast_to(ge, t.shape))[ns]
                comp_start[:, :, i][ns] = t[ns]
                gfr[ns] = work_f[:, :, i][ns]
                gbr[ns] = work_b[:, :, i][ns]
                started[ns] = True
            if m.any():
                dt = gfr / rate_f + gbr / rm
                t[m] = (t + dt)[m]
                comp_end[:, :, i][m] = t[m]
                busy[m] += (t - comp_start[:, :, i])[m]
                started[m] = False
                ci[m] = i + 1

    def advance_window(t_stop: np.ndarray) -> None:
        """Advance every lane, slowed, to its group's window end `t_stop`
        (B,), with partial progress on the in-flight kernel (batched
        engine's window mode)."""
        ts = t_stop[:, None]                         # (B, 1)
        done = np.zeros((B, G), bool)
        while True:
            active = ~done & (ci < Kc)
            if not active.any():
                break
            i = int(ci[active].min())
            m = active & (ci == i)
            ns = m & ~started
            if ns.any():
                w = int(k_wait[i])
                if w >= 0:
                    ge = np.broadcast_to(comm_gend[:, w][:, None], t.shape)
                    closed = ns & (np.isnan(ge) | (ge >= ts))
                    done |= closed
                    ns &= ~closed
                    m &= ~closed
                    t[ns] = np.maximum(t, ge)[ns]
                comp_start[:, :, i][ns] = t[ns]
                gfr[ns] = work_f[:, :, i][ns]
                gbr[ns] = work_b[:, :, i][ns]
                started[ns] = True
            if m.any():
                dt = gfr / rate_f_s + gbr / rm_s
                fits = m & (t + dt <= ts)
                if fits.any():
                    comp_ovl[:, :, i][fits] += dt[fits]
                    t[fits] = (t + dt)[fits]
                    comp_end[:, :, i][fits] = t[fits]
                    busy[fits] += (t - comp_start[:, :, i])[fits]
                    started[fits] = False
                    ci[fits] = i + 1
                part = m & ~fits
                if part.any():
                    avail = np.broadcast_to(ts, t.shape) - t
                    pp = part & (avail > 0)
                    if pp.any():
                        comp_ovl[:, :, i][pp] += avail[pp]
                        use = np.minimum(avail, gfr / rate_f_s)
                        gfr[pp] = (gfr - use * rate_f_s)[pp]
                        gbr[pp] = np.maximum(
                            0.0, gbr - (avail - use) * rm_s)[pp]
                    done |= part
        t[:, :] = ts                                 # all lanes end at stop

    prev_end = np.zeros(B)
    for j in range(Km):
        prod = int(cprod[j])
        if prod >= 0:
            need = np.isnan(comp_end[:, :, prod])
            if need.any():
                advance_full(prod, need)
            arr = np.where(need, comp_end[:, :, prod], prev_end[:, None])
        else:
            arr = np.broadcast_to(prev_end[:, None], (B, G)).copy()
        comm_lstart[:, :, j] = arr
        prev_end = arr.max(axis=1) + dur_comm[:, j]
        comm_gend[:, j] = prev_end
        advance_window(prev_end)
    advance_full(Kc - 1, ci < Kc, allow_gate_stall=True)   # drain

    return [sims[b]._make_trace(comp_start[b], comp_end[b], comp_ovl[b],
                                comm_lstart[b], comm_gend[b], busy[b])
            for b in range(B)]


class NodeSim:
    """Closed-loop node: C3 execution × thermal/DVFS physics per iteration."""

    def __init__(self, workload: Workload, preset: DevicePreset,
                 sim_cfg: SimConfig, n_devices: int = 8, seed: int = 0,
                 straggler_boost: float = 1.28,
                 churn: Optional[ChurnModel] = None):
        self.thermal = ThermalModel(preset, n_devices, seed=seed,
                                    straggler_boost=straggler_boost,
                                    churn=churn)
        self.sim = C3Sim(workload, preset, sim_cfg, n_devices)
        self.state = self.thermal.init_state()
        self.G = n_devices
        self.preset = preset
        self.history: List[dict] = []
        self.iteration = 0
        # telemetry hook (repro.telemetry.TelemetryCollector.attach_node):
        # None during warmup, so recordings start at operational time zero
        self.collector = None
        # fault-injection hook (repro.core.faults via ClusterSim): per-device
        # compute-rate multiplier (perf_degrade / device_loss); the device
        # still draws power at its governed frequency — sick silicon burns
        # watts without doing work.  None keeps execution bit-identical.
        self.perf_scale: Optional[np.ndarray] = None
        # warm up thermals: a few iterations to reach operating temperature
        for _ in range(30):
            self.step()
        self.history.clear()
        # churn clocks start at operational time zero, post warm-up
        self.thermal.t_sim = 0.0

    def set_power_caps(self, caps: np.ndarray) -> None:
        """Apply per-device power caps (W), as a fleet manager would; DVFS
        converges toward them on subsequent `commit` calls."""
        self.state.cap = np.asarray(caps, float).copy()

    def run_only(self) -> IterationTrace:
        """Execute one iteration at current frequencies without committing
        physics — a cluster layer runs all nodes first, then commits with
        the global (barrier-stretched) interval."""
        self._freq_used = self.state.freq.copy()
        if self.perf_scale is not None:
            self._freq_used = self._freq_used * self.perf_scale
        return self.sim.run_iteration(self._freq_used)

    def commit(self, trace: IterationTrace,
               t_interval: Optional[float] = None,
               active_wait: bool = False) -> None:
        """Thermal/DVFS update over `t_interval` (default: local t_iter).
        When the node is barrier-bound by a slower peer, its devices idle
        for t_interval - t_iter, lowering utilization (and so power) over
        the stretched interval.  Under `active_wait` (tensor parallelism)
        the wait happens *inside* collective kernels that keep the device
        near peak power — utilization stays high over the whole interval,
        so waiting on a straggler heats the waiters (paper §II-B)."""
        t = trace.t_iter if t_interval is None else t_interval
        if active_wait:
            util = (trace.util * trace.t_iter + (t - trace.t_iter)) / t
        else:
            util = trace.util * (trace.t_iter / t)
        self.thermal.update(self.state, util, t)
        self.history.append({
            "iter": self.iteration,
            "freq_used": self._freq_used,
            "t_iter": t,
            "t_local": trace.t_iter,
            "freq": self.state.freq.copy(),
            "temp": self.state.temp.copy(),
            "power": self.state.power.copy(),
            "cap": self.state.cap.copy(),
            "throughput": 1.0 / t,
            "energy": float(np.sum(self.state.power) * t),
        })
        if self.collector is not None:
            self.collector.on_node_commit(self, trace, t, self.iteration)
        self.iteration += 1

    def step(self) -> IterationTrace:
        """One standalone iteration: `run_only` then `commit` with the
        node's own t_iter (no barrier stretching)."""
        trace = self.run_only()
        self.commit(trace)
        return trace
