"""Analytical performance model — paper §IV-A (Eqs 1-6).

Kernels split into constant-overlap (C) and varying-overlap (V) sets.  The
baseline is straggler-bound:  t_baseline = t_max(C) + t_min(V).  Varying-
overlap kernels are already fastest on the straggler (least overlap), so the
only lever is frequency:  S_V = S_C and by Amdahl  S_iter = S_C  (Insight 5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.detect import classify_overlap

_AGG = {"max": np.max, "med": np.median, "min": np.min}


def t_agg(dur: np.ndarray, agg: str) -> float:
    """Eq 2: sum over kernels of agg-across-GPUs duration.  dur: (G, K)."""
    if dur.shape[1] == 0:
        return 0.0
    return float(_AGG[agg](dur, axis=0).sum())


@dataclass
class PerfPrediction:
    t_baseline: float
    s_c: float
    s_v: float
    r_c: float
    r_v: float
    s_iter: float


def predict_speedup(dur: np.ndarray, overlap_ratio: np.ndarray,
                    agg: str = "med", tol: float = 0.15) -> PerfPrediction:
    """dur/overlap_ratio: (G, K) from a baseline trace.

    agg is the alignment target for the C set (Eq 4): 'max' aligns everyone
    to the straggler (GPU-Red: no speedup), 'med'/'min' model boosting the
    straggler toward the pack/leaders (GPU-Realloc / CPU-Slosh).
    """
    const_mask = classify_overlap(overlap_ratio, tol)
    d_c = dur[:, const_mask]
    d_v = dur[:, ~const_mask]
    t_max_c = t_agg(d_c, "max")
    t_min_v = t_agg(d_v, "min")
    t_baseline = t_max_c + t_min_v                       # Eq 3
    s_c = t_max_c / max(t_agg(d_c, agg), 1e-12)          # Eq 4
    s_v = s_c                                            # Eq 4 (C3 term = 1)
    r_c = t_max_c / t_baseline                           # Eq 5
    r_v = t_min_v / t_baseline
    s_iter = 1.0 / (r_c / s_c + r_v / s_v)               # Eq 6 -> == s_c
    return PerfPrediction(t_baseline, s_c, s_v, r_c, r_v, s_iter)


def insight5_identity(pred: PerfPrediction) -> float:
    """|S_iter - S_C| — zero by Eq 6; exposed for the property tests."""
    return abs(pred.s_iter - pred.s_c)
