"""Accelerator-native engine: the kernel-window arithmetic on JAX/XLA.

Two programs live here, both jitted end-to-end:

  * :func:`jax_iteration` — the per-iteration engine behind
    ``engine="jax"``.  It is the drop-in analogue of
    :func:`repro.core.c3sim.vector_iteration`: one iteration for B barrier
    groups of G lanes each, computed as a single XLA program (``jax.vmap``
    over groups, the per-window device loop unrolled over a *static window
    plan* derived from the workload).  It consumes the **same numpy noise
    draws** as the vector engine (``C3Sim._draw_noise``), so its traces are
    the event/batched/vector traces up to float associativity (the
    property tests in tests/test_jax_engine.py pin the tolerance and the
    exact structural subset: NaN patterns, argmin/argmax outcomes, kernel
    ordering).

  * :func:`run_fleet_scan` — the whole-run engine behind Monte-Carlo
    sweeps (``repro.api.sweep``).  The iteration/churn loop — kernel
    windows, parallelism topology, thermal RC + DVFS governor, cooling
    churn — runs inside one ``jax.lax.scan``, so a 1000-node fleet steps
    T iterations (plus the NodeSim-style 30-iteration thermal warmup) in a
    single device program, and a sweep vmaps that program over samples.
    Per-kernel noise and TP jitter are drawn from JAX PRNG streams inside
    the scan (numpy Generator streams cannot be replayed there), so this
    path is *statistically* equivalent to ClusterSim, not trace-identical;
    the static thermal lottery (per-device ``r_th`` / ``m_coef``) is
    passed in as arrays and reproduces ClusterSim's numpy draws exactly
    (see :func:`build_fleet_arrays`).

Everything computes in float64 (``jax.experimental.enable_x64`` is entered
around tracing and execution; the global JAX config is left untouched so
the float32 Pallas training substrate is unaffected).  CPU-backend JAX is
fully supported — no GPU is required, in CI or anywhere else.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:                                    # the repo's jax_pallas toolchain
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    import repro._jax_compat            # noqa: F401  (version knobs)
    HAS_JAX = True
except Exception:                       # pragma: no cover - gated container
    HAS_JAX = False

__all__ = ["HAS_JAX", "WindowPlan", "window_plan", "jax_iteration",
           "FleetScanSpec", "fleet_scan_spec", "build_fleet_arrays",
           "run_fleet_scan"]


def _require_jax() -> None:
    if not HAS_JAX:
        raise RuntimeError(
            "engine='jax' requires the jax package, which this environment "
            "does not provide — use engine='vector' (numpy) instead")


# --------------------------------------------------------------------------- #
# static window plan
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class WindowPlan:
    """The static control flow of one iteration, precomputed per workload.

    The batched/vector engines discover at runtime which compute kernels
    each collective window touches; under a global barrier per collective
    that structure is *static*: after window ``j``'s arrival phase every
    lane has passed kernel ``max(cprod[:j+1])``, and no lane can pass the
    first kernel gated on a comm ``>= j`` before window ``j`` ends.  Those
    bounds give, per window, a closed kernel range for the full-rate
    arrival advance and the slowed window advance — so the whole iteration
    unrolls into ~``Kc + sum(window spans)`` masked vector steps with no
    data-dependent loops, which is what XLA wants.

    Within those ranges every comm gate is provably open and non-binding
    (lane clocks are pulled to each window's global end, which is ≥ every
    previously-ended gate), so the unrolled steps need no gate arithmetic
    at all; gate graphs that *could* deadlock are rejected while building
    the plan — the same error the numpy engines raise at runtime, caught
    statically here.

    Hashable (all-tuple) so compiled programs cache on it via
    ``functools.lru_cache``.
    """

    n_comp: int                               # Kc
    n_comm: int                               # Km
    cprod: Tuple[int, ...]                    # (Km,) producer kernel or -1
    k_wait: Tuple[int, ...]                   # (Kc,) gating comm or -1
    arrival: Tuple[Tuple[int, int], ...]      # (Km,) [lo, hi) full-rate range
    window: Tuple[Tuple[int, int], ...]       # (Km,) [lo, hi) slowed range
    drain_lo: int                             # first kernel of the drain

    @property
    def n_steps(self) -> int:
        """Total unrolled kernel-steps (compile-size indicator)."""
        spans = sum(hi - lo for lo, hi in self.arrival)
        spans += sum(hi - lo for lo, hi in self.window)
        return spans + (self.n_comp - self.drain_lo)


def window_plan(wl) -> WindowPlan:
    """Build (and cache on the workload) the static window plan."""
    cached = getattr(wl, "_c3_jax_plan", None)
    if cached is not None:
        return cached
    from repro.core.c3sim import workload_arrays
    A = workload_arrays(wl)
    k_wait = tuple(int(x) for x in A["wait"])
    cprod = tuple(int(x) for x in A["cprod"])
    Kc, Km = len(k_wait), len(cprod)
    # first kernel gated on comm >= j, per window j
    first_gated = []
    for j in range(Km):
        idx = [i for i, w in enumerate(k_wait) if w >= j]
        first_gated.append(min(idx) if idx else Kc)
    arrival: List[Tuple[int, int]] = []
    window: List[Tuple[int, int]] = []
    maxprod = -1
    for j in range(Km):
        prod = cprod[j]
        if prod >= 0:
            lo = maxprod + 1
            for i in range(lo, prod + 1):
                if k_wait[i] >= j:
                    raise RuntimeError(
                        f"C3Sim[jax]: deadlock — kernel {i} (producer path "
                        f"of comm {j}) is gated on comm {k_wait[i]}, which "
                        f"cannot have ended")
            arrival.append((lo, prod + 1))
            maxprod = max(maxprod, prod)
        else:
            arrival.append((0, 0))
        window.append((maxprod + 1, max(maxprod + 1, first_gated[j])))
    plan = WindowPlan(n_comp=Kc, n_comm=Km, cprod=cprod, k_wait=k_wait,
                      arrival=tuple(arrival), window=tuple(window),
                      drain_lo=maxprod + 1)
    wl._c3_jax_plan = plan
    return plan


# --------------------------------------------------------------------------- #
# one iteration for one barrier group (G lanes) — a scan over a step table
# --------------------------------------------------------------------------- #
# step kinds in the static table
_K_KERNEL = 0       # advance kernel idx (capped→slowed toward prev_end)
_K_COMM = 1         # resolve comm idx: arrival, global end, new barrier
_K_PULL = 2         # pull every lane's clock to the barrier (window end)


@functools.lru_cache(maxsize=64)
def _step_table(plan: WindowPlan):
    """Flatten the window plan into (kind, idx, capped) per scan step.

    Two identities make one uniform kernel-step possible (both follow from
    ``WindowPlan``'s invariant that lane clocks start each window at the
    previous barrier):

      * the arrival-phase ``need`` mask is redundant — for kernels
        ``i <= prod`` a lane has ``ci == i`` iff it still needs to produce,
        so the plain cursor match is the mask;
      * the arrival value is ``max(comp_end[prod], prev_end)`` elementwise
        — lanes that finished the producer in an earlier window did so at
        or before the previous barrier, lanes that finished it this window
        did so at or after it.
    """
    kinds: List[int] = []
    idx: List[int] = []
    capped: List[bool] = []

    def emit(kind, i, c=False):
        kinds.append(kind)
        idx.append(i)
        capped.append(c)

    for j in range(plan.n_comm):
        lo, hi = plan.arrival[j]
        for i in range(lo, hi):
            emit(_K_KERNEL, i)
        emit(_K_COMM, j)
        lo, hi = plan.window[j]
        for i in range(lo, hi):
            emit(_K_KERNEL, i, c=True)
        emit(_K_PULL, 0)
    for i in range(plan.drain_lo, plan.n_comp):
        emit(_K_KERNEL, i)
    return (np.asarray(kinds, np.int32), np.asarray(idx, np.int32),
            np.asarray(capped))


def _iteration_scan(plan: WindowPlan, kappa_comp, kappa_mem,
                    rate_f, rm, work_f, work_b, dur_comm, emit: bool):
    """Run the step-table scan for one barrier group of G lanes.

    Pure function of the per-lane compute rates (G,), the group memory
    rate (scalar), the noised work tables (G, Kc) and collective durations
    (Km,).  Mirrors the vector engine's piecewise-rate integration at the
    same window boundaries, expressed as a `jax.lax.scan` over the
    workload's static `_step_table` so compile time is independent of
    kernel count; see `WindowPlan` for why no gate checks appear here.

    The scan carries only (G,) lane state — trace matrices are *emitted*
    per step (``emit=True``) and reassembled afterwards with static
    segment reductions (carrying (Kc, G) buffers through a scan forces XLA
    to copy them every step).  Two further identities keep the carry
    small: at a comm-resolve step every lane's clock *is* its arrival
    (producers just finished at ``t``, everyone else sits at the barrier),
    and completion bookkeeping only needs the in-flight kernel's start
    time (``cur_start``).  With ``emit=False`` only the carry survives —
    enough for ``t_iter``/``util``, and several times cheaper; the fleet
    scan runs in that mode.

    Returns ``(carry, ys)`` where carry is
    ``(t, ci, started, gfr, gbr, busy, cur_start, prev_end)`` and ys is
    ``(s_rows, e_rows, o_rows)`` stacked over steps, or ``None``.
    """
    G = work_f.shape[0]
    Kc, Km = plan.n_comp, plan.n_comm
    rate_f_s = rate_f / (1.0 + kappa_comp)
    rm_s = rm / (1.0 + kappa_mem)
    w_f = jnp.transpose(work_f)                  # (Kc, G): per-step row reads
    w_b = jnp.transpose(work_b)
    kinds_np, idx_np, capped_np = _step_table(plan)
    xs = (jnp.asarray(kinds_np), jnp.asarray(idx_np),
          jnp.asarray(capped_np))
    dur = dur_comm if Km else jnp.zeros((1,))
    INF = jnp.inf

    def body(carry, x):
        kind, i, cap = x
        t, ci, started, gfr, gbr, busy, cur_start, prev_end = carry
        is_k = kind == _K_KERNEL
        is_c = kind == _K_COMM
        # -- kernel step: full-rate to completion (target mode) or slowed
        #    toward the barrier with partial progress (window mode)
        ts = jnp.where(cap, prev_end, INF)
        rf = jnp.where(cap, rate_f_s, rate_f)
        rmm = jnp.where(cap, rm_s, rm)
        m = is_k & (ci == i)
        ns = m & ~started
        # comm steps borrow the start-row slot for their arrivals (each
        # lane's clock *is* its arrival); segment routing separates them
        s_row = jnp.where(ns | is_c, t, INF) if emit else None
        cur_start = jnp.where(ns, t, cur_start)
        gfr = jnp.where(ns, w_f[jnp.minimum(i, Kc - 1)], gfr)
        gbr = jnp.where(ns, w_b[jnp.minimum(i, Kc - 1)], gbr)
        started = started | ns
        dt = gfr / rf + gbr / rmm
        fits = m & (t + dt <= ts)
        t = jnp.where(fits, t + dt, t)
        e_row = jnp.where(fits, t, INF) if emit else None
        busy = busy + jnp.where(fits, t - cur_start, 0.0)
        started = started & ~fits
        ci = jnp.where(fits, i + 1, ci)
        avail = ts - t
        pp = m & ~fits & (avail > 0)
        use = jnp.minimum(avail, gfr / rate_f_s)
        gfr_new = jnp.where(pp, gfr - use * rate_f_s, gfr)
        gbr = jnp.where(pp, jnp.maximum(0.0, gbr - (avail - use) * rm_s),
                        gbr)
        o_row = (jnp.where(fits & cap, dt, 0.0)
                 + jnp.where(pp, avail, 0.0)) if emit else None
        # -- comm resolve: the collective globally ends at max arrival
        #    (= max lane clock) + duration, which is the next barrier
        ge = jnp.max(t) + dur[jnp.minimum(i, max(Km, 1) - 1)]
        prev_end = jnp.where(is_c, ge, prev_end)
        # -- barrier pull: window over, every lane ends at the barrier
        t = jnp.where(kind == _K_PULL, prev_end, t)
        new = (t, ci, started, gfr_new, gbr, busy, cur_start, prev_end)
        return new, ((s_row, e_row, o_row) if emit else None)

    init = (jnp.zeros((G,)), jnp.zeros((G,), jnp.int32),
            jnp.zeros((G,), bool), jnp.zeros((G,)), jnp.zeros((G,)),
            jnp.zeros((G,)), jnp.zeros((G,)), jnp.asarray(0.0))
    return jax.lax.scan(body, init, xs)


def _group_iteration(plan: WindowPlan, kappa_comp, kappa_mem,
                     rate_f, rm, work_f, work_b, dur_comm):
    """One full-trace iteration for one group: scan + trace reassembly."""
    Kc, Km = plan.n_comp, plan.n_comm
    kinds_np, idx_np, _ = _step_table(plan)
    carry, ys = _iteration_scan(plan, kappa_comp, kappa_mem, rate_f, rm,
                                work_f, work_b, dur_comm, emit=True)
    busy = carry[5]
    s_rows, e_rows, o_rows = ys

    # reassemble (G, Kc)/(G, Km) trace matrices via static routing tables:
    # each (lane, kernel) start/end is written at most once (INF elsewhere),
    # so segment-min over the step axis recovers it; overlaps accumulate.
    # comm steps route to the dump segment Kc so their borrowed start-row
    # values never reach the compute matrices.
    seg = jnp.asarray(np.where(kinds_np == _K_KERNEL, idx_np, Kc))
    comp_start = jax.ops.segment_min(s_rows, seg, num_segments=Kc + 1)[:Kc]
    comp_end = jax.ops.segment_min(e_rows, seg, num_segments=Kc + 1)[:Kc]
    comp_ovl = jax.ops.segment_sum(o_rows, seg, num_segments=Kc + 1)[:Kc]
    comp_start = jnp.where(jnp.isinf(comp_start), jnp.nan, comp_start)
    comp_end = jnp.where(jnp.isinf(comp_end), jnp.nan, comp_end)
    comm_pos = jnp.asarray(np.flatnonzero(kinds_np == _K_COMM))
    comm_lstart = s_rows[comm_pos]               # (Km, G), one row per comm
    comm_gend = jnp.max(comm_lstart, axis=1) + dur_comm[:Km]
    return (jnp.transpose(comp_start), jnp.transpose(comp_end),
            jnp.transpose(comp_ovl), jnp.transpose(comm_lstart),
            comm_gend, busy)


def _group_summary(plan: WindowPlan, kappa_comp, kappa_mem,
                   rate_f, rm, work_f, work_b, dur_comm):
    """Carry-only iteration for one group: just ``(t_iter, util)``.

    ``t_iter`` is the max lane clock after the drain (per-lane completion
    times are nondecreasing, so the final clock is the lane's last
    completion) held to the final barrier; ``util`` is busy time over it.
    Several times cheaper than `_group_iteration` — no per-step trace
    emission — and what `run_fleet_scan` iterates.
    """
    carry, _ = _iteration_scan(plan, kappa_comp, kappa_mem, rate_f, rm,
                               work_f, work_b, dur_comm, emit=False)
    t, busy, prev_end = carry[0], carry[5], carry[7]
    t_iter = jnp.maximum(jnp.max(t), prev_end)
    util = busy / jnp.maximum(t_iter, 1e-12)
    return t_iter, util


@functools.lru_cache(maxsize=32)
def _compiled_iteration(plan: WindowPlan, kappa_comp: float,
                        kappa_mem: float):
    """Jitted vmap of `_group_iteration` over B groups, cached per
    (workload plan, contention factors)."""
    fn = functools.partial(_group_iteration, plan, kappa_comp, kappa_mem)
    return jax.jit(jax.vmap(fn))


def jax_iteration(sims: Sequence, freqs: Sequence[np.ndarray],
                  noises: Sequence[tuple]) -> List:
    """Run one iteration for B node-groups as a single XLA program.

    Same contract as :func:`repro.core.c3sim.vector_iteration`: every sim
    must share one Workload, presets/frequencies may differ per group,
    comm barriers are per group, and ``noises`` carries each sim's own
    ``_draw_noise()`` output so per-node numpy RNG streams stay identical
    to a per-node run.  Returns the per-group `IterationTrace`s; they
    match the vector engine's within float tolerance (XLA may fuse
    multiply-adds, so bitwise equality is not guaranteed).
    """
    _require_jax()
    wl = sims[0].wl
    A = sims[0].arrays
    cfg = sims[0].cfg
    for s in sims[1:]:
        if s.arrays is not A:
            raise ValueError("jax_iteration: all sims must share one "
                             "Workload (kernel schedules must be identical)")
    plan = window_plan(wl)
    B, G = len(sims), sims[0].G

    rate_f = np.empty((B, G))
    rm = np.empty(B)
    for b, (s, f) in enumerate(zip(sims, freqs)):
        p = s.preset
        rate_f[b] = p.peak_gflops * cfg.gemm_eff * (np.asarray(f) / p.f_max)
        rm[b] = p.hbm_gbps
    noise_c = np.stack([n for n, _ in noises])       # (B, G, Kc)
    dur_comm = np.stack([d for _, d in noises])      # (B, Km)
    work_f = A["gflop"][None, None, :] * noise_c
    work_b = A["gbyte"][None, None, :] * noise_c

    fn = _compiled_iteration(plan, float(cfg.kappa_comp),
                             float(cfg.kappa_mem))
    with enable_x64():
        out = fn(jnp.asarray(rate_f), jnp.asarray(rm),
                 jnp.asarray(work_f), jnp.asarray(work_b),
                 jnp.asarray(dur_comm))
    comp_start, comp_end, comp_ovl, comm_lstart, comm_gend, busy = (
        np.asarray(x) for x in out)
    return [sims[b]._make_trace(comp_start[b], comp_end[b], comp_ovl[b],
                                comm_lstart[b], comm_gend[b], busy[b])
            for b in range(B)]


# --------------------------------------------------------------------------- #
# whole-run fleet scan: iterations × thermal × churn × topology in one jit
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class FleetScanSpec:
    """The static half of a fleet-scan program (hashable → compile cache).

    Everything that changes array shapes or control flow lives here;
    everything numeric rides in the `build_fleet_arrays` dict, so one
    compiled program serves a whole Monte-Carlo sweep via ``vmap``.
    """

    plan: WindowPlan
    n_nodes: int
    n_devices: int
    iterations: int
    warmup: int = 30                    # NodeSim's thermal warm-up length
    topology: str = "dp"                # dp | pp | tp
    microbatches: int = 8               # pp
    tp_syncs: int = 16                  # tp
    spike: bool = False                 # comm latency spikes enabled
    collect: str = "full"               # "full": (T, N) series | "summary"


_NODE_FIELDS = ("f_max", "f_min", "p_idle", "peak_gflops", "hbm_gbps",
                "t_amb", "t_throttle", "throttle_slope", "t_ref",
                "leak_quad", "intensity", "tau")


def fleet_scan_spec(workload, sim_cfg, cluster_cfg, iterations: int,
                    collect: str = "full",
                    devices_per_node: int = 8) -> FleetScanSpec:
    """The static companion of `build_fleet_arrays` for one scenario."""
    from repro.core.topology import make_topology
    cc = cluster_cfg
    if cc.topology not in ("dp", "pp", "tp"):
        raise ValueError(f"unsupported scan topology {cc.topology!r}")
    topo = make_topology(cc, cc.n_nodes, workload, 1.0, seed=0)
    return FleetScanSpec(
        plan=window_plan(workload), n_nodes=cc.n_nodes,
        n_devices=devices_per_node, iterations=int(iterations),
        topology=cc.topology, microbatches=cc.microbatches,
        tp_syncs=int(getattr(topo, "K", 1)),
        spike=bool(sim_cfg.comm_spike_p > 0), collect=collect)


def build_fleet_arrays(workload, preset, sim_cfg, cluster_cfg,
                       caps_w: Optional[float], seed: int,
                       devices_per_node: int = 8,
                       rng_seed: int = 0) -> Dict[str, np.ndarray]:
    """The numeric half of a fleet scan: per-lane thermal lottery, per-node
    preset constants, churn event tables, topology constants, PRNG key.

    The thermal draws (``r_th`` spread + straggler slot, silicon-lottery
    ``m_coef``) reproduce ``ThermalModel``'s numpy streams exactly — node
    ``n`` draws from ``default_rng(seed + 7919 * n)`` with the same
    clip/boost arithmetic, via an actual `ThermalModel` instance — so a
    scan run shares ClusterSim's static physics; only the per-iteration
    noise streams differ (JAX PRNG keyed on ``rng_seed``).

    To batch runs for a sweep, build one dict per sample and stack every
    entry along a new leading axis before calling `run_fleet_scan`.
    """
    from repro.core.c3sim import workload_arrays
    from repro.core.thermal import PRESETS, ThermalModel
    from repro.core.topology import make_topology

    cc = cluster_cfg
    N, G = cc.n_nodes, devices_per_node
    if cc.node_presets is not None:
        if len(cc.node_presets) != N:
            raise ValueError(f"node_presets has {len(cc.node_presets)} "
                             f"entries for {N} nodes")
        presets = [PRESETS[p] if isinstance(p, str) else p
                   for p in cc.node_presets]
    else:
        presets = [preset] * N

    arrays: Dict[str, np.ndarray] = {}
    r_th = np.empty((N, G))
    m_coef = np.empty((N, G))
    per_node = {f: np.empty(N) for f in _NODE_FIELDS}
    churn = cc.churn or {}
    max_ev = max([len(cm.events) for cm in churn.values()] + [1])
    drift_rate = np.zeros(N)
    ev_t = np.full((N, max_ev), np.inf)
    ev_dev = np.zeros((N, max_ev), np.int32)
    ev_factor = np.ones((N, max_ev))
    for n in range(N):
        boost = (cc.straggler_boost if n == cc.straggler_node
                 else cc.healthy_boost)
        tm = ThermalModel(presets[n], G, seed=seed + 7919 * n,
                          straggler_boost=boost, churn=None)
        r_th[n] = tm.r_th
        m_coef[n] = tm.m_coef
        for f in _NODE_FIELDS:
            per_node[f][n] = getattr(presets[n], f)
        cm = churn.get(n)
        if cm is not None:
            drift_rate[n] = cm.drift_rate
            for e, ev in enumerate(cm.events):
                ev_t[n, e] = ev.t
                ev_dev[n, e] = ev.device
                ev_factor[n, e] = ev.factor
    arrays["r_th"] = r_th
    arrays["m_coef"] = m_coef
    arrays.update(per_node)
    arrays["drift_rate"] = drift_rate
    arrays["ev_t"] = ev_t
    arrays["ev_dev"] = ev_dev
    arrays["ev_factor"] = ev_factor
    tdp = np.array([p.tdp for p in presets])
    arrays["tdp_caps"] = np.repeat(tdp[:, None], G, axis=1)
    arrays["caps"] = (np.full((N, G), float(caps_w))
                      if caps_w is not None else arrays["tdp_caps"].copy())

    A = workload_arrays(workload)
    arrays["gflop"] = A["gflop"]
    arrays["gbyte"] = A["gbyte"]
    arrays["cbytes"] = A["cbytes"]

    grad = cc.grad_bytes
    if grad is None:
        grad = sum(c.bytes for c in workload.comm
                   if c.name.startswith("rs_"))
        if grad <= 0:
            grad = workload.total_bytes / 3.0
    topo = make_topology(cc, N, workload, float(grad), seed=seed)
    arrays["comm_const"] = np.asarray(topo.comm_time(), float)
    arrays["tp_jitter"] = np.asarray(getattr(topo, "jitter", 0.0), float)
    arrays["tp_skew_cost"] = np.asarray(
        getattr(topo, "skew_cost", 0.0), float)
    for f in ("kappa_comp", "kappa_mem", "gemm_eff", "comm_gbps", "noise",
              "comm_spike_p", "comm_spike_mult"):
        arrays[f] = np.asarray(getattr(sim_cfg, f), float)
    arrays["key"] = np.asarray(
        np.random.default_rng(rng_seed).integers(0, 2 ** 32, size=2),
        np.uint32)
    return arrays


def _fleet_scan_core(spec: FleetScanSpec, a: Dict):
    """The pure scan program: warmup (uncoupled, TDP caps) then the main
    coupled loop, all under one trace.  ``a`` is the `build_fleet_arrays`
    dict as jnp arrays."""
    plan = spec.plan
    N, G = spec.n_nodes, spec.n_devices
    Kc, Km = plan.n_comp, plan.n_comm
    base_key = a["key"]

    def iteration(rate_f, rm, work_f, work_b, dur_comm):
        fn = jax.vmap(lambda rf, r, wf, wb, dc: _group_summary(
            plan, a["kappa_comp"], a["kappa_mem"], rf, r, wf, wb, dc))
        return fn(rate_f, rm, work_f, work_b, dur_comm)

    def m_eff(temp):
        dt = jnp.maximum(temp - a["t_ref"][:, None], 0.0)
        return a["m_coef"] * (1.0 + a["leak_quad"][:, None] * dt * dt)

    def effective_r_th(t_sim):
        drift = 1.0 + a["drift_rate"][:, None] * t_sim[:, None] / 3600.0
        active = jnp.where(t_sim[:, None] >= a["ev_t"], a["ev_factor"], 1.0)
        onehot = a["ev_dev"][:, :, None] == jnp.arange(G)[None, None, :]
        ev = jnp.prod(jnp.where(onehot, active[:, :, None], 1.0), axis=1)
        return a["r_th"] * drift * ev

    def draw_noise(key):
        k1, k2, k3 = jax.random.split(key, 3)
        noise_c = jnp.exp(a["noise"] * jax.random.normal(k1, (N, G, Kc)))
        base = a["cbytes"][None, :] / (a["comm_gbps"] * 1e9)
        dur = base * jnp.exp(a["noise"] * jax.random.normal(k2, (N, Km)))
        if spec.spike:
            ks, ku = jax.random.split(k3)
            hit = jax.random.uniform(ks, (N, Km)) < a["comm_spike_p"]
            mult = a["comm_spike_mult"] * (
                1.0 + jax.random.uniform(ku, (N, Km)))
            dur = dur * jnp.where(hit, mult, 1.0)
        return noise_c, dur

    def run_iteration(freq, key):
        noise_c, dur_comm = draw_noise(key)
        rate_f = (a["peak_gflops"][:, None] * a["gemm_eff"]
                  * freq / a["f_max"][:, None])
        work_f = a["gflop"][None, None, :] * noise_c
        work_b = a["gbyte"][None, None, :] * noise_c
        t_local, util = iteration(rate_f, a["hbm_gbps"], work_f, work_b,
                                  dur_comm)
        return t_local, util

    def topology_step(t_local, key):
        if spec.topology == "dp":
            t_fleet = jnp.max(t_local) + a["comm_const"]
            lead = jnp.max(t_local) - t_local
        elif spec.topology == "pp":
            tau = t_local / spec.microbatches
            t_fleet = (jnp.sum(tau)
                       + (spec.microbatches - 1) * jnp.max(tau)
                       + a["comm_const"])
            lead = t_fleet - t_local
        else:                           # tp
            K = spec.tp_syncs
            w = jnp.exp(jax.random.normal(key, (N, K)) * a["tp_jitter"])
            w = w / jnp.sum(w, axis=1, keepdims=True)
            seg = t_local[:, None] * w
            seg_max = jnp.max(seg, axis=0)
            t_skew = (a["tp_skew_cost"]
                      * jnp.sum(seg_max - jnp.min(seg, axis=0))
                      if N > 1 else 0.0)
            t_fleet = jnp.sum(seg_max) + t_skew + a["comm_const"]
            lead = jnp.sum(seg_max[None, :] - seg, axis=1)
        return t_fleet, lead

    def commit(temp, freq, cap, t_sim, util, t_interval):
        """`ThermalModel.update`, vectorized over (N, G) lanes: power from
        current freq/util, RC thermal step, then the governor picks
        next-interval frequencies from the *new* temperature."""
        u_pow = 0.8 + 0.2 * jnp.clip(util, 0.0, 1.0)
        draw = a["p_idle"][:, None] + m_eff(temp) * freq * u_pow
        power = jnp.minimum(draw, cap)
        t_ss = a["t_amb"][:, None] + effective_r_th(t_sim) * power
        alpha = 1.0 - jnp.exp(-t_interval[:, None] / a["tau"][:, None])
        temp = temp + alpha * (t_ss - temp)
        budget = jnp.maximum(cap - a["p_idle"][:, None], 1.0)
        f_cap = budget / (m_eff(temp) * a["intensity"][:, None])
        over = jnp.maximum(temp - a["t_throttle"][:, None], 0.0)
        f_hard = a["f_max"][:, None] * (
            1.0 - a["throttle_slope"][:, None] * over)
        freq = jnp.clip(jnp.minimum(f_cap, f_hard),
                        a["f_min"][:, None], a["f_max"][:, None])
        return temp, freq, power, t_sim + t_interval

    temp0 = a["t_amb"][:, None] + 20.0 + jnp.zeros((N, G))
    freq0 = a["f_max"][:, None] + jnp.zeros((N, G))

    def warm_body(carry, i):
        temp, freq, t_sim = carry
        k = jax.random.fold_in(base_key, i)
        t_local, util = run_iteration(freq, k)
        temp, freq, _, t_sim = commit(temp, freq, a["tdp_caps"], t_sim,
                                      util, t_local)
        return (temp, freq, t_sim), None

    (temp, freq, _), _ = jax.lax.scan(
        warm_body, (temp0, freq0, jnp.zeros(N)), jnp.arange(spec.warmup))
    t_sim = jnp.zeros(N)                # churn clock resets post-warmup

    def main_body(carry, i):
        temp, freq, t_sim = carry
        k = jax.random.fold_in(base_key, spec.warmup + 1 + i)
        kt = jax.random.fold_in(base_key, 2 ** 20 + i)  # tp jitter stream
        t_local, util = run_iteration(freq, k)
        t_fleet, lead = topology_step(t_local, kt)
        if spec.topology == "tp":       # active wait: hot inside collectives
            util_eff = (util * t_local[:, None]
                        + (t_fleet - t_local)[:, None]) / t_fleet
        else:                           # barrier/bubble wait: idle and cool
            util_eff = util * (t_local / t_fleet)[:, None]
        temp, freq, power, t_sim = commit(temp, freq, a["caps"], t_sim,
                                          util_eff, jnp.full(N, t_fleet))
        node_power = jnp.sum(power, axis=1)
        if spec.collect == "full":
            out = (t_fleet, t_local, lead, node_power)
        else:
            out = (t_fleet, jnp.max(lead), jnp.argmax(t_local),
                   jnp.argmin(lead), jnp.sum(node_power))
        return (temp, freq, t_sim), out

    (temp, freq, t_sim), series = jax.lax.scan(
        main_body, (temp, freq, t_sim), jnp.arange(spec.iterations))
    state = {"temp": temp, "freq": freq}
    if spec.collect == "full":
        t_fleet, t_local, lead, node_power = series
        return {"t_fleet": t_fleet, "t_local": t_local, "lead": lead,
                "node_power": node_power, **state}
    t_fleet, lead_max, slowest, strag, power = series
    return {"t_fleet": t_fleet, "lead_max": lead_max,
            "slowest_node": slowest, "straggler_node": strag,
            "fleet_power": power, **state}


@functools.lru_cache(maxsize=16)
def _compiled_scan(spec: FleetScanSpec, batched: bool):
    core = functools.partial(_fleet_scan_core, spec)
    return jax.jit(jax.vmap(core) if batched else core)


def run_fleet_scan(spec: FleetScanSpec,
                   arrays: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Execute one fleet run — or, when every array carries a leading
    sample axis, a whole batch of runs — as a single jitted scan program.

    Returns per-iteration series (``t_fleet`` plus, per ``spec.collect``,
    either full (T, N) ``t_local``/``lead``/``node_power`` series or
    per-iteration summary scalars) and the final thermal ``temp``/``freq``
    state, as numpy arrays.
    """
    _require_jax()
    batched = arrays["r_th"].ndim == 3
    fn = _compiled_scan(spec, batched)
    with enable_x64():
        out = fn({k: jnp.asarray(v) for k, v in arrays.items()})
    return {k: np.asarray(v) for k, v in out.items()}
