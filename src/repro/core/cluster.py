"""Cluster-scale Lit Silicon: N thermally-independent nodes coupled by a
parallelism topology.

Each node runs the paper's intra-node C3/thermal dynamics (`NodeSim`).
Across nodes, the `Topology` (topology.py) maps the per-node local
iteration times plus a link model onto the fleet iteration time and per-node
lead signals: data parallelism adds a gradient ring all-reduce over the
slower inter-node fabric plus a global barrier (the paper's case — one hot
GPU straggles every node in the fleet); pipeline parallelism couples stages
point-to-point so a hot stage only bubbles the pipeline; tensor parallelism
syncs every layer on the fast link so waits happen inside collectives at
near-peak power.

Thermal feedback is wait-aware: under barrier/bubble topologies, nodes that
finish early idle and cool — the wasted provisioned power the
FleetPowerManager reallocates toward the straggler.  Under tensor
parallelism the waiters stay hot inside collective kernels and throttle
toward the straggler (tighter coupling).

Fleets may be heterogeneous (per-node `DevicePreset`, e.g. mixed air- and
liquid-cooled chassis) and may churn (per-node `ChurnModel` degrading
cooling over simulated time so stragglers emerge and migrate mid-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.c3sim import (IterationTrace, NodeSim, SimConfig,
                              vector_iteration)
from repro.core.faults import FaultModel
from repro.core.thermal import PRESETS, ChurnModel, DevicePreset
from repro.core.topology import Topology, make_topology, ring_allreduce_time
from repro.core.workload import Workload

__all__ = ["ClusterConfig", "ClusterSim", "ring_allreduce_time"]


@dataclass
class ClusterConfig:
    n_nodes: int = 4
    inter_node_gbps: float = 12.5     # per-device effective inter-node GB/s
    grad_bytes: Optional[float] = None  # DP all-reduce payload per device;
    #                                     default: sum of the workload's
    #                                     gradient reduce-scatter payloads
    straggler_node: int = 0           # node hosting the hot GPU
    straggler_boost: float = 1.28     # r_th multiplier for that GPU
    healthy_boost: float = 1.0        # boost on every other node's worst slot
    engine: str = "batched"           # C3Sim engine for node iterations:
    #                                   "batched" | "event" | "vector" | "jax"
    #                                   (vector/jax batch all nodes per step)
    # ---------------------------------------------------------- topology
    topology: str = "dp"              # dp | pp | tp (see topology.py)
    microbatches: int = 8             # PP: microbatches per iteration
    act_bytes: Optional[float] = None  # PP p2p / TP sync payload override;
    #                                    default: Workload.act_bytes
    tp_gbps: float = 300.0            # TP collectives ride the fast link
    tp_bytes: Optional[float] = None  # TP per-sync payload override
    tp_syncs: Optional[int] = None    # TP sync points; default 2 per layer
    tp_jitter: float = 0.01           # TP per-segment lognormal sigma
    tp_skew_cost: float = 1.0         # ring-collective stretch per unit of
    #                                   arrival skew at each sync point
    # ------------------------------------------------- fleet heterogeneity
    node_presets: Optional[Sequence[Union[str, DevicePreset]]] = None
    # per-node DevicePreset (or PRESETS name); default: the ClusterSim
    # `preset` argument on every node (homogeneous fleet)
    churn: Optional[Dict[int, ChurnModel]] = None
    # node index -> cooling-churn model for that node's devices


class ClusterSim:
    """N `NodeSim`s coupled by a parallelism `Topology`."""

    def __init__(self, workload: Workload, preset: DevicePreset,
                 sim_cfg: SimConfig, cluster_cfg: ClusterConfig,
                 devices_per_node: int = 8, seed: int = 0,
                 faults: Optional[FaultModel] = None,
                 fault_nodes: Optional[Sequence[int]] = None,
                 fault_t0: float = 0.0):
        cc = cluster_cfg
        self.cfg = cc
        self.N = cc.n_nodes
        self.G = devices_per_node
        self.presets: List[DevicePreset] = self._resolve_presets(preset)
        self.preset = self.presets[0]
        node_engine = ("batched" if cc.engine in ("vector", "jax")
                       else cc.engine)
        node_sim_cfg = dataclasses.replace(sim_cfg, engine=node_engine)
        churn = cc.churn or {}
        self.nodes: List[NodeSim] = []
        for n in range(self.N):
            boost = (cc.straggler_boost if n == cc.straggler_node
                     else cc.healthy_boost)
            self.nodes.append(NodeSim(
                workload, self.presets[n],
                dataclasses.replace(node_sim_cfg, seed=sim_cfg.seed + n),
                n_devices=devices_per_node, seed=seed + 7919 * n,
                straggler_boost=boost, churn=churn.get(n)))
        grad = cc.grad_bytes
        if grad is None:
            grad = sum(c.bytes for c in workload.comm
                       if c.name.startswith("rs_"))
            if grad <= 0:
                grad = workload.total_bytes / 3.0
        self.grad_bytes = float(grad)
        self.topology: Topology = make_topology(
            cc, self.N, workload, self.grad_bytes, seed=seed)
        self.history: List[dict] = []
        self.iteration = 0
        # telemetry hook (TelemetryCollector.attach_cluster) — fleet-scope
        # records; the per-node hooks live on each NodeSim
        self.collector = None
        # ---------------------------------------------------- fault injection
        # ``fault_nodes`` maps local node index -> global node id (an
        # escalation runner rebuilds smaller fleets after drains but fault
        # events keep naming the physical node they were scheduled on);
        # ``fault_t0`` offsets this fleet's clock onto the global sim clock.
        self.faults = faults
        self.fault_nodes: List[int] = (list(fault_nodes)
                                       if fault_nodes is not None
                                       else list(range(self.N)))
        if len(self.fault_nodes) != self.N:
            raise ValueError(f"fault_nodes has {len(self.fault_nodes)} "
                             f"entries for {self.N} nodes")
        self.t_sim = float(fault_t0)
        self._fault_seen: set = set()
        if faults is not None:
            faults.validate()
            for n, node in enumerate(self.nodes):
                gid = self.fault_nodes[n]
                node.thermal.rth_fault = (
                    lambda gid=gid: self.faults.rth_multipliers(
                        self.t_sim, gid, self.G))

    def _resolve_presets(self, preset: DevicePreset) -> List[DevicePreset]:
        np_cfg = self.cfg.node_presets
        if np_cfg is None:
            return [preset] * self.N
        if len(np_cfg) != self.N:
            raise ValueError(f"node_presets has {len(np_cfg)} entries for "
                             f"{self.N} nodes")
        return [PRESETS[p] if isinstance(p, str) else p for p in np_cfg]

    # ------------------------------------------------------------------ api
    def allreduce_time(self) -> float:
        """DP gradient ring all-reduce time (informational for pp/tp)."""
        return ring_allreduce_time(self.grad_bytes, self.N,
                                   self.cfg.inter_node_gbps)

    def set_node_caps(self, node: int, caps: np.ndarray) -> None:
        self.nodes[node].set_power_caps(caps)

    def get_node_caps(self, node: int) -> np.ndarray:
        return self.nodes[node].state.cap.copy()

    def _run_nodes(self) -> List[IterationTrace]:
        if self.cfg.engine in ("vector", "jax") and self.N > 1:
            # one batched pass over all N*G lanes (numpy or XLA); per-node
            # RNG streams are drawn exactly as a per-node run would
            freqs, noises = [], []
            for node in self.nodes:
                f = node.state.freq.copy()
                if node.perf_scale is not None:
                    f = f * node.perf_scale
                node._freq_used = f
                freqs.append(node._freq_used)
                noises.append(node.sim._draw_noise())
            if self.cfg.engine == "jax":
                from repro.core.jax_engine import jax_iteration
                return jax_iteration([n.sim for n in self.nodes],
                                     freqs, noises)
            return vector_iteration([n.sim for n in self.nodes],
                                    freqs, noises)
        return [node.run_only() for node in self.nodes]

    def step(self) -> List[IterationTrace]:
        """One coupled iteration: all nodes execute locally, then the
        topology resolves the fleet time and per-node lead signals, and
        every node commits thermals over the stretched interval.

        With a ``FaultModel`` attached, active faults are applied first
        (compute-rate scales, step-time hangs), newly-onset events are
        reported to the collector, and the history row carries the
        ``sensor_dead`` mask telemetry observers must respect."""
        t_now = self.t_sim
        sensor_dead = None
        if self.faults is not None:
            for n, node in enumerate(self.nodes):
                node.perf_scale = self.faults.perf_scale(
                    t_now, self.fault_nodes[n], self.G)
            sensor_dead = np.array([self.faults.sensor_dead(t_now, g)
                                    for g in self.fault_nodes])
        traces = self._run_nodes()
        t_local = np.array([tr.t_iter for tr in traces])
        if self.faults is not None:
            hang = np.array([self.faults.hang_multiplier(t_now, g)
                             for g in self.fault_nodes])
            t_local = t_local * hang
        fs = self.topology.step(t_local)
        t_fleet = fs.t_fleet
        for node, tr in zip(self.nodes, traces):
            node.commit(tr, t_interval=t_fleet,
                        active_wait=self.topology.wait_active)
        power = np.array([float(np.sum(n.state.power)) for n in self.nodes])
        row = {
            "iter": self.iteration,
            "t_local": t_local,
            "t_fleet": t_fleet,
            "throughput": 1.0 / t_fleet,
            "node_power": power,
            "power": float(power.sum()),
            "slowest_node": int(np.argmax(t_local)),
            "lead": fs.lead,
            "comm_time": fs.comm_time,
            "topology": self.topology.name,
        }
        if self.faults is not None:
            row["t_sim"] = t_now
            row["sensor_dead"] = sensor_dead
        self.history.append(row)
        self.t_sim += t_fleet
        if self.faults is not None and self.collector is not None:
            for ev in self.faults.activated_between(
                    -np.inf, self.t_sim, nodes=self.fault_nodes):
                key = id(ev)
                if key in self._fault_seen:
                    continue
                self._fault_seen.add(key)
                self.collector.on_fault_event(
                    self.iteration - getattr(self, "_telemetry_iter0", 0),
                    t_sim=ev.t, kind=ev.kind, node=ev.node,
                    device=ev.device, value=ev.magnitude, source="fault")
        if self.collector is not None:
            self.collector.on_cluster_step(self, traces)
        self.iteration += 1
        return traces

    # ------------------------------------------------------------ reporting
    def fleet_throughput(self, last: int = 30) -> float:
        h = self.history[-last:]
        return float(np.mean([x["throughput"] for x in h]))

    def fleet_power(self, last: int = 30) -> float:
        h = self.history[-last:]
        return float(np.mean([x["power"] for x in h]))
