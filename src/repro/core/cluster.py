"""Cluster-scale Lit Silicon: N thermally-independent nodes coupled by
data parallelism.

Each node runs the paper's intra-node C3/thermal dynamics (`NodeSim`).
Across nodes, data parallelism adds a per-iteration gradient all-reduce over
the (much slower) inter-node fabric plus a global barrier: the fleet
iteration time is the *slowest* node's local time plus the ring all-reduce.
A single hot GPU on one node therefore straggles every node in the fleet —
the aggregation step that turns the paper's node-level observation into the
datacenter-scale cost claim ("Not All GPUs Are Created Equal" measures the
same compounding on real fleets).

Thermal feedback is barrier-aware: nodes that finish early idle at the
barrier, so their devices run at lower average utilization over the
stretched interval, draw less power, and cool — which is exactly the wasted
provisioned power the FleetPowerManager reallocates toward the straggler.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.c3sim import IterationTrace, NodeSim, SimConfig
from repro.core.thermal import DevicePreset
from repro.core.workload import Workload


@dataclass
class ClusterConfig:
    n_nodes: int = 4
    inter_node_gbps: float = 12.5     # per-device effective DP-fabric GB/s
    grad_bytes: Optional[float] = None  # all-reduce payload per device;
    #                                     default: sum of the workload's
    #                                     gradient reduce-scatter payloads
    straggler_node: int = 0           # node hosting the hot GPU
    straggler_boost: float = 1.28     # r_th multiplier for that GPU
    healthy_boost: float = 1.0        # boost on every other node's worst slot
    engine: str = "batched"           # C3Sim engine for node iterations


def ring_allreduce_time(payload_bytes: float, n_nodes: int,
                        gbps: float) -> float:
    """Bandwidth term of a ring all-reduce: 2(N-1)/N chunks over the link."""
    if n_nodes <= 1 or payload_bytes <= 0:
        return 0.0
    return 2.0 * (n_nodes - 1) / n_nodes * payload_bytes / (gbps * 1e9)


class ClusterSim:
    """N `NodeSim`s under data parallelism with a global iteration barrier."""

    def __init__(self, workload: Workload, preset: DevicePreset,
                 sim_cfg: SimConfig, cluster_cfg: ClusterConfig,
                 devices_per_node: int = 8, seed: int = 0):
        cc = cluster_cfg
        self.cfg = cc
        self.N = cc.n_nodes
        self.G = devices_per_node
        self.preset = preset
        node_sim_cfg = dataclasses.replace(sim_cfg, engine=cc.engine)
        self.nodes: List[NodeSim] = []
        for n in range(self.N):
            boost = (cc.straggler_boost if n == cc.straggler_node
                     else cc.healthy_boost)
            self.nodes.append(NodeSim(
                workload, preset,
                dataclasses.replace(node_sim_cfg, seed=sim_cfg.seed + n),
                n_devices=devices_per_node, seed=seed + 7919 * n,
                straggler_boost=boost))
        grad = cc.grad_bytes
        if grad is None:
            grad = sum(c.bytes for c in workload.comm
                       if c.name.startswith("rs_"))
            if grad <= 0:
                grad = workload.total_bytes / 3.0
        self.grad_bytes = float(grad)
        self.history: List[dict] = []
        self.iteration = 0

    # ------------------------------------------------------------------ api
    def allreduce_time(self) -> float:
        return ring_allreduce_time(self.grad_bytes, self.N,
                                   self.cfg.inter_node_gbps)

    def set_node_caps(self, node: int, caps: np.ndarray) -> None:
        self.nodes[node].set_power_caps(caps)

    def get_node_caps(self, node: int) -> np.ndarray:
        return self.nodes[node].state.cap.copy()

    def step(self) -> List[IterationTrace]:
        """One data-parallel iteration: all nodes execute, then the gradient
        all-reduce and global barrier stretch everyone to the slowest."""
        traces = [node.run_only() for node in self.nodes]
        t_local = np.array([tr.t_iter for tr in traces])
        t_fleet = float(t_local.max()) + self.allreduce_time()
        for node, tr in zip(self.nodes, traces):
            node.commit(tr, t_interval=t_fleet)
        power = np.array([float(np.sum(n.state.power)) for n in self.nodes])
        self.history.append({
            "iter": self.iteration,
            "t_local": t_local,
            "t_fleet": t_fleet,
            "throughput": 1.0 / t_fleet,
            "node_power": power,
            "power": float(power.sum()),
            "slowest_node": int(np.argmax(t_local)),
        })
        self.iteration += 1
        return traces

    # ------------------------------------------------------------ reporting
    def fleet_throughput(self, last: int = 30) -> float:
        h = self.history[-last:]
        return float(np.mean([x["throughput"] for x in h]))

    def fleet_power(self, last: int = 30) -> float:
        h = self.history[-last:]
        return float(np.mean([x["power"] for x in h]))
