"""Escalation above the power managers: detect → mitigate → drain →
elastic restart.

The paper's FleetPowerManager *tunes around* thermal stragglers by sloshing
power budget toward them.  Some stragglers no cap schedule can fix — a
device in thermal runaway, a dead sensor, a lost chip (faults.py).  This
module adds the control layer a production fleet runs above the power
managers:

  * :class:`EscalationPolicy` — a deterministic state machine over the
    *observed* per-node iteration-time stream (``FleetSample.t_obs``: the
    fleet sensor's view, NaN where a node's sensor died).  A node whose
    observed time exceeds ``straggle_threshold`` x the median of the other
    nodes accrues a strike per observation (a finite healthy reading
    resets the streak); a streak sustained for ``patience_s`` *simulated
    seconds* escalates the node — patience is measured in time, not step
    counts, because the fault itself inflates step time (a node limping at
    10x would stretch a step-counted window tenfold) — and a
    per-node :class:`~repro.train.fault.Watchdog` (fed the same observed
    ratios as simulated step durations) must corroborate with a stall
    before the policy orders a drain — so a power-manager-fixable lean
    never drains a node, while a transient ``kernel_hang`` shorter than
    the patience window is ridden out.  NaN observations retry
    ``sensor_retries`` times before the sensor is declared dead
    (escalation's own detection has to survive broken telemetry).
  * :func:`run_healing_fleet` — the measurable scenario: run a faulted
    fleet under the hierarchical power manager, and when the policy orders
    a drain, charge ``drain_s``, recompute the mesh over the survivors
    (:class:`~repro.train.fault.ElasticPlan`), restore progress from the
    last :class:`~repro.train.checkpoint.CheckpointManager` checkpoint
    (rolling back the iterations since it), charge ``restart_penalty_s``,
    and resume on the smaller fleet.  The report scores the whole story as
    **goodput**: useful node-iterations per simulated second, net of
    rollbacks, drains and restarts.

Every decision is a pure function of the observed stream and the config,
so a lossless telemetry trace replays the drain decisions bit-for-bit
offline (``repro.telemetry.replay.replay_escalation``).  Node ids in all
events and decisions are **global** (position in the original fleet),
stable across post-drain rebuilds.
"""
from __future__ import annotations

import dataclasses
import math
import tempfile
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.backends import ClusterSimBackend
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.faults import FaultModel
from repro.core.manager import FleetManagerConfig, FleetPowerManager
from repro.train.fault import ElasticPlan, Watchdog, WatchdogConfig

__all__ = ["DRAIN_MODES", "STAGES", "EscalationConfig", "EscalationEvent",
           "DrainDecision", "EscalationPolicy", "HealReport",
           "run_healing_fleet"]

DRAIN_MODES = ("escalate", "immediate", "never")

# the escalation state machine's observable stages, in order of severity;
# "restart" is emitted by the healing runner when the rebuilt fleet resumes
STAGES = ("suspect", "escalate", "sensor-dead", "drain", "restart")


def _default_watchdog() -> WatchdogConfig:
    # fed cross-sectional ratios (node time / median of the others), not
    # wall-clock durations: a healthy node sits at ~1.0, so a long window
    # keeps the stall baseline anchored to healthy history and a slow
    # drift (thermal runaway) still crosses stall_factor x median
    return WatchdogConfig(stall_factor=1.35, window=64)


@dataclass
class EscalationConfig:
    """Knobs of the detect→escalate→drain state machine and of the
    restart cost model the healing runner charges."""

    straggle_threshold: float = 1.25   # observed t / median(others) ratio
    patience_s: float = 4.0            # seconds a straggle streak must be
    #                                    sustained before escalation (time,
    #                                    not steps: the fault inflates dt)
    sensor_retries: int = 3            # NaN reads tolerated before declaring
    #                                    the node's sensor dead
    drain_mode: str = "escalate"       # escalate | immediate | never
    alert_corroborate: bool = False    # accept a firing observability alert
    #                                    (repro.obs) as drain corroboration,
    #                                    alongside the watchdog — off by
    #                                    default so pinned drain/goodput
    #                                    replays are untouched
    drain_s: float = 6.0               # seconds to drain + deschedule a node
    restart_penalty_s: float = 8.0     # checkpoint restore + re-setup time
    checkpoint_period: int = 10        # steps between checkpoints
    global_batch: int = 64             # kept across restarts (ElasticPlan)
    min_nodes: int = 1                 # never drain below this fleet size
    watchdog: WatchdogConfig = field(default_factory=_default_watchdog)

    def validate(self) -> "EscalationConfig":
        if self.drain_mode not in DRAIN_MODES:
            raise ValueError(f"drain_mode must be one of {DRAIN_MODES}, "
                             f"got {self.drain_mode!r}")
        if self.straggle_threshold <= 1.0:
            raise ValueError("straggle_threshold must be > 1")
        if self.patience_s <= 0:
            raise ValueError("patience_s must be > 0")
        if self.sensor_retries < 0:
            raise ValueError("sensor_retries must be >= 0")
        if self.checkpoint_period < 1:
            raise ValueError("checkpoint_period must be >= 1")
        if self.min_nodes < 1:
            raise ValueError("min_nodes must be >= 1")
        return self

    # manual dict codec (used for trace meta, where the spec-layer codec
    # is unavailable without an api->telemetry import cycle)
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["watchdog"] = dataclasses.asdict(self.watchdog)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "EscalationConfig":
        d = dict(d)
        wd = d.pop("watchdog", None)
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - names)
        if unknown:
            raise ValueError(f"unknown EscalationConfig key(s) {unknown}")
        cfg = cls(**d)
        if wd is not None:
            cfg.watchdog = WatchdogConfig(**wd)
        return cfg.validate()


@dataclass
class EscalationEvent:
    """One stage transition: ``stage`` (a ``STAGES`` entry) on global node
    ``node`` at observed step ``step`` / simulated second ``t_sim``."""

    step: int
    t_sim: float
    stage: str
    node: int
    value: float = 0.0                 # stage-specific (ratio, fleet size..)


@dataclass
class DrainDecision:
    """The policy's verdict that a node is beyond mitigation."""

    node: int                          # local index in the observed vector
    global_node: int
    step: int
    t_sim: float
    reason: str                        # "straggle" | "sensor"
    strikes: int
    ratio: float                       # last observed straggle ratio


class EscalationPolicy:
    """Deterministic drain-decision state machine (see module docstring).

    ``observe`` consumes one observed per-node iteration-time vector per
    sampled step and returns a :class:`DrainDecision` when a node should
    be drained (at most one per call).  All state is a pure function of
    the observation sequence and the config — no clocks, no RNG — which
    is what makes recorded decisions replayable offline.
    """

    def __init__(self, cfg: EscalationConfig,
                 nodes: Optional[Sequence[int]] = None,
                 on_event: Optional[Callable[[EscalationEvent], None]] = None):
        self.cfg = cfg.validate()
        self.events: List[EscalationEvent] = []
        self.on_event = on_event
        self.reset(nodes if nodes is not None else [])

    def reset(self, nodes: Sequence[int]) -> None:
        """Start a fresh observation epoch over ``nodes`` (global ids,
        index-aligned with subsequent ``observe`` vectors).  Called at
        every fleet (re)build — streaks never span an elastic restart."""
        self.nodes = list(nodes)
        n = len(self.nodes)
        self.strikes = [0] * n
        self.stale = [0] * n
        self.sensor_failed = [False] * n
        self.suspected = [False] * n
        self.escalated = [False] * n
        self.watchdogs = [Watchdog(dataclasses.replace(self.cfg.watchdog))
                          for _ in range(n)]
        self._stalls0 = [0] * n        # stall count at current streak start
        self.streak_t0 = [math.nan] * n   # t_sim of the streak's first strike
        self.alert_nodes: set = set()  # local indices with a firing alert

    # ------------------------------------------------------------------ events
    def emit(self, step: int, t_sim: float, stage: str, node: int,
             value: float = 0.0) -> EscalationEvent:
        ev = EscalationEvent(step=int(step), t_sim=float(t_sim),
                             stage=stage, node=int(node), value=float(value))
        self.events.append(ev)
        if self.on_event is not None:
            self.on_event(ev)
        return ev

    def note_alerts(self, nodes) -> None:
        """Update the set of *local* node indices with a firing
        observability alert (``ObsPipeline.firing_nodes()`` live, the
        reconstructed firing set on replay).  Consulted by ``observe``
        only when ``cfg.alert_corroborate`` is set — a pure input, so
        decisions stay replayable."""
        self.alert_nodes = set(int(n) for n in nodes)

    # ----------------------------------------------------------------- observe
    def observe(self, step: int, t_obs: np.ndarray,
                t_sim: float = 0.0) -> Optional[DrainDecision]:
        cfg = self.cfg
        t = np.asarray(t_obs, float)
        if len(t) != len(self.nodes):
            raise ValueError(f"observed {len(t)} nodes, policy tracks "
                             f"{len(self.nodes)} (call reset after a "
                             "membership change)")
        n = len(t)
        if n < 2:
            return None                # nothing to compare against
        decision: Optional[DrainDecision] = None
        for i in range(n):
            gid = self.nodes[i]
            if not np.isfinite(t[i]):
                # retry/backoff before declaring the sensor dead; a dead
                # sensor is itself an unrecoverable fault, so blind reads
                # beyond the retry budget accrue strikes
                self.stale[i] += 1
                if (self.stale[i] > cfg.sensor_retries
                        and not self.sensor_failed[i]):
                    self.sensor_failed[i] = True
                    self.emit(step, t_sim, "sensor-dead", gid,
                              float(self.stale[i]))
                if self.sensor_failed[i]:
                    if self.strikes[i] == 0:
                        self.streak_t0[i] = float(t_sim)
                    self.strikes[i] += 1
                ratio = math.nan
            else:
                self.stale[i] = 0      # a read came back: retry succeeded
                others = np.delete(t, i)
                others = others[np.isfinite(others)]
                med = float(np.median(others)) if others.size else math.nan
                ratio = (float(t[i]) / med
                         if (np.isfinite(med) and med > 0) else math.nan)
                if np.isfinite(ratio):
                    # the watchdog sees the ratio stream as step durations:
                    # a stall verdict is the corroborating authority
                    self.watchdogs[i].end_step(0.0, 0.0, dt=ratio)
                if np.isfinite(ratio) and ratio > cfg.straggle_threshold:
                    if self.strikes[i] == 0:
                        self.streak_t0[i] = float(t_sim)
                    self.strikes[i] += 1
                    if not self.suspected[i]:
                        self.suspected[i] = True
                        self.emit(step, t_sim, "suspect", gid, ratio)
                else:
                    self.strikes[i] = 0
                    self.suspected[i] = False
                    self.escalated[i] = False
                    self._stalls0[i] = self.watchdogs[i].stalls
                    self.streak_t0[i] = math.nan
            if self.strikes[i] == 0:
                continue
            # patience is a *time* window: at least two consecutive strikes
            # sustained for patience_s simulated seconds (immediate mode
            # escalates on the first strike)
            straggle_for = float(t_sim) - self.streak_t0[i]
            due = (self.strikes[i] >= 1 if cfg.drain_mode == "immediate"
                   else (self.strikes[i] >= 2
                         and straggle_for >= cfg.patience_s))
            if not due:
                continue
            if not self.escalated[i]:
                self.escalated[i] = True
                self.emit(step, t_sim, "escalate", gid, ratio)
            corroborated = (self.sensor_failed[i]
                            or self.watchdogs[i].stalls > self._stalls0[i]
                            or cfg.drain_mode == "immediate"
                            or (cfg.alert_corroborate
                                and i in self.alert_nodes))
            if (cfg.drain_mode != "never" and corroborated
                    and decision is None):
                decision = DrainDecision(
                    node=i, global_node=gid, step=int(step),
                    t_sim=float(t_sim),
                    reason=("sensor" if self.sensor_failed[i]
                            else "straggle"),
                    strikes=self.strikes[i], ratio=ratio)
                self.emit(step, t_sim, "drain", gid, ratio)
        return decision


# --------------------------------------------------------------------------- #
# the healing runner: fault → detect → drain → elastic restart, measured
# --------------------------------------------------------------------------- #
@dataclass
class HealReport:
    """What one healing run is worth, in goodput terms."""

    goodput: float                  # useful node-iterations / simulated s
    useful_units: float             # committed node-iterations
    lost_units: float               # rolled-back node-iterations
    t_total_s: float                # simulated seconds incl. drains/restarts
    energy_j: float
    progress: int                   # committed fleet iterations
    surviving_nodes: int
    false_drains: int               # drains of nodes with no unrecoverable
    #                                 fault active at decision time
    drains: List[dict]
    events: List[EscalationEvent]
    time_to_detect_s: float = math.nan   # first true drain: onset → decision
    time_to_heal_s: float = math.nan     # first true drain: decision → resume
    checkpoints: int = 0
    restores: int = 0
    cluster: object = None          # final-epoch ClusterSim (live handle)
    manager: object = None          # final-epoch FleetPowerManager (or None)


def _subfleet_config(cfg: ClusterConfig, alive: List[int]) -> ClusterConfig:
    """The ClusterConfig of the surviving fleet: per-node knobs reindexed
    from global node ids onto the new (smaller) local index space."""
    kw: dict = {"n_nodes": len(alive)}
    if cfg.node_presets is not None:
        kw["node_presets"] = [cfg.node_presets[g] for g in alive]
    if cfg.churn:
        kw["churn"] = {alive.index(g): cm for g, cm in cfg.churn.items()
                       if g in alive}
    if cfg.straggler_node in alive:
        kw["straggler_node"] = alive.index(cfg.straggler_node)
    else:                              # the boosted node was drained
        kw["straggler_node"] = 0
        kw["straggler_boost"] = cfg.healthy_boost
    return dataclasses.replace(cfg, **kw)


def _tree(progress: float, units: float, caps: np.ndarray,
          budgets: np.ndarray) -> dict:
    """The global-shaped (original fleet size) training-state tree the
    CheckpointManager persists; surviving rows are selected on restore."""
    return {"progress": np.asarray(float(progress)),
            "units": np.asarray(float(units)),
            "caps": np.asarray(caps, float),
            "budgets": np.asarray(budgets, float)}


def _observed(cluster: ClusterSim, collector, it: int):
    """The policy's input for iteration ``it``: the recorded fleet
    sample's observed t_local vector when telemetry is attached (None when
    the sensor skipped the iteration — the policy is then blind), else the
    simulator's own t_local with dead sensors masked to NaN."""
    if collector is not None:
        if collector.fleet and collector.fleet[-1].iteration == it:
            return collector.fleet[-1].t_obs
        return None
    h = cluster.history[-1]
    t = np.asarray(h["t_local"], float).copy()
    dead = h.get("sensor_dead")
    if dead is not None:
        t[np.asarray(dead, bool)] = np.nan
    return t


def run_healing_fleet(workload, preset, sim_cfg, cluster_cfg: ClusterConfig,
                      *, iterations: int,
                      faults: Optional[FaultModel] = None,
                      escalation: Optional[EscalationConfig] = None,
                      manager_cfg: Optional[FleetManagerConfig] = None,
                      tune_after: Optional[int] = None,
                      devices_per_node: int = 8, seed: int = 0,
                      node_caps_w: Optional[float] = None,
                      collector=None,
                      checkpoint_dir: Optional[str] = None,
                      alert_source=None) -> HealReport:
    """Run ``iterations`` committed fleet steps under fault injection and
    the escalation policy, healing through drains by elastic restart.

    Two clocks: ``step`` counts *executed* fleet steps monotonically (it
    drives telemetry iteration numbering, the manager's sampling cadence
    and checkpoint ids), while ``progress`` counts *committed* steps and
    rolls back to the restored checkpoint on every drain — the loop runs
    until ``progress`` reaches ``iterations``, so every run finishes the
    same amount of useful work and goodput is directly comparable across
    drain modes.
    """
    from repro.train.checkpoint import CheckpointManager   # pulls in jax

    esc = (escalation if escalation is not None
           else EscalationConfig(drain_mode="never"))
    esc.validate()
    if faults is not None:
        faults.validate()
    N0 = int(cluster_cfg.n_nodes)
    G = int(devices_per_node)
    tune_after = iterations // 2 if tune_after is None else int(tune_after)

    tmp = None
    if checkpoint_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="heal-ckpt-")
        checkpoint_dir = tmp.name
    ckpt = CheckpointManager(checkpoint_dir, keep=3, async_write=False)

    if collector is not None:
        collector.meta["escalation"] = esc.to_dict()

    def forward(ev: EscalationEvent) -> None:
        if collector is not None:
            collector.on_fault_event(ev.step, ev.t_sim, ev.stage, ev.node,
                                     value=ev.value, source="escalation")

    policy = EscalationPolicy(esc, on_event=forward)

    alive: List[int] = list(range(N0))
    step = 0                        # monotonic executed-step counter
    progress = 0                    # committed steps (rolls back on drain)
    units = 0.0                     # committed node-iterations
    lost_units = 0.0
    t_total = 0.0                   # global simulated clock
    energy_j = 0.0
    drains: List[dict] = []
    n_saves = n_restores = 0
    # global-shaped warm-start state (per original node)
    init_cap = (float(node_caps_w) if node_caps_w is not None
                else float(preset.tdp))
    caps_global = np.full((N0, G), init_cap)
    budgets_global = np.full(N0, G * init_cap)

    epoch = 0
    cluster = None
    mgr = None
    fault_seen: set = set()         # shared across epochs: a rebuilt fleet
    #                                 must not re-report old fault onsets
    while progress < iterations and len(alive) >= esc.min_nodes:
        cfg_e = _subfleet_config(cluster_cfg, alive)
        cluster = ClusterSim(workload, preset, sim_cfg, cfg_e,
                             devices_per_node=G,
                             seed=seed + 100003 * epoch,
                             faults=faults, fault_nodes=list(alive),
                             fault_t0=t_total)
        cluster._fault_seen = fault_seen
        if node_caps_w is not None:
            for n in range(cluster.N):
                cluster.set_node_caps(n, np.full(G, float(node_caps_w)))
        if collector is not None:
            collector.attach_cluster(cluster)
            # the trace describes the *original* fleet: post-drain epochs
            # shrink the live width but not the global node space
            collector.meta["n_nodes"] = N0
            # rebase the recording clock so iteration numbers continue
            # monotonically from the executed-step counter across epochs
            cluster._telemetry_iter0 = cluster.iteration - step
            for node in cluster.nodes:
                node._telemetry_iter0 = node.iteration - step
        backend = ClusterSimBackend(cluster)
        mgr = None
        if manager_cfg is not None:
            mcfg = manager_cfg
            if (mcfg.cluster_power_budget is not None and len(alive) < N0):
                mcfg = dataclasses.replace(
                    mcfg, cluster_power_budget=(
                        mcfg.cluster_power_budget * len(alive) / N0))
            mgr = FleetPowerManager(backend, mcfg, collector=collector)
        if epoch > 0:
            # warm start from the checkpointed cap/budget state — the
            # survivors keep their converged mitigation (paper Fig 12)
            backend.set_power_caps(caps_global[alive])
            if mgr is not None:
                mgr.import_budgets(budgets_global[alive])

        def save_ckpt() -> None:
            nonlocal n_saves, caps_global, budgets_global
            caps_global = caps_global.copy()
            caps_global[alive] = backend.get_power_caps()
            if mgr is not None:
                budgets_global = budgets_global.copy()
                budgets_global[alive] = mgr.node_budgets
            ckpt.save(step, _tree(progress, units, caps_global,
                                  budgets_global))
            n_saves += 1

        policy.reset(alive)
        save_ckpt()                 # epoch-start checkpoint: a restore
        #                             never rolls back across a rebuild
        if epoch > 0:
            policy.emit(step, t_total, "restart", -1, value=len(alive))

        drained = False
        while progress < iterations:
            it = step
            traces = backend.run_iteration()
            if mgr is not None and it >= tune_after:
                mgr.on_iteration(it, traces)
            h = cluster.history[-1]
            dt = float(h["t_fleet"])
            t_total += dt
            energy_j += float(h["power"]) * dt
            units += float(len(alive))
            progress += 1
            step = it + 1
            t_obs = _observed(cluster, collector, it)
            decision = None
            if t_obs is not None:
                # observability corroboration: the pipeline evaluated its
                # rules inside run_iteration (at the fleet sample), so the
                # firing set is current as of this observation
                if alert_source is not None:
                    policy.note_alerts(alert_source.firing_nodes())
                decision = policy.observe(it, t_obs, t_sim=t_total)
            if decision is not None and len(alive) - 1 < esc.min_nodes:
                decision = None     # floor reached: ride it out
            if decision is not None:
                g = decision.global_node
                onset = (faults.onset_of_unrecoverable(g, before=t_total)
                         if faults is not None else None)
                false_drain = onset is None
                ttd = (t_total - onset) if onset is not None else math.nan
                plan = ElasticPlan.after_failure(
                    len(alive) * G, G, model_parallel=G,
                    global_batch=esc.global_batch)
                tree, _ = ckpt.restore(_tree(0, 0, caps_global,
                                             budgets_global))
                n_restores += 1
                new_progress = int(round(float(np.asarray(tree["progress"]))))
                new_units = float(np.asarray(tree["units"]))
                lost_units += units - new_units
                rolled_back = progress - new_progress
                progress, units = new_progress, new_units
                caps_global = np.asarray(tree["caps"], float).copy()
                budgets_global = np.asarray(tree["budgets"], float).copy()
                heal_s = esc.drain_s + esc.restart_penalty_s
                # survivors idle at floor power while the node drains and
                # the job restores + re-setups
                idle_w = sum(cluster.presets[n].p_idle * G
                             for n in range(cluster.N)
                             if alive[n] != g)
                t_total += heal_s
                energy_j += idle_w * heal_s
                alive = [a for a in alive if a != g]
                drains.append({
                    "node": g, "step": decision.step,
                    "t_sim": decision.t_sim, "reason": decision.reason,
                    "ratio": decision.ratio, "strikes": decision.strikes,
                    "false": false_drain, "time_to_detect_s": ttd,
                    "time_to_heal_s": heal_s,
                    "rolled_back_iters": rolled_back,
                    "surviving_devices": plan.n_devices,
                    "mesh": list(plan.mesh_shape()),
                    "batch_per_replica": plan.batch_per_replica(),
                    "batch_padding": plan.batch_padding()})
                drained = True
                break
            if step % esc.checkpoint_period == 0:
                save_ckpt()
        if not drained:
            break
        epoch += 1

    if tmp is not None:
        tmp.cleanup()
    true_drains = [d for d in drains if not d["false"]]
    report = HealReport(
        goodput=(units / t_total if t_total > 0 else math.nan),
        useful_units=units, lost_units=lost_units,
        t_total_s=t_total, energy_j=energy_j,
        progress=progress, surviving_nodes=len(alive),
        false_drains=sum(1 for d in drains if d["false"]),
        drains=drains, events=list(policy.events),
        time_to_detect_s=(true_drains[0]["time_to_detect_s"]
                          if true_drains else math.nan),
        time_to_heal_s=(true_drains[0]["time_to_heal_s"]
                        if true_drains else math.nan),
        checkpoints=n_saves, restores=n_restores,
        cluster=cluster, manager=mgr)
    return report
