"""Device physics: thermal RC model + DVFS + power-cap governor.

This is the simulated analogue of the paper's §III-B profiling (Fig 5): each
device has its own thermal resistance (cooling quality varies with chassis
placement / manufacturing — paper §VIII-C) so identical workloads produce a
temperature spread; per-device DVFS then throttles the hottest devices into
stragglers.  Power caps act through the same governor the mitigation layer
tunes (paper footnote 2: power capping is more precise than frequency capping).

Units: time s, frequency GHz, power W, temperature °C, work GFLOP.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class DevicePreset:
    """Per-device class constants (MI300X node for paper validation;
    v5e host as the deployment target)."""

    name: str = "mi300x"
    f_max: float = 2.10                   # GHz
    f_min: float = 0.9
    tdp: float = 750.0                    # W
    p_idle: float = 140.0                 # W (β V² f + γΔTV + θV lumped)
    peak_gflops: float = 1_307_000.0      # bf16 dense peak at f_max
    hbm_gbps: float = 5_300.0             # GB/s
    t_amb: float = 32.0                   # °C inlet
    t_throttle: float = 90.0              # °C: hard safety derating onset
    throttle_slope: float = 0.03          # fraction of f_max shed per °C over
    t_ref: float = 40.0                   # °C leakage reference
    leak_quad: float = 1.0e-4             # quadratic leakage: M_eff factor/°C²
    intensity: float = 1.12               # peak-phase power / average (GEMMs)
    r_th_mean: float = 0.064              # °C/W junction->inlet
    r_th_spread: float = 0.10             # relative spread across devices
    tau: float = 25.0                     # s thermal time constant
    m_spread: float = 0.02                # silicon-lottery spread of M = P/f


V5E_PRESET = DevicePreset(
    name="v5e",
    f_max=1.70, f_min=0.8, tdp=250.0, p_idle=55.0,
    peak_gflops=197_000.0, hbm_gbps=819.0,
    t_amb=27.0, t_throttle=88.0, throttle_slope=0.03,
    t_ref=38.0, leak_quad=6.5e-5, intensity=1.10,
    r_th_mean=0.205, r_th_spread=0.10, tau=18.0, m_spread=0.02,
)

MI300X_PRESET = DevicePreset()


def derated_preset(preset: DevicePreset, r_th_factor: float,
                   suffix: str = "-air") -> DevicePreset:
    """A cooling-derated variant of ``preset``: same silicon, worse heat
    path (air-cooled chassis vs liquid, clogged filters, bad slot).  The
    Cooling Matters setup mixes exactly such nodes in one fleet."""
    return dataclasses.replace(preset, name=preset.name + suffix,
                               r_th_mean=preset.r_th_mean * r_th_factor)


MI300X_AIR_PRESET = derated_preset(MI300X_PRESET, 1.22)

PRESETS = {"mi300x": MI300X_PRESET, "v5e": V5E_PRESET,
           "mi300x-air": MI300X_AIR_PRESET}


# --------------------------------------------------------------------------- #
# Cooling churn: degradation over simulated operating time
# --------------------------------------------------------------------------- #
@dataclass
class ChurnEvent:
    """From simulated second ``t`` on, device ``device``'s thermal
    resistance is multiplied by ``factor`` (>1 degrades, <1 is a fan swap /
    filter clean).  Events compose multiplicatively."""

    t: float
    device: int
    factor: float


@dataclass
class ChurnModel:
    """Cooling efficiency drift over simulated time.

    "Not All GPUs Are Created Equal" observes fleets degrading
    heterogeneously over months: dust, fan wear, thermal-paste pump-out.
    ``drift_rate`` applies a uniform fractional r_th growth per simulated
    hour; ``events`` schedule discrete per-device changes, so a straggler
    can *emerge* mid-run and *migrate* (degrade device A, later repair A /
    degrade B harder).
    """

    drift_rate: float = 0.0                 # fractional r_th growth / hour
    events: List[ChurnEvent] = field(default_factory=list)

    def multipliers(self, t: float, n_devices: int) -> np.ndarray:
        m = np.full(n_devices, 1.0 + self.drift_rate * t / 3600.0)
        for ev in self.events:
            if t >= ev.t:
                m[ev.device] *= ev.factor
        return m


@dataclass
class DeviceState:
    temp: np.ndarray                      # (G,) °C
    freq: np.ndarray                      # (G,) GHz
    power: np.ndarray                     # (G,) W (last-interval average)
    cap: np.ndarray                       # (G,) W current power cap


class ThermalModel:
    """Vectorized physics for G devices."""

    def __init__(self, preset: DevicePreset, n_devices: int, seed: int = 0,
                 straggler_boost: float = 1.28,
                 churn: Optional[ChurnModel] = None):
        self.preset = preset
        self.G = n_devices
        self.churn = churn
        self.t_sim = 0.0                 # simulated operating time (churn)
        # fault-injection hook (repro.core.faults via ClusterSim): a
        # callable (G,)-multiplier source composed on top of churn — e.g.
        # thermal_runaway grows a device's r_th without bound.  None keeps
        # the physics bit-identical to a fault-free run.
        self.rth_fault = None
        rng = np.random.default_rng(seed)
        # cooling heterogeneity: smooth spread + one notably worse slot
        # (paper Fig 7 top node: a single persistent straggler; §VIII-C:
        # chassis placement and manufacturing jointly cause straggling)
        spread = rng.normal(0.0, preset.r_th_spread / 2, n_devices)
        spread = np.clip(spread, -preset.r_th_spread, preset.r_th_spread)
        self.r_th = preset.r_th_mean * (1.0 + spread)
        worst = int(rng.integers(n_devices))
        self.r_th[worst] *= straggler_boost
        self.straggler_hint = worst
        # silicon lottery: per-device base power coefficient M0 = P_active/f
        # at T_ref; effective M grows quadratically with temperature (leakage)
        self.m_coef = (0.81 * (preset.tdp - preset.p_idle) / preset.f_max
                       * (1.0 + rng.normal(0.0, preset.m_spread, n_devices)))

    def m_eff(self, temp: np.ndarray) -> np.ndarray:
        """Leakage-adjusted W/GHz: hotter silicon buys fewer GHz per watt."""
        dt = np.maximum(temp - self.preset.t_ref, 0.0)
        return self.m_coef * (1.0 + self.preset.leak_quad * dt * dt)

    def init_state(self) -> DeviceState:
        p = self.preset
        return DeviceState(
            temp=np.full(self.G, p.t_amb + 20.0),
            freq=np.full(self.G, p.f_max),
            power=np.full(self.G, p.p_idle),
            cap=np.full(self.G, p.tdp),
        )

    # ------------------------------------------------------------------ DVFS
    def governor_freq(self, state: DeviceState) -> np.ndarray:
        """f = min(f_max, power-cap limit, hard thermal safety limit).

        The cap limit uses the peak-phase intensity: the governor must keep
        GEMM-phase power under the cap, so sustainable f is set by
        (cap - idle) / (M_eff(T) * intensity) — this is why a hotter device
        under the *same* cap clocks lower (Lit Silicon's root cause) and why
        raising the straggler's cap buys frequency back (the mitigation).
        """
        p = self.preset
        budget = np.maximum(state.cap - p.p_idle, 1.0)
        f_cap = budget / (self.m_eff(state.temp) * p.intensity)
        over = np.maximum(state.temp - p.t_throttle, 0.0)
        f_hard = p.f_max * (1.0 - p.throttle_slope * over)
        return np.clip(np.minimum(f_cap, f_hard), p.f_min, p.f_max)

    def power_draw(self, state: DeviceState, util: np.ndarray) -> np.ndarray:
        """Average draw: waiting at collectives still burns near-peak power
        (the comm kernel keeps the device active) — the GPU-Red opportunity."""
        u_pow = 0.8 + 0.2 * np.clip(util, 0.0, 1.0)
        draw = (self.preset.p_idle
                + self.m_eff(state.temp) * state.freq * u_pow)
        return np.minimum(draw, state.cap)

    def effective_r_th(self) -> np.ndarray:
        """Per-device thermal resistance at the current simulated time —
        the static spread, any churn degradation accrued so far, and any
        injected fault (thermal runaway) multipliers."""
        r = self.r_th
        if self.churn is not None:
            r = r * self.churn.multipliers(self.t_sim, self.G)
        if self.rth_fault is not None:
            r = r * self.rth_fault()
        return r

    def step_thermal(self, state: DeviceState, power: np.ndarray,
                     dt: float) -> None:
        """First-order RC: dT/dt = (T_amb + R*P - T) / tau."""
        p = self.preset
        t_ss = p.t_amb + self.effective_r_th() * power
        a = 1.0 - np.exp(-dt / p.tau)
        state.temp = state.temp + a * (t_ss - state.temp)
        state.power = power

    def update(self, state: DeviceState, util: np.ndarray, dt: float) -> None:
        """One control-interval update: power from current f/util, thermal
        integration, then the governor picks next-interval frequencies."""
        power = self.power_draw(state, util)
        self.step_thermal(state, power, dt)
        state.freq = self.governor_freq(state)
        self.t_sim += dt
