"""The paper's primary contribution: Lit Silicon characterization, analytical
models, detection (Algorithm 1), mitigation (Algorithms 2+3) and the
node-level power-management layer, plus the calibrated thermal/DVFS/C3 node
simulator that stands in for device physics on this CPU-only container."""
from repro.core.backends import (ClusterSimBackend, NodeViewBackend,
                                 PowerBackend, SimBackend, TPUPlatformBackend)
from repro.core.c3sim import (C3Sim, IterationTrace, NodeSim, SimConfig,
                              workload_arrays)
from repro.core.cluster import ClusterConfig, ClusterSim, ring_allreduce_time
from repro.core.escalate import (DRAIN_MODES, STAGES, DrainDecision,
                                 EscalationConfig, EscalationEvent,
                                 EscalationPolicy, HealReport,
                                 run_healing_fleet)
from repro.core.faults import (FAULT_KINDS, LOST_DEVICE_RATE,
                               UNRECOVERABLE_KINDS, FaultEvent, FaultModel,
                               random_faults)
from repro.core.detect import (aggregate_lead, classify_overlap, cosine,
                               lead_value_detect, lead_values,
                               overlap_duration_correlation, pearson,
                               straggler_index)
from repro.core.manager import (USE_CASES, FleetManagerConfig,
                                FleetPowerManager, ManagerConfig,
                                PowerManager, run_closed_loop,
                                run_fleet_closed_loop)
from repro.core.mitigate import adj_power_node, inc_power_gpu
from repro.core.perf_model import PerfPrediction, predict_speedup, t_agg
from repro.core.power_model import PowerPrediction, predict_power
from repro.core.thermal import (MI300X_PRESET, PRESETS, V5E_PRESET,
                                DevicePreset, DeviceState, ThermalModel)
from repro.core.workload import (CommKernel, CompKernel, Workload,
                                 fsdp_llm_iteration)

__all__ = [
    "PowerBackend", "SimBackend", "TPUPlatformBackend", "ClusterSimBackend",
    "NodeViewBackend", "C3Sim", "IterationTrace", "NodeSim", "SimConfig",
    "workload_arrays", "ClusterConfig", "ClusterSim", "ring_allreduce_time",
    "FleetManagerConfig", "FleetPowerManager", "run_fleet_closed_loop",
    "aggregate_lead",
    "classify_overlap", "cosine", "lead_value_detect", "lead_values",
    "overlap_duration_correlation", "pearson", "straggler_index", "USE_CASES",
    "ManagerConfig", "PowerManager", "run_closed_loop", "adj_power_node",
    "inc_power_gpu", "PerfPrediction", "predict_speedup", "t_agg",
    "PowerPrediction", "predict_power", "MI300X_PRESET", "PRESETS",
    "V5E_PRESET", "DevicePreset", "DeviceState", "ThermalModel", "CommKernel",
    "CompKernel", "Workload", "fsdp_llm_iteration",
    "FAULT_KINDS", "UNRECOVERABLE_KINDS", "LOST_DEVICE_RATE", "FaultEvent",
    "FaultModel", "random_faults", "DRAIN_MODES", "STAGES", "DrainDecision",
    "EscalationConfig", "EscalationEvent", "EscalationPolicy", "HealReport",
    "run_healing_fleet",
]
