"""Power backends: how the manager reads telemetry and sets caps.

The manager is oblivious to the telemetry source — the same property that
makes the paper's 200-line solution deployable.  ``SimBackend`` drives the
calibrated node simulator (this CPU container); ``TPUPlatformBackend`` is the
real-hardware stub documenting the production integration points.
"""
from __future__ import annotations

from typing import List, Optional, Protocol

import numpy as np

from repro.core.c3sim import IterationTrace, NodeSim
from repro.core.cluster import ClusterSim


class PowerBackend(Protocol):
    n_devices: int
    tdp: float

    def run_iteration(self) -> IterationTrace: ...
    def set_power_caps(self, caps: np.ndarray) -> None: ...
    def get_power_caps(self) -> np.ndarray: ...
    def telemetry(self) -> dict: ...


class SimBackend:
    """Backend over the discrete-event node simulator.  ``collector``
    (a ``repro.telemetry.TelemetryCollector``) attaches to the node so
    every committed iteration is offered to the trace recorder."""

    def __init__(self, node: NodeSim, collector=None):
        self.node = node
        self.n_devices = node.G
        self.tdp = node.thermal.preset.tdp
        if collector is not None:
            collector.attach_node(node)

    def run_iteration(self) -> IterationTrace:
        return self.node.step()

    def set_power_caps(self, caps: np.ndarray) -> None:
        self.node.set_power_caps(caps)

    def get_power_caps(self) -> np.ndarray:
        return self.node.state.cap.copy()

    def telemetry(self) -> dict:
        s = self.node.state
        return {"temp": s.temp.copy(), "freq": s.freq.copy(),
                "power": s.power.copy(), "cap": s.cap.copy()}


class NodeViewBackend:
    """Per-node cap/telemetry view over a cluster — satisfies the parts of
    ``PowerBackend`` a `PowerManager` touches (caps + metadata), so the
    unmodified node-level controller runs against one node of a fleet."""

    def __init__(self, cluster: ClusterSim, node: int):
        self.cluster = cluster
        self.node = node
        self.n_devices = cluster.G
        self.tdp = cluster.presets[node].tdp

    def run_iteration(self) -> IterationTrace:
        raise NotImplementedError(
            "NodeViewBackend is cap/telemetry only; iterations are driven "
            "fleet-wide through ClusterSimBackend.run_iteration")

    def set_power_caps(self, caps: np.ndarray) -> None:
        self.cluster.set_node_caps(self.node, caps)

    def get_power_caps(self) -> np.ndarray:
        return self.cluster.get_node_caps(self.node)

    def telemetry(self) -> dict:
        s = self.cluster.nodes[self.node].state
        return {"temp": s.temp.copy(), "freq": s.freq.copy(),
                "power": s.power.copy(), "cap": s.cap.copy()}


class ClusterSimBackend:
    """Backend over the N-node cluster simulator.  ``run_iteration`` returns
    the per-node traces of one data-parallel step; per-node cap control is
    exposed through `NodeViewBackend` views."""

    def __init__(self, cluster: ClusterSim, collector=None):
        self.cluster = cluster
        self.n_nodes = cluster.N
        self.n_devices = cluster.G
        self.tdp = cluster.preset.tdp
        self.node_tdps = np.array([p.tdp for p in cluster.presets])
        self.node_views = [NodeViewBackend(cluster, n)
                           for n in range(cluster.N)]
        if collector is not None:
            collector.attach_cluster(cluster)

    def run_iteration(self) -> List[IterationTrace]:
        return self.cluster.step()

    def node_leads(self) -> Optional[np.ndarray]:
        """Topology-defined per-node lead signal of the last fleet step:
        barrier wait (DP), bubble time (PP), or exposed collective wait
        (TP).  The straggling node leads by ~0 under all three."""
        h = self.cluster.history
        return h[-1]["lead"] if h else None

    def set_power_caps(self, caps: np.ndarray) -> None:
        caps = np.asarray(caps, float).reshape(self.n_nodes, self.n_devices)
        for n in range(self.n_nodes):
            self.cluster.set_node_caps(n, caps[n])

    def get_power_caps(self) -> np.ndarray:
        return np.stack([self.cluster.get_node_caps(n)
                         for n in range(self.n_nodes)])

    def telemetry(self) -> dict:
        return {"nodes": [v.telemetry() for v in self.node_views],
                "t_fleet": (self.cluster.history[-1]["t_fleet"]
                            if self.cluster.history else None)}


class TPUPlatformBackend:
    """Production stub: on a real pod the three integration points are

      1. kernel-start timestamps  — from the TPU profiler (xplane) or a
         lightweight per-step host callback around each pjit'd step;
      2. power caps               — the platform power-management API
         (per-chip power envelopes; OCP-style short-term TDP exceedance is
         standardized, paper §VIII-B);
      3. telemetry                — chip temperature/frequency counters.

    Each host manages its local chips; aggregate lead vectors are reduced
    across hosts with one small allgather per sampling period (G floats).
    """

    def __init__(self, n_devices: int, tdp: float = 250.0):
        self.n_devices = n_devices
        self.tdp = tdp

    def run_iteration(self) -> IterationTrace:
        raise NotImplementedError(
            "TPUPlatformBackend requires real hardware; on this CPU "
            "container use SimBackend (see DESIGN.md §2)")

    set_power_caps = get_power_caps = telemetry = run_iteration
