"""Power backends: how the manager reads telemetry and sets caps.

The manager is oblivious to the telemetry source — the same property that
makes the paper's 200-line solution deployable.  ``SimBackend`` drives the
calibrated node simulator (this CPU container); ``TPUPlatformBackend`` is the
real-hardware stub documenting the production integration points.
"""
from __future__ import annotations

from typing import Optional, Protocol

import numpy as np

from repro.core.c3sim import IterationTrace, NodeSim


class PowerBackend(Protocol):
    n_devices: int
    tdp: float

    def run_iteration(self) -> IterationTrace: ...
    def set_power_caps(self, caps: np.ndarray) -> None: ...
    def get_power_caps(self) -> np.ndarray: ...
    def telemetry(self) -> dict: ...


class SimBackend:
    """Backend over the discrete-event node simulator."""

    def __init__(self, node: NodeSim):
        self.node = node
        self.n_devices = node.G
        self.tdp = node.thermal.preset.tdp

    def run_iteration(self) -> IterationTrace:
        return self.node.step()

    def set_power_caps(self, caps: np.ndarray) -> None:
        self.node.set_power_caps(caps)

    def get_power_caps(self) -> np.ndarray:
        return self.node.state.cap.copy()

    def telemetry(self) -> dict:
        s = self.node.state
        return {"temp": s.temp.copy(), "freq": s.freq.copy(),
                "power": s.power.copy(), "cap": s.cap.copy()}


class TPUPlatformBackend:
    """Production stub: on a real pod the three integration points are

      1. kernel-start timestamps  — from the TPU profiler (xplane) or a
         lightweight per-step host callback around each pjit'd step;
      2. power caps               — the platform power-management API
         (per-chip power envelopes; OCP-style short-term TDP exceedance is
         standardized, paper §VIII-B);
      3. telemetry                — chip temperature/frequency counters.

    Each host manages its local chips; aggregate lead vectors are reduced
    across hosts with one small allgather per sampling period (G floats).
    """

    def __init__(self, n_devices: int, tdp: float = 250.0):
        self.n_devices = n_devices
        self.tdp = tdp

    def run_iteration(self) -> IterationTrace:
        raise NotImplementedError(
            "TPUPlatformBackend requires real hardware; on this CPU "
            "container use SimBackend (see DESIGN.md §2)")

    set_power_caps = get_power_caps = telemetry = run_iteration
