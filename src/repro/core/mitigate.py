"""Mitigation of Lit Silicon — paper Algorithms 2 (INCPOWERGPU) and
3 (ADJPOWERNODE), vectorized.

Algorithm 2 turns an aggregate lead vector into per-device power-cap
increases: proportional to the device's normalized lead within the sample
(line 5) and damped by the largest lead ever seen (line 6, 'global' scale) so
adjustments shrink as convergence approaches.  Algorithm 3 projects the
requested caps onto the node power cap and TDP by uniform shifts.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np


def inc_power_gpu(lead: np.ndarray, max_inc: float, global_max: float,
                  scale: str = "global") -> Tuple[np.ndarray, float]:
    """Algorithm 2.  lead: (G,) aggregate lead values.

    Returns (I (G,) cap increases, updated global_max).
    scale='local' always uses max_inc (paper Table II: faster, more variance).
    """
    lead = np.asarray(lead, float)
    max_lead = float(lead.max())
    min_lead = float(lead.min())
    global_max = max(global_max, max_lead)
    span = max_lead - min_lead
    if span <= 0:
        norm_lead = np.ones_like(lead)      # no differentiation this sample
    else:
        norm_lead = 1.0 - (lead - min_lead) / span
    damp = (max_lead / global_max) if (scale == "global"
                                       and global_max > 0) else 1.0
    return norm_lead * damp * max_inc, global_max


def adj_power_node(inc: np.ndarray, caps: np.ndarray, tdp: float,
                   node_cap: float) -> np.ndarray:
    """Algorithm 3: apply increases, then uniform-shift to satisfy the node
    cap (line 5-8) and TDP (line 9-11)."""
    caps = np.asarray(caps, float) + np.asarray(inc, float)
    G = caps.shape[0]
    node_power = float(caps.sum())
    gpu_delta_max = math.ceil((node_power - node_cap) / G)
    caps = caps - gpu_delta_max
    gpu_delta = max(0.0, float((caps - tdp).max()))
    caps = caps - gpu_delta
    return caps
