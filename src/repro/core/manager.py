"""PowerManager: the paper's node-level power-management layer (§V).

Wires detection (Algorithm 1) and mitigation (Algorithms 2+3) into a running
training loop with the Table II knobs: sampling period, warm-up, window size,
aggregation, max adjustment, global/local scale — under one of three use
cases (Table I):

  GPU-Red      no node cap (node cap = G·TDP): leaders get capped down,
               straggler stays at TDP — power drops, throughput flat.
  GPU-Realloc  node cap below provisioned: straggler boosted, everyone
               shifted down uniformly — throughput up at equal node power.
  CPU-Slosh    node cap raised by idle-CPU budget sloshed to the devices —
               straggler boosted without capping leaders.

The converged cap distribution is reusable across runs (paper Fig 12): it
can be exported/imported, so detection is a one-time (or weekly) cost.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.backends import PowerBackend
from repro.core.c3sim import IterationTrace
from repro.core.detect import lead_value_detect
from repro.core.mitigate import adj_power_node, inc_power_gpu

USE_CASES = ("gpu-red", "gpu-realloc", "cpu-slosh")


@dataclass
class ManagerConfig:
    """Table II knobs (defaults = paper defaults)."""

    use_case: str = "gpu-red"
    sampling_period: int = 10          # sample 1 of every N iterations
    warmup: int = 50                   # samples before first adjustment
    window_size: int = 3               # samples averaged per adjustment
    aggregation: str = "sum"           # sum | max | last
    max_adjustment: float = 15.0       # W, Algorithm 2 max_inc
    scale: str = "global"              # global | local
    power_cap: float = 700.0           # per-GPU initial cap (Realloc/Slosh)
    cpu_budget: float = 20.0           # W per GPU sloshable (CPU-Slosh)
    convergence_freeze: bool = True    # disable after caps stabilize (§V:
    freeze_tol_w: float = 2.5          #   one-time profiling cost)
    freeze_window: int = 3

    def node_cap(self, n_devices: int, tdp: float) -> float:
        if self.use_case == "gpu-red":
            return n_devices * tdp
        if self.use_case == "gpu-realloc":
            return n_devices * self.power_cap
        if self.use_case == "cpu-slosh":
            return n_devices * (self.power_cap + self.cpu_budget)
        raise ValueError(f"unknown use case {self.use_case!r}")

    def initial_caps(self, n_devices: int, tdp: float) -> np.ndarray:
        base = tdp if self.use_case == "gpu-red" else self.power_cap
        return np.full(n_devices, float(base))


class PowerManager:
    """Continuous measure-and-correct controller (paper Fig 8)."""

    def __init__(self, backend: PowerBackend, cfg: ManagerConfig):
        self.backend = backend
        self.cfg = cfg
        self.G = backend.n_devices
        self.tdp = backend.tdp
        self.global_max = 0.0
        self.samples_seen = 0
        self.window: List[np.ndarray] = []
        self.lead_log: List[np.ndarray] = []
        self.adjust_log: List[np.ndarray] = []
        self.enabled = True
        backend.set_power_caps(cfg.initial_caps(self.G, self.tdp))

    # ----------------------------------------------------------------- hook
    def on_iteration(self, iteration: int,
                     trace: Optional[IterationTrace]) -> None:
        """Training-loop hook: called every iteration with the trace when
        this iteration was sampled (else None)."""
        if not self.enabled or trace is None:
            return
        if iteration % self.cfg.sampling_period:
            return
        lead = lead_value_detect(trace.comp_start, self.cfg.aggregation)
        self.lead_log.append(lead)
        self.samples_seen += 1
        if self.samples_seen <= self.cfg.warmup:
            return
        self.window.append(lead)
        if len(self.window) < self.cfg.window_size:
            return
        avg_lead = np.mean(self.window, axis=0)
        self.window.clear()
        self.adjust(avg_lead)

    def adjust(self, lead: np.ndarray) -> np.ndarray:
        """One Algorithm-2 + Algorithm-3 correction."""
        inc, self.global_max = inc_power_gpu(
            lead, self.cfg.max_adjustment, self.global_max, self.cfg.scale)
        caps = adj_power_node(inc, self.backend.get_power_caps(), self.tdp,
                              self.cfg.node_cap(self.G, self.tdp))
        self.backend.set_power_caps(caps)
        self.adjust_log.append(caps.copy())
        # one-time profiling: freeze once the cap distribution stabilizes
        w = self.cfg.freeze_window
        if (self.cfg.convergence_freeze and len(self.adjust_log) > w):
            recent = np.stack(self.adjust_log[-(w + 1):])
            if np.abs(np.diff(recent, axis=0)).max() < self.cfg.freeze_tol_w:
                self.enabled = False
        return caps

    # ------------------------------------------------------ cap persistence
    def export_caps(self, path: str) -> None:
        """Converged caps are reusable across workloads/knobs (Fig 12)."""
        caps = self.backend.get_power_caps()
        with open(path, "w") as f:
            json.dump({"use_case": self.cfg.use_case,
                       "caps": caps.tolist()}, f)

    def import_caps(self, path: str) -> None:
        with open(path) as f:
            data = json.load(f)
        self.backend.set_power_caps(np.asarray(data["caps"], float))
        self.enabled = False               # one-time profiling cost amortized


def run_closed_loop(backend: PowerBackend, cfg: ManagerConfig,
                    iterations: int, tune_after: Optional[int] = None):
    """Convenience driver: run `iterations`, tuning from `tune_after` on
    (default: halfway, as in paper Fig 9).  Returns (manager, history)."""
    mgr = PowerManager(backend, cfg)
    tune_after = iterations // 2 if tune_after is None else tune_after
    mgr.enabled = False
    for i in range(iterations):
        if i == tune_after:
            mgr.enabled = True
        trace = backend.run_iteration()
        mgr.on_iteration(i, trace)
    return mgr
