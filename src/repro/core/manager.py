"""PowerManager: the paper's node-level power-management layer (§V).

Wires detection (Algorithm 1) and mitigation (Algorithms 2+3) into a running
training loop with the Table II knobs: sampling period, warm-up, window size,
aggregation, max adjustment, global/local scale — under one of three use
cases (Table I):

  GPU-Red      no node cap (node cap = G·TDP): leaders get capped down,
               straggler stays at TDP — power drops, throughput flat.
  GPU-Realloc  node cap below provisioned: straggler boosted, everyone
               shifted down uniformly — throughput up at equal node power.
  CPU-Slosh    node cap raised by idle-CPU budget sloshed to the devices —
               straggler boosted without capping leaders.

The converged cap distribution is reusable across runs (paper Fig 12): it
can be exported/imported, so detection is a one-time (or weekly) cost.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.backends import PowerBackend
from repro.core.c3sim import IterationTrace
from repro.core.detect import lead_value_detect
from repro.core.mitigate import adj_power_node, inc_power_gpu

USE_CASES = ("gpu-red", "gpu-realloc", "cpu-slosh")


@dataclass
class ManagerConfig:
    """Table II knobs (defaults = paper defaults)."""

    use_case: str = "gpu-red"
    sampling_period: int = 10          # sample 1 of every N iterations
    warmup: int = 50                   # samples before first adjustment
    window_size: int = 3               # samples averaged per adjustment
    aggregation: str = "sum"           # sum | max | last
    max_adjustment: float = 15.0       # W, Algorithm 2 max_inc
    scale: str = "global"              # global | local
    power_cap: float = 700.0           # per-GPU initial cap (Realloc/Slosh)
    cpu_budget: float = 20.0           # W per GPU sloshable (CPU-Slosh)
    convergence_freeze: bool = True    # disable after caps stabilize (§V:
    freeze_tol_w: float = 2.5          #   one-time profiling cost)
    freeze_window: int = 3
    node_cap_override: Optional[float] = None  # W: a fleet controller sets
    #                                    this to the node's current budget

    def node_cap(self, n_devices: int, tdp: float) -> float:
        if self.node_cap_override is not None:
            return self.node_cap_override
        if self.use_case == "gpu-red":
            return n_devices * tdp
        if self.use_case == "gpu-realloc":
            return n_devices * self.power_cap
        if self.use_case == "cpu-slosh":
            return n_devices * (self.power_cap + self.cpu_budget)
        raise ValueError(f"unknown use case {self.use_case!r}")

    def initial_caps(self, n_devices: int, tdp: float) -> np.ndarray:
        base = tdp if self.use_case == "gpu-red" else self.power_cap
        return np.full(n_devices, float(base))


class PowerManager:
    """Continuous measure-and-correct controller (paper Fig 8).

    Two telemetry paths:

      * **oracle** (default, ``sensor=None``): the exact kernel-start
        matrix the simulator produced, sampled every
        ``cfg.sampling_period`` iterations — arithmetic unchanged since
        the first version of this layer;
      * **sensor-backed** (``sensor=SensorModel(...)``): starts are
        observed through a noisy/quantized/dropping sensor, and the
        *sensor's* ``sample_period``/``phase_jitter`` decide which
        iterations yield a reading — what a deployment consuming
        rocm-smi-style counters sees.  A lossless sensor with
        ``sample_period == cfg.sampling_period`` reproduces the oracle
        path bit-for-bit.

    ``collector``, when given, records every applied cap vector as a
    ``ManagerAction`` so traces carry the mitigation decisions alongside
    the signals that caused them.
    """

    def __init__(self, backend: PowerBackend, cfg: ManagerConfig,
                 sensor=None, collector=None, collector_node: int = 0):
        self.backend = backend
        self.cfg = cfg
        self.sensor = sensor
        self.collector = collector
        self.collector_node = collector_node   # which node actions name
        self.G = backend.n_devices
        self.tdp = backend.tdp
        self.global_max = 0.0
        self.samples_seen = 0
        self.window: List[np.ndarray] = []
        self.lead_log: List[np.ndarray] = []
        self.adjust_log: List[np.ndarray] = []
        self.enabled = True
        self._last_iteration = -1
        backend.set_power_caps(cfg.initial_caps(self.G, self.tdp))

    # ----------------------------------------------------------------- hook
    def on_iteration(self, iteration: int,
                     trace: Optional[IterationTrace]) -> None:
        """Training-loop hook: called every iteration with the trace when
        this iteration was sampled (else None)."""
        if not self.enabled or trace is None:
            return
        if self.sensor is not None:
            if not self.sensor.take_sample(iteration):
                return
            start = self.sensor.observe_starts(trace.comp_start)
        else:
            if iteration % self.cfg.sampling_period:
                return
            start = trace.comp_start
        self._last_iteration = iteration
        lead = lead_value_detect(start, self.cfg.aggregation)
        self.lead_log.append(lead)
        self.samples_seen += 1
        if self.samples_seen <= self.cfg.warmup:
            return
        self.window.append(lead)
        if len(self.window) < self.cfg.window_size:
            return
        avg_lead = np.mean(self.window, axis=0)
        self.window.clear()
        self.adjust(avg_lead)

    def adjust(self, lead: np.ndarray) -> np.ndarray:
        """One Algorithm-2 + Algorithm-3 correction."""
        inc, self.global_max = inc_power_gpu(
            lead, self.cfg.max_adjustment, self.global_max, self.cfg.scale)
        caps = adj_power_node(inc, self.backend.get_power_caps(), self.tdp,
                              self.cfg.node_cap(self.G, self.tdp))
        self.backend.set_power_caps(caps)
        self.adjust_log.append(caps.copy())
        if self.collector is not None:
            self.collector.on_manager_action("caps", self._last_iteration,
                                             caps, node=self.collector_node)
        # one-time profiling: freeze once the cap distribution stabilizes
        w = self.cfg.freeze_window
        if (self.cfg.convergence_freeze and len(self.adjust_log) > w):
            recent = np.stack(self.adjust_log[-(w + 1):])
            if np.abs(np.diff(recent, axis=0)).max() < self.cfg.freeze_tol_w:
                self.enabled = False
        return caps

    # ------------------------------------------------------ cap persistence
    def export_caps(self, path: str) -> None:
        """Converged caps are reusable across workloads/knobs (Fig 12)."""
        caps = self.backend.get_power_caps()
        with open(path, "w") as f:
            json.dump({"use_case": self.cfg.use_case,
                       "caps": caps.tolist()}, f,
                      sort_keys=True, allow_nan=False)

    def import_caps(self, path: str) -> None:
        with open(path) as f:
            data = json.load(f)
        self.backend.set_power_caps(np.asarray(data["caps"], float))
        self.enabled = False               # one-time profiling cost amortized


OBJECTIVES = ("throughput", "tail-latency")


@dataclass
class FleetManagerConfig(ManagerConfig):
    """Cluster-level knobs on top of the Table II node knobs."""

    cluster_power_budget: Optional[float] = None  # W total; default
    #                                               n_nodes * node_cap
    node_window_size: int = 3          # fleet samples per node adjustment
    max_node_adjustment: float = 60.0  # W of node-budget shift per step
    node_scale: str = "global"         # damping for the node-level Alg. 2
    # ------------------------------------------------- serving objective
    objective: str = "throughput"      # "throughput": lead = barrier wait /
    #                                    topology signal (the paper's
    #                                    objective — equalize node speed);
    #                                    "tail-latency": lead from the
    #                                    serving tail signal, so budget
    #                                    chases the node dominating p99
    #                                    TTFT (serve/* scenarios only)
    tail_quantile: float = 0.95        # quantile of the recent-TTFT window
    tail_window_s: float = 10.0        # s of completed TTFTs per node in the
    #                                    window (time-based: count-based
    #                                    windows go stale at low per-node
    #                                    completion rates and chase ghosts)
    tail_target_s: float = 0.0         # act only while the worst node's
    #                                    tail signal exceeds this (0: always
    #                                    act); scenarios set it to the TTFT
    #                                    deadline so a healthy fleet keeps
    #                                    its allocation instead of chasing
    #                                    sub-deadline quantile noise


class FleetPowerManager:
    """Hierarchical Lit Silicon control for an N-node fleet.

    Two nested instances of the paper's detect→mitigate loop:

      * per node, an unmodified `PowerManager` runs Algorithms 1-3 over that
        node's kernel-start traces, within the node's current power budget;
      * across nodes, the *same* Algorithms 2+3 run at node granularity over
        the **topology-defined lead signal** (`ClusterSimBackend.node_leads`):
        barrier wait under data parallelism, bubble time under pipeline
        parallelism, exposed collective wait under tensor parallelism.  The
        straggling node has lead ~0 under all three and receives budget
        sloshed from the waiting nodes, projected onto the cluster budget.

    Heterogeneous fleets are supported: per-node TDPs (mixed presets) bound
    each node's budget and floor individually; the initial budget split is
    proportional to each node's provisioned cap.

    The node-level loop needs only one scalar per node per sample, i.e. the
    same O(small allgather) telemetry cost the paper's §VIII-B deployment
    sketch budgets for.
    """

    def __init__(self, backend, cfg: FleetManagerConfig, collector=None):
        if not hasattr(backend, "node_views"):
            raise TypeError("FleetPowerManager needs a cluster backend "
                            "exposing per-node views (ClusterSimBackend)")
        if cfg.objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {cfg.objective!r} "
                             f"(expected one of {OBJECTIVES})")
        self.backend = backend
        self.cfg = cfg
        self.collector = collector
        self._last_iteration = -1
        self.N = backend.n_nodes
        self.G = backend.n_devices
        self.tdp = backend.tdp
        self.node_tdps = np.asarray(
            getattr(backend, "node_tdps", np.full(self.N, self.tdp)), float)
        per_node_caps = np.array([cfg.node_cap(self.G, t)
                                  for t in self.node_tdps])
        self.cluster_budget = (cfg.cluster_power_budget
                               if cfg.cluster_power_budget is not None
                               else float(per_node_caps.sum()))
        # initial split proportional to each node's provisioned cap
        # (uniform when the fleet is homogeneous)
        self.node_budgets = (per_node_caps * self.cluster_budget
                             / per_node_caps.sum())
        self.node_cfgs = [dataclasses.replace(
            cfg, node_cap_override=float(b)) for b in self.node_budgets]
        self.managers = [
            PowerManager(v, c, collector=collector, collector_node=n)
            for n, (v, c) in enumerate(zip(backend.node_views,
                                           self.node_cfgs))]
        self.node_global_max = 0.0
        self.samples_seen = 0
        self.lead_window: List[np.ndarray] = []
        self.budget_log: List[np.ndarray] = []

    # ----------------------------------------------------------------- hook
    def on_iteration(self, iteration: int,
                     traces: Optional[List[IterationTrace]]) -> None:
        if traces is None:
            return
        self._last_iteration = iteration
        for mgr, tr in zip(self.managers, traces):
            mgr.on_iteration(iteration, tr)
        if iteration % self.cfg.sampling_period:
            return
        lead = None
        if hasattr(self.backend, "node_leads"):
            lead = self.backend.node_leads()
        if lead is None:       # non-topology backend: barrier-wait fallback
            t_local = np.array([tr.t_iter for tr in traces])
            lead = t_local.max() - t_local
        self.samples_seen += 1
        if self.samples_seen <= self.cfg.warmup:
            return
        self.lead_window.append(np.asarray(lead, float))
        if len(self.lead_window) < self.cfg.node_window_size:
            return
        lead_avg = np.mean(self.lead_window, axis=0)
        self.lead_window.clear()
        self._adjust_from_lead(lead_avg)

    def on_serve_iteration(self, iteration: int,
                           traces: Optional[List[IterationTrace]],
                           tail_signal=None) -> None:
        """Serving-loop hook (`ServingFleet.run`): same cadence and nested
        per-node Algorithm-1 loops as `on_iteration`, but the node-level
        lead comes from the configured *objective*:

          * ``"throughput"`` — barrier-wait over local iteration times
            (max(t) - t), the paper's equalize-node-speed signal;
          * ``"tail-latency"`` — the serving tail signal (per-node recent
            p99 TTFT / head-of-line age, computed by the serving engine):
            the node *dominating the latency tail* leads by ~0 and
            receives the budget, even past the point of speed equality —
            a backlogged node must run faster than its peers to drain.
        """
        if traces is None:
            return
        self._last_iteration = iteration
        for mgr, tr in zip(self.managers, traces):
            mgr.on_iteration(iteration, tr)
        if iteration % self.cfg.sampling_period:
            return
        if self.cfg.objective == "tail-latency" and tail_signal is not None:
            sig = np.asarray(tail_signal, float)
            if sig.max() < self.cfg.tail_target_s:
                # tails within target fleet-wide: hold the allocation
                # rather than chase quantile noise between healthy nodes
                self.lead_window.clear()
                return
        else:
            sig = np.array([tr.t_iter for tr in traces])
        lead = sig.max() - sig
        self.samples_seen += 1
        if self.samples_seen <= self.cfg.warmup:
            return
        self.lead_window.append(np.asarray(lead, float))
        if len(self.lead_window) < self.cfg.node_window_size:
            return
        lead_avg = np.mean(self.lead_window, axis=0)
        self.lead_window.clear()
        self._adjust_from_lead(lead_avg)

    def import_budgets(self, budgets) -> np.ndarray:
        """Warm-start the node-budget split from external state (e.g. a
        checkpoint restored after an elastic restart): the given per-node
        budgets are projected onto this fleet's cluster budget and pushed
        into the nested per-node managers, so the survivors resume with
        their converged mitigation instead of re-learning it."""
        b = np.asarray(budgets, float).copy()
        if b.shape != (self.N,):
            raise ValueError(f"expected {self.N} node budgets, "
                             f"got shape {b.shape}")
        if b.sum() > 0:
            b *= self.cluster_budget / b.sum()
        self.node_budgets = b
        for n, mgr in enumerate(self.managers):
            mgr.cfg.node_cap_override = float(b[n])
        return b

    def adjust_node_budgets(self, t_local: np.ndarray) -> np.ndarray:
        """Direct-drive entry point from per-node iteration times: the
        barrier-wait lead (data-parallel semantics).  The closed loop goes
        through `_adjust_from_lead` with the topology's own signal."""
        t_local = np.asarray(t_local, float)
        return self._adjust_from_lead(t_local.max() - t_local)

    def _adjust_from_lead(self, lead: np.ndarray) -> np.ndarray:
        """Algorithms 2+3 at node granularity over the lead signal
        (the straggling node leads by ~0)."""
        inc, self.node_global_max = inc_power_gpu(
            lead, self.cfg.max_node_adjustment, self.node_global_max,
            self.cfg.node_scale)
        budgets = adj_power_node(inc, self.node_budgets,
                                 tdp=self.G * float(self.node_tdps.max()),
                                 node_cap=self.cluster_budget)
        # heterogeneous fleets: each node is individually bound by its own
        # provisioned silicon (no-op when all presets match)
        budgets = np.minimum(budgets, self.G * self.node_tdps)
        floor = self.G * self.node_tdps * 0.25
        budgets = np.maximum(budgets, floor)
        # flooring after the projection can overshoot the cluster budget:
        # claw the excess back from nodes with headroom above the floor
        excess = budgets.sum() - self.cluster_budget
        if excess > 0:
            headroom = budgets - floor
            total = headroom.sum()
            if total > 0:
                budgets -= headroom * min(1.0, excess / total)
        # ... and the TDP clip can strand watts *below* it: a straggler
        # pinned at its silicon bound keeps requesting budget the clip
        # discards while the uniform shift already took it from the other
        # nodes, bleeding total budget every cycle.  Hand the shortfall
        # back to nodes with headroom so the projection lands on the
        # budget simplex, not under it.
        deficit = self.cluster_budget - budgets.sum()
        if deficit > 0:
            headroom = self.G * self.node_tdps - budgets
            total = headroom.sum()
            if total > 0:
                budgets += headroom * min(1.0, deficit / total)
        self.node_budgets = budgets
        self.budget_log.append(budgets.copy())
        if self.collector is not None:
            self.collector.on_manager_action("budgets", self._last_iteration,
                                             budgets)
        for n, mgr in enumerate(self.managers):
            if abs(mgr.cfg.node_cap_override - budgets[n]) > 1e-6:
                mgr.cfg.node_cap_override = float(budgets[n])
                mgr.enabled = True      # budget moved: resume adaptation
        return budgets


def run_fleet_closed_loop(backend, cfg: FleetManagerConfig, iterations: int,
                          tune_after: Optional[int] = None, collector=None):
    """Cluster counterpart of `run_closed_loop`: run `iterations` fleet
    steps, enabling hierarchical tuning from `tune_after` (default
    halfway).  Returns the FleetPowerManager."""
    mgr = FleetPowerManager(backend, cfg, collector=collector)
    tune_after = iterations // 2 if tune_after is None else tune_after
    enabled = False
    for i in range(iterations):
        if i == tune_after:
            enabled = True
        traces = backend.run_iteration()
        if enabled:
            mgr.on_iteration(i, traces)
    return mgr


def run_closed_loop(backend: PowerBackend, cfg: ManagerConfig,
                    iterations: int, tune_after: Optional[int] = None,
                    sensor=None, collector=None):
    """Convenience driver: run `iterations`, tuning from `tune_after` on
    (default: halfway, as in paper Fig 9).  Returns the PowerManager (the
    node's history lives on ``backend.node.history``).  ``sensor``/
    ``collector`` flow into the ``PowerManager`` (telemetry-backed
    detection / action recording); defaults leave the oracle path
    untouched."""
    mgr = PowerManager(backend, cfg, sensor=sensor, collector=collector)
    tune_after = iterations // 2 if tune_after is None else tune_after
    mgr.enabled = False
    for i in range(iterations):
        if i == tune_after:
            mgr.enabled = True
        trace = backend.run_iteration()
        mgr.on_iteration(i, trace)
    return mgr
