"""Detection of Lit Silicon — paper Algorithm 1 (LEADVALUEDETECT) plus the
straggler-wave / overlap-ratio analyses of §III (Figs 3, 4, 6, 7).

Lead value of device g on kernel k = (latest start among devices) - (g's
start): the straggler trends to 0, leaders accumulate lead until collectives
clamp them (equilibrium).  Aggregation: sum (area under the wave — default,
penalizes devices while in equilibrium), max, or last (paper Table II).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def lead_values(start: np.ndarray) -> np.ndarray:
    """Algorithm 1 lines 1-4.  start: (G, K) kernel-start timestamps.

    Returns lead_value: (G, K).  NaN starts (never-ran kernels, or readings
    a lossy telemetry sensor dropped) -> 0 lead; an all-NaN kernel column
    (no device reported it) is 0 lead everywhere rather than a warning —
    noisy sensor streams hit this case routinely.
    """
    t = np.asarray(start, float)
    finite = np.isfinite(t)
    t_max = np.where(finite, t, -np.inf).max(axis=0, keepdims=True)
    return np.where(finite & np.isfinite(t_max), t_max - t, 0.0)


def aggregate_lead(lead: np.ndarray, mode: str = "sum") -> np.ndarray:
    """Algorithm 1 lines 5-6 (plus the paper's max/last alternatives)."""
    if mode == "sum":
        return lead.sum(axis=1)
    if mode == "max":
        return lead.max(axis=1)
    if mode == "last":
        return lead[:, -1]
    raise ValueError(f"unknown aggregation {mode!r}")


def lead_value_detect(start: np.ndarray, mode: str = "sum") -> np.ndarray:
    """Full Algorithm 1: (G, K) starts -> (G,) aggregate lead vector."""
    return aggregate_lead(lead_values(start), mode)


def straggler_index(start: np.ndarray, mode: str = "sum") -> int:
    """The straggler has the smallest aggregate lead (~0: everyone waits)."""
    return int(np.argmin(lead_value_detect(start, mode)))


# --------------------------------------------------------------------------- #
# §III characterization statistics
# --------------------------------------------------------------------------- #
def overlap_spread(overlap_ratio: np.ndarray) -> np.ndarray:
    """(G, K) per-kernel overlap ratios -> (K,) max-min spread across GPUs."""
    return overlap_ratio.max(axis=0) - overlap_ratio.min(axis=0)


def classify_overlap(overlap_ratio: np.ndarray,
                     tol: float = 0.15) -> np.ndarray:
    """Split kernels into constant (C) vs varying (V) overlap sets (§IV-A).

    Returns bool (K,): True -> constant overlap (spread < tol across GPUs).
    """
    return overlap_spread(overlap_ratio) < tol


def pearson(a: np.ndarray, b: np.ndarray) -> float:
    a = a - a.mean()
    b = b - b.mean()
    d = np.sqrt((a * a).sum() * (b * b).sum())
    return float((a * b).sum() / d) if d > 0 else 0.0


def cosine(a: np.ndarray, b: np.ndarray) -> float:
    d = np.linalg.norm(a) * np.linalg.norm(b)
    return float(np.dot(a, b) / d) if d > 0 else 0.0


def overlap_duration_correlation(overlap_ratio: np.ndarray,
                                 dur: np.ndarray) -> Tuple[float, float]:
    """Fig 4: correlation between a kernel's overlap ratio and its duration
    across GPUs×samples.  Returns (pearson, cosine)."""
    o = overlap_ratio.ravel()
    d = dur.ravel()
    return pearson(o, d), cosine(o, d)
