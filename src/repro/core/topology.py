"""Inter-node topologies: how parallelism strategy couples nodes to a
straggler.

The paper's node-level observation — one hot GPU stalls its peers through
concurrent execution — aggregates to the cluster through whatever dependency
structure the parallelism strategy imposes.  A `Topology` maps the per-node
local iteration times (from each node's `IterationTrace`) plus a link model
onto (a) the fleet iteration time, (b) a per-node *lead signal* the
hierarchical power manager consumes, and (c) whether inter-node waiting is
*active* (burns near-peak power inside collective kernels) or *idle* (the
device parks at a barrier and cools):

  DataParallel      ring all-reduce on the slow fabric + a global barrier.
                    Every node stretches to the slowest; waits are idle.
                    Lead = barrier wait.  (The paper's case, preserved
                    bit-for-bit from the original `ClusterSim`.)

  PipelineParallel  stage-to-stage point-to-point dependencies.  A hot stage
                    bubbles the pipeline, but the sum/M fill-drain term
                    dilutes its impact — strictly *weaker* coupling than the
                    barrier case, which upper-bounds it.  Lead = bubble
                    (idle) time per stage.

  TensorParallel    per-layer all-gather/reduce-scatter on the fast link:
                    many sync points per iteration expose per-segment jitter
                    (sum of per-segment maxima >= max of sums) and the waits
                    happen *inside* collective kernels at near-peak power,
                    heating the waiters — strictly *tighter* coupling than
                    the barrier case.  Lead = exposed collective wait.

"Characterizing the Efficiency of Distributed Training" (PAPERS.md) measures
exactly this strategy-dependence of thermal/power behavior on real fleets.
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


def ring_allreduce_time(payload_bytes: float, n_nodes: int,
                        gbps: float) -> float:
    """Bandwidth term of a ring all-reduce: 2(N-1)/N chunks over the link."""
    if n_nodes <= 1 or payload_bytes <= 0:
        return 0.0
    return 2.0 * (n_nodes - 1) / n_nodes * payload_bytes / (gbps * 1e9)


def p2p_time(payload_bytes: float, gbps: float) -> float:
    """One point-to-point activation/grad transfer between adjacent stages."""
    if payload_bytes <= 0:
        return 0.0
    return payload_bytes / (gbps * 1e9)


@dataclass
class FleetStep:
    """One topology-resolved fleet iteration."""

    t_fleet: float                  # wall-clock of the coupled iteration
    lead: np.ndarray                # (N,) per-node lead signal (straggler ~0)
    comm_time: float                # exposed inter-node communication time
    info: Dict[str, float] = field(default_factory=dict)


class Topology(ABC):
    """Maps per-node local iteration times onto the fleet dependency
    structure.  Subclasses define `step`; `wait_active` tells the cluster
    whether inter-node waits keep devices hot (inside collective kernels)
    or let them idle and cool (barrier/bubble)."""

    name: str = "abstract"
    wait_active: bool = False       # True: waits burn near-peak power

    def __init__(self, n_nodes: int):
        self.N = int(n_nodes)

    @abstractmethod
    def step(self, t_local: np.ndarray) -> FleetStep:
        """Resolve one fleet iteration from (N,) local iteration times."""


class DataParallel(Topology):
    """Ring all-reduce over the slow inter-node fabric + global barrier.

    t_fleet = max(t_local) + allreduce; lead = barrier wait.  This is the
    original `ClusterSim` arithmetic, preserved bit-for-bit.
    """

    name = "dp"

    def __init__(self, n_nodes: int, grad_bytes: float, gbps: float):
        super().__init__(n_nodes)
        self.grad_bytes = float(grad_bytes)
        self.gbps = float(gbps)

    def comm_time(self) -> float:
        return ring_allreduce_time(self.grad_bytes, self.N, self.gbps)

    def step(self, t_local: np.ndarray) -> FleetStep:
        t_local = np.asarray(t_local, float)
        t_ar = self.comm_time()
        t_fleet = float(t_local.max()) + t_ar
        lead = t_local.max() - t_local          # barrier wait; straggler ~0
        return FleetStep(t_fleet, lead, t_ar)


class PipelineParallel(Topology):
    """N pipeline stages (one per node), M microbatches, 1F1B steady state.

    Per-microbatch stage time is t_local/M; the iteration takes the fill
    (sum of stage times) plus (M-1) beats of the slowest stage plus the
    exposed fill/drain point-to-point transfers:

        t_fleet = sum_s(t_s)/M + (M-1)/M * max_s(t_s) + p2p

    One hot stage only adds ~M/(N+M-1) of its excess to the fleet relative
    iteration time — the barrier (DP) case upper-bounds this coupling.  A
    stage's bubble (idle) time t_fleet - t_s is the lead signal: the hot
    stage has the least bubble, its downstream peers the most.
    """

    name = "pp"

    def __init__(self, n_nodes: int, act_bytes: float, gbps: float,
                 microbatches: int = 8):
        super().__init__(n_nodes)
        self.act_bytes = float(act_bytes)
        self.gbps = float(gbps)
        self.M = max(1, int(microbatches))

    def comm_time(self) -> float:
        # fill + drain: one fwd activation and one bwd grad hop per stage
        # boundary is exposed outside steady state
        return 2.0 * (self.N - 1) * p2p_time(self.act_bytes, self.gbps)

    def step(self, t_local: np.ndarray) -> FleetStep:
        t_local = np.asarray(t_local, float)
        tau = t_local / self.M
        t_compute = float(tau.sum() + (self.M - 1) * tau.max())
        t_fleet = t_compute + self.comm_time()
        lead = t_fleet - t_local                # bubble time; straggler min
        return FleetStep(t_fleet, lead, self.comm_time())


class TensorParallel(Topology):
    """Per-layer all-gather/reduce-scatter on the fast link.

    The iteration is cut into `n_syncs` segments, one per collective; every
    sync is a fleet-wide rendezvous, so the compute term is the *sum of
    per-segment maxima* — at least max(t_local), and strictly more under
    per-segment jitter (sum-of-maxes >= max-of-sums).  Two effects make
    this the tightest coupling of the three:

      * the collectives start *staggered* — there is no barrier in front of
        them, so a bandwidth-bound ring collective is gated on the latest
        rank at every chunk hop and its duration stretches by the arrival
        skew (`skew_cost` * (max - min) per sync).  DP pays the skew once,
        at the single barrier; TP pays it at every layer.
      * the waits happen inside collective kernels at near-peak power
        (`wait_active`): waiters heat up, throttle, and converge toward the
        straggler instead of cooling at a barrier.

    Collective payloads ride the fast TP link, so the constant bandwidth
    overhead itself is small.  Lead = exposed collective wait
    sum_k(max_j seg_jk - seg_ik); the straggler waits ~0.
    """

    name = "tp"
    wait_active = True

    def __init__(self, n_nodes: int, sync_bytes: float, gbps: float,
                 n_syncs: int = 16, jitter: float = 0.01,
                 skew_cost: float = 1.0, seed: int = 0):
        super().__init__(n_nodes)
        self.sync_bytes = float(sync_bytes)
        self.gbps = float(gbps)
        self.K = max(1, int(n_syncs))
        self.jitter = float(jitter)
        self.skew_cost = float(skew_cost)
        self.rng = np.random.default_rng(seed + 15485863)

    def comm_time(self) -> float:
        # AG + RS per sync point on the fast link
        return self.K * ring_allreduce_time(self.sync_bytes, self.N,
                                            self.gbps)

    def step(self, t_local: np.ndarray) -> FleetStep:
        t_local = np.asarray(t_local, float)
        N, K = self.N, self.K
        if self.jitter > 0 and N > 1:
            w = np.exp(self.rng.normal(0.0, self.jitter, (N, K)))
            w /= w.sum(axis=1, keepdims=True)   # rows sum to 1 exactly
        else:
            w = np.full((N, K), 1.0 / K)
        seg = t_local[:, None] * w              # (N, K) per-segment times
        seg_max = seg.max(axis=0)
        t_compute = float(seg_max.sum())
        t_skew = (self.skew_cost * float((seg_max - seg.min(axis=0)).sum())
                  if N > 1 else 0.0)
        t_fleet = t_compute + t_skew + self.comm_time()
        lead = (seg_max[None, :] - seg).sum(axis=1)  # exposed wait
        return FleetStep(t_fleet, lead, self.comm_time(),
                         info={"t_skew": t_skew})


TOPOLOGIES = {"dp": DataParallel, "pp": PipelineParallel,
              "tp": TensorParallel}


def make_topology(cfg, n_nodes: int, workload, grad_bytes: float,
                  seed: int = 0) -> Topology:
    """Build the topology named by ``cfg.topology`` from a `ClusterConfig`
    (duck-typed) and the workload's payload hints.

    Payload defaults: PP point-to-point and TP per-sync payloads are the
    per-layer activation size when the workload records it
    (`Workload.act_bytes`), else a grad_bytes-derived fallback; TP sync
    count defaults to 2 per layer (forward AG + backward RS).
    """
    kind = getattr(cfg, "topology", "dp")
    act = getattr(cfg, "act_bytes", None)
    if act is None:
        act = getattr(workload, "act_bytes", 0.0) or grad_bytes / 8.0
    if kind == "dp":
        return DataParallel(n_nodes, grad_bytes, cfg.inter_node_gbps)
    if kind == "pp":
        return PipelineParallel(n_nodes, act, cfg.inter_node_gbps,
                                microbatches=cfg.microbatches)
    if kind == "tp":
        syncs = cfg.tp_syncs
        if syncs is None:
            n_layers = getattr(workload, "n_layers", 0)
            syncs = 2 * n_layers if n_layers else max(1, len(workload.comm))
        tp_bytes = cfg.tp_bytes if cfg.tp_bytes is not None else act
        return TensorParallel(n_nodes, tp_bytes, cfg.tp_gbps,
                              n_syncs=syncs, jitter=cfg.tp_jitter,
                              skew_cost=cfg.tp_skew_cost, seed=seed)
    raise ValueError(f"unknown topology {kind!r} "
                     f"(expected one of {sorted(TOPOLOGIES)})")
