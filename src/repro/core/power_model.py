"""Analytical power model — paper §IV-B (Eqs 7-16).

P = P_active + P_idle with P_active = M·f (Eq 10, V²α absorbed into M), and
runtime-frequency duality f = ρ/t (Eq 11).  Constant-overlap kernel runtimes
are rank-sorted across devices (Eq 12) to de-noise; aligning every rank's
runtime to t_agg(C) by a multiplicative δ gives the new rank power
P'_r = (P_r - P_idle)/δ + P_idle (Eq 15) and the system ratio P'_sys/P_sys.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.detect import classify_overlap
from repro.core.perf_model import t_agg


@dataclass
class PowerPrediction:
    p_sys: float
    p_sys_new: float

    @property
    def ratio(self) -> float:
        return self.p_sys_new / self.p_sys

    @property
    def improvement(self) -> float:
        """Paper Table III convention: >1 means power saved."""
        return self.p_sys / self.p_sys_new


def rank_runtimes(dur_c: np.ndarray) -> np.ndarray:
    """Eq 12: sort each kernel's durations across devices, sum per rank.

    dur_c: (G, Kc) constant-overlap kernel durations -> (G,) rank runtimes,
    increasing (rank 0 = leader-like, rank G-1 = straggler-like).
    """
    return np.sort(dur_c, axis=0).sum(axis=1)


def predict_power(dur: np.ndarray, overlap_ratio: np.ndarray,
                  p_baseline: float, p_idle: float, agg: str = "max",
                  tol: float = 0.15) -> PowerPrediction:
    """Power ratio when aligning all ranks' C-runtime to t_agg(C).

    p_baseline: per-device baseline power (all devices at the same cap).
    """
    const_mask = classify_overlap(overlap_ratio, tol)
    d_c = dur[:, const_mask]
    t_r = rank_runtimes(d_c)                              # (G,)
    target = t_agg(d_c, agg)
    delta = target / np.maximum(t_r, 1e-12)               # Eq 14
    p_new = (p_baseline - p_idle) / delta + p_idle        # Eq 15/16
    G = dur.shape[0]
    return PowerPrediction(p_sys=G * p_baseline, p_sys_new=float(p_new.sum()))
