"""Scope-aware HLO accounting: FLOPs / HBM bytes / collective bytes with
while-loop trip-count multipliers.

XLA's ``cost_analysis()`` counts a while (lax.scan) body ONCE — useless for
layer-scanned models.  This module parses ``compiled.as_text()`` into
computations, recovers each while's trip count from the integer constant in
its condition computation, propagates nesting multipliers, and accounts:

  * FLOPs       — 2 x prod(result dims) x prod(contracted dims) per dot;
  * HBM bytes   — Σ (result + operand bytes) over top-level (post-fusion)
                  instructions: fusion internals stay on-chip, so the fusion
                  boundary i/o is the HBM-traffic estimate;
  * collectives — result bytes per op kind + replica-group size (wire-byte
                  conversion lives in roofline.analyze).

Everything is per-device (the partitioned module); callers scale by chips.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\-.]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\-.]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w\-.]+)")
_WHILE_ATTRS = re.compile(r"condition=%?([\w\-.]+),\s*body=%?([\w\-.]+)")
_CONST_INT = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")
_CALL_TARGET = re.compile(r"(?:to_apply|calls)=%?([\w\-.]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "all-gather-start",
                  "all-reduce-start", "collective-permute-start"}
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call", "custom-call",
                   "after-all", "partition-id", "replica-id", "iota"}


def shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str

    def operands(self) -> List[str]:
        # operands appear after the op's '(' and before "), " attrs;
        # conservative: all %refs on the line except self
        body = self.line.split("(", 1)[1] if "(" in self.line else ""
        names = _OPERAND.findall(body)
        return names


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # name -> type


def parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), line)
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.type_str
    return comps, entry


def trip_count(cond: Computation) -> int:
    """Largest s32 constant in the condition region = loop bound."""
    best = 1
    for ins in cond.instrs:
        m = _CONST_INT.search(ins.line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def compute_multipliers(comps: Dict[str, Computation],
                        entry: str) -> Tuple[Dict[str, float],
                                             Dict[str, int]]:
    """Returns (multiplier per computation, local trip count per while body)."""
    mult: Dict[str, float] = {entry: 1.0}
    trips: Dict[str, int] = {}
    # iterate to fixpoint (nesting depth is tiny)
    for _ in range(12):
        changed = False
        for cname, comp in comps.items():
            if cname not in mult:
                continue
            base = mult[cname]
            for ins in comp.instrs:
                targets: List[Tuple[str, float]] = []
                if ins.op == "while":
                    m = _WHILE_ATTRS.search(ins.line)
                    if m:
                        cond, body = m.group(1), m.group(2)
                        t = trip_count(comps[cond]) if cond in comps else 1
                        trips[body] = max(trips.get(body, 1), t)
                        targets.append((body, base * t))
                        targets.append((cond, base * t))
                elif ins.op in ("call", "fusion", "custom-call", "reduce",
                                "sort", "scatter", "map", "reduce-window",
                                "select-and-scatter"):
                    m = _CALL_TARGET.search(ins.line)
                    if m:
                        targets.append((m.group(1), base))
                elif ins.op == "conditional":
                    m = _BRANCHES.search(ins.line)
                    if m:
                        for b in m.group(1).split(","):
                            targets.append((b.strip().lstrip("%"), base))
                for tgt, val in targets:
                    if tgt in comps and mult.get(tgt, 0.0) < val:
                        mult[tgt] = val
                        changed = True
        if not changed:
            break
    return mult, trips


def dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    res = shape_dims(ins.type_str)
    if not res:
        return 0.0
    out_n = 1
    for d in res[0][1]:
        out_n *= d
    contract = 1
    m = _CONTRACT.search(ins.line)
    ops = ins.operands()
    if m and ops:
        lhs_t = symbols.get(ops[0], "")
        lhs = shape_dims(lhs_t)
        if lhs:
            dims = lhs[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * out_n * contract


@dataclass
class HloAccount:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: List[Dict] = field(default_factory=list)


def _leading_dim(type_str: str) -> int:
    s = shape_dims(type_str)
    if s and s[0][1]:
        return s[0][1][0]
    return 0


def account(text: str) -> HloAccount:
    comps, entry = parse_computations(text)
    if not entry:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    mult, trips = compute_multipliers(comps, entry)
    acc = HloAccount()
    # fusion computations: internals are on-chip; we count the fusion call
    # site i/o instead.  Identify fusion-called comps to skip their bytes.
    fusion_comps = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                m = _CALL_TARGET.search(ins.line)
                if m:
                    fusion_comps.add(m.group(1))

    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        if k <= 0:
            continue
        in_fusion = cname in fusion_comps
        trip = trips.get(cname, 1)
        # loop-carried tensors accessed by slicing (scan xs/ys): a gte of the
        # loop parameter whose LEADING DIM == trip count is a stacked scan
        # buffer — per-iteration traffic is 1/trip of its size
        scan_bufs = set()
        if trip > 1:
            params = {i.name for i in comp.instrs if i.op == "parameter"}
            for i in comp.instrs:
                if (i.op == "get-tuple-element"
                        and any(o in params for o in i.operands())
                        and _leading_dim(i.type_str) == trip):
                    scan_bufs.add(i.name)

        for ins in comp.instrs:
            if ins.op == "dot" or ins.op == "convolution":
                acc.flops += k * dot_flops(ins, comp.symbols)
            if in_fusion:
                continue
            if ins.op in _SKIP_BYTES_OPS:
                continue
            is_dus = (ins.op == "dynamic-update-slice"
                      or (ins.op == "fusion"
                          and "dynamic-update-slice" in ins.name))
            is_gather = (ins.op == "gather"
                         or (ins.op == "fusion" and "gather" in ins.name))
            rb = type_bytes(ins.type_str)
            if is_dus and _leading_dim(ins.type_str) == trip and trip > 1:
                b = 2.0 * rb / trip          # writes one slab per iteration
            else:
                b = float(rb)
                for o in ins.operands():
                    t = comp.symbols.get(o)
                    if not t:
                        continue
                    ob = type_bytes(t)
                    if o in scan_bufs:
                        ob = ob / trip       # sliced access per iteration
                    elif is_gather:
                        ob = min(ob, rb)     # gather reads ~result rows
                    b += ob
            acc.hbm_bytes += k * b
            base_op = ins.op.replace("-start", "")
            if ins.op in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                gs, stride = 0, 1
                g1 = _GROUPS.search(ins.line)
                if g1:
                    first = g1.group(1).split("},{")[0].strip("{}")
                    ids = [int(x) for x in first.split(",") if x.strip()]
                    gs = len(ids)
                    if len(ids) >= 2:
                        stride = ids[1] - ids[0]
                else:
                    g2 = _GROUPS_V2.search(ins.line)
                    if g2:
                        gs = int(g2.group(2))
                acc.collectives.append({
                    "kind": base_op,
                    "result_bytes": type_bytes(ins.type_str),
                    "group_size": gs or 1,
                    "stride": stride,
                    "count": k,
                })
    return acc
