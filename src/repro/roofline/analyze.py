"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    T_comp = HLO_FLOPs_global   / (chips × peak_FLOP/s)
    T_mem  = HLO_bytes_global   / (chips × HBM_bw)
    T_coll = Σ_axis wire_bytes  / (chips × axis_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device for the SPMD
partitioned module — multiplied back to global).  Collective bytes are NOT in
cost_analysis: we parse ``compiled.as_text()`` and sum the result-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, convert to wire bytes with the ring-algorithm factors,
and attribute each op to ICI or DCN from its replica-group size.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.roofline.hw import V5E, HwSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int       # per-device result size
    group_size: int


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Extract every collective with its result bytes and replica-group size."""
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:        # async pair: count only the -start
            continue
        type_str = m.group(1) or m.group(2)
        kind = m.group(3)
        size = _shape_bytes(type_str)
        gs = 0
        g1 = _GROUPS_RE.search(line)
        if g1:
            first = g1.group(1).split("},{")[0].strip("{}")
            gs = len([x for x in first.split(",") if x.strip() != ""])
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                gs = int(g2.group(2))
        out.append(CollectiveOp(kind, size, gs or 1))
    return out


def wire_bytes(op: CollectiveOp) -> float:
    """Per-device bytes on the wire (ring algorithms)."""
    n = max(op.group_size, 1)
    f = (n - 1) / n
    if op.kind == "all-gather":
        return op.result_bytes * f                    # result = gathered
    if op.kind == "reduce-scatter":
        return op.result_bytes * (n - 1)              # operand = n x result
    if op.kind == "all-reduce":
        return 2 * op.result_bytes * f
    if op.kind == "all-to-all":
        return op.result_bytes * f
    if op.kind == "collective-permute":
        return op.result_bytes
    return op.result_bytes


@dataclass
class Roofline:
    chips: int
    flops_global: float
    bytes_global: float
    coll_ici_bytes: float               # per-device wire bytes over ICI
    coll_dcn_bytes: float               # per-device wire bytes over DCN
    collectives: List[Dict]
    model_flops: float = 0.0
    hw: HwSpec = field(default_factory=lambda: V5E)

    @property
    def t_comp(self) -> float:
        return self.flops_global / (self.chips * self.hw.peak_flops)

    @property
    def t_mem(self) -> float:
        return self.bytes_global / (self.chips * self.hw.hbm_bw)

    @property
    def t_coll(self) -> float:
        ici = self.hw.ici_link_bw * self.hw.ici_links_per_axis
        return (self.coll_ici_bytes / ici
                + self.coll_dcn_bytes / self.hw.dcn_bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: the score we hillclimb."""
        t_model = self.model_flops / (self.chips * self.hw.peak_flops)
        return t_model / self.bound_time if self.bound_time else 0.0

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste indicator."""
        return self.model_flops / self.flops_global if self.flops_global else 0

    def to_dict(self) -> Dict:
        return {
            "chips": self.chips,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "coll_ici_bytes": self.coll_ici_bytes,
            "coll_dcn_bytes": self.coll_dcn_bytes,
            "model_flops": self.model_flops,
            "t_comp": self.t_comp, "t_mem": self.t_mem,
            "t_coll": self.t_coll, "dominant": self.dominant,
            "roofline_fraction": self.roofline_fraction,
            "flops_ratio": self.flops_ratio,
            "collectives": self.collectives,
        }


def analyze(cost: Dict, hlo_text: str, chips: int, pod_size: int,
            model_flops: float, hw: HwSpec = V5E) -> Roofline:
    """Scope-aware accounting (repro.roofline.hlo_parse) with while-loop
    trip-count multipliers; ``cost`` (XLA cost_analysis) kept for reference
    only — it counts scan bodies once."""
    from repro.roofline.hlo_parse import account

    acc = account(hlo_text)
    flops = acc.flops * chips                   # per-device -> global
    bytes_ = acc.hbm_bytes * chips
    ici = dcn = 0.0
    summary: Dict[Tuple[str, int], Dict] = {}
    for rec in acc.collectives:
        op = CollectiveOp(rec["kind"], rec["result_bytes"],
                          rec["group_size"])
        w = wire_bytes(op) * rec["count"]
        axis = ("model" if rec.get("stride", 1) == 1
                else "data" if rec.get("stride", 1) == 16 else "other")
        # heuristic: group spanning more devices than one pod, or a group of
        # exactly the pod count on a multi-pod mesh, crosses DCN
        crosses_dcn = (chips > pod_size
                       and (op.group_size > pod_size
                            or op.group_size == chips // pod_size))
        if crosses_dcn:
            dcn += w
        else:
            ici += w
        key = (op.kind, op.group_size, axis)
        s = summary.setdefault(key, {"kind": op.kind,
                                     "group_size": op.group_size,
                                     "axis": axis,
                                     "count": 0, "result_bytes": 0,
                                     "wire_bytes": 0.0,
                                     "fabric": "dcn" if crosses_dcn
                                     else "ici"})
        s["count"] += rec["count"]
        s["result_bytes"] += op.result_bytes * rec["count"]
        s["wire_bytes"] += w
    return Roofline(chips=chips, flops_global=flops, bytes_global=bytes_,
                    coll_ici_bytes=ici, coll_dcn_bytes=dcn,
                    collectives=sorted(summary.values(),
                                       key=lambda s: -s["wire_bytes"]),
                    model_flops=model_flops, hw=hw)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed.

    Decode steps process global_batch tokens (one each); train counts the
    full fwd+bwd 6x factor, prefill/decode the 2x forward factor.
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch
