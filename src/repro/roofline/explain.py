import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Hillclimbing profiler: attribute HBM bytes / FLOPs / collective wire bytes
to individual HLO instructions (with while-trip multipliers) for one cell.

  PYTHONPATH=src python -m repro.roofline.explain --arch llama3.1-8b \
      --shape train_4k [--top 25]
"""
import argparse
import sys


def explain(arch, shape, top=25, **cell_kw):
    from repro.launch.dryrun import run_cell
    from repro.roofline import hlo_parse as hp

    # reuse run_cell's lowering path but capture the HLO
    import repro.launch.dryrun as dr
    captured = {}
    orig_analyze = None

    import repro.roofline.analyze as an
    orig_analyze = an.analyze

    def spy(cost, hlo_text, *a, **kw):
        captured["hlo"] = hlo_text
        return orig_analyze(cost, hlo_text, *a, **kw)

    an.analyze = spy
    dr.analyze = spy
    try:
        rec = run_cell(arch, shape, verbose=False, **cell_kw)
    finally:
        an.analyze = orig_analyze
        dr.analyze = orig_analyze
    text = captured["hlo"]
    comps, entry = hp.parse_computations(text)
    mult, trips = hp.compute_multipliers(comps, entry)

    fusion_comps = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                m = hp._CALL_TARGET.search(ins.line)
                if m:
                    fusion_comps.add(m.group(1))

    rows = []
    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        if k <= 0 or cname in fusion_comps:
            continue
        trip = trips.get(cname, 1)
        scan_bufs = set()
        if trip > 1:
            params = {i.name for i in comp.instrs if i.op == "parameter"}
            for i in comp.instrs:
                if (i.op == "get-tuple-element"
                        and any(o in params for o in i.operands())
                        and hp._leading_dim(i.type_str) == trip):
                    scan_bufs.add(i.name)
        for ins in comp.instrs:
            fl = 0.0
            if ins.op in ("dot", "convolution"):
                fl = k * hp.dot_flops(ins, comp.symbols)
            b = 0.0
            if ins.op not in hp._SKIP_BYTES_OPS:
                is_dus = (ins.op == "dynamic-update-slice"
                          or (ins.op == "fusion"
                              and "dynamic-update-slice" in ins.name))
                is_gather = (ins.op == "gather"
                             or (ins.op == "fusion" and "gather" in ins.name))
                rb = hp.type_bytes(ins.type_str)
                if is_dus and hp._leading_dim(ins.type_str) == trip > 1:
                    b = 2.0 * rb / trip
                else:
                    b = float(rb)
                    for o in ins.operands():
                        t = comp.symbols.get(o)
                        if not t:
                            continue
                        ob = hp.type_bytes(t)
                        if o in scan_bufs:
                            ob /= trip
                        elif is_gather:
                            ob = min(ob, rb)
                        b += ob
                b *= k
            if fl or b:
                rows.append((b, fl, cname, ins))
    rows.sort(key=lambda r: -(r[0]))
    tot_b = sum(r[0] for r in rows)
    tot_f = sum(r[1] for r in rows)
    print(f"\n== {arch} x {shape}: per-device bytes={tot_b/1e9:.1f} GB "
          f"flops={tot_f:.3e} (x{rec['chips']} chips) ==")
    print(f"{'GB':>8s} {'%':>5s} {'GF':>9s}  instruction")
    for b, fl, cname, ins in rows[:top]:
        print(f"{b/1e9:8.2f} {100*b/tot_b:5.1f} {fl/1e9:9.1f}  "
              f"[{cname[:24]}] {ins.line.strip()[:130]}")
    return rec, rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args(argv)
    explain(args.arch, args.shape, top=args.top, multi_pod=args.multi_pod,
            remat=args.remat)
    return 0


if __name__ == "__main__":
    sys.exit(main())
