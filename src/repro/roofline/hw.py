"""TPU v5e hardware constants for the roofline terms (per assignment)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HwSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_link_bw: float = 50e9           # B/s per ICI link
    ici_links_per_axis: int = 2         # bidirectional ring on a 16-torus
    dcn_bw: float = 25e9                # B/s per chip cross-pod (DCN)
    hbm_bytes: float = 16e9             # HBM capacity per chip
    vmem_bytes: float = 128e6           # VMEM per chip


V5E = HwSpec()
