"""Roofline analysis: HLO parsing, hardware ceilings, per-op intensity
accounting and explanations.  A regular package (not an implicit namespace
package) so src-layout discovery and editable installs always ship it."""
