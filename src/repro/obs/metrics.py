"""Streaming metrics registry: the Prometheus-shaped signal layer.

``MetricsRegistry`` holds Counter / Gauge / Histogram families keyed by a
metric name from the :data:`METRICS` catalog, each with labeled children
(``node``/``gpu``/``kind``-style label sets).  The registry is fed from
``TelemetryCollector`` hooks through :class:`~repro.obs.pipeline.ObsPipeline`
— the hooks fire identically under every engine (event / batched / vector /
jax fallback), so the series a rule evaluates are engine-independent by
construction.

Design constraints, inherited from the repo's replay idiom:

  * updates are a pure function of the ingested record stream — no wall
    clocks, no RNG — so replaying a recorded JSONL trace through a fresh
    registry reproduces every series (and every alert computed from them)
    bit-for-bit;
  * histograms use *fixed* bucket boundaries plus a bounded sample window
    for quantiles, so memory stays O(buckets + window) on unbounded runs;
  * NaN observations are counted (``nan_count``) but never poison buckets
    or quantiles — a dead sensor degrades a series, it must not corrupt it.

Export surfaces: :meth:`MetricsRegistry.exposition` (Prometheus text
format 0.0.4) and :meth:`MetricsRegistry.snapshot_jsonl` (a versioned
JSONL snapshot, one series per line — the machine-readable artifact the
dashboard and CI consume).
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["METRICS", "METRICS_FORMAT", "METRICS_VERSION", "Counter",
           "Gauge", "Histogram", "MetricsRegistry", "DEFAULT_BUCKETS"]

METRICS_FORMAT = "lit-silicon-metrics"
METRICS_VERSION = 1

# The metric catalog: every series the pipeline can emit, with its type and
# help text.  scripts/check_docs.py enforces that each name is documented
# in docs/observability.md, so the catalog cannot silently grow past the
# docs.  Label conventions: ``node`` (global node id), ``gpu`` (device
# index within the node), ``kind``/``stage``/``rule``/``state`` for the
# categorical counters, ``topology`` on the fleet series.
METRICS: Dict[str, Tuple[str, str]] = {
    "sim_iterations_total": (
        "counter", "sampled iterations ingested by the pipeline"),
    "node_step_seconds": (
        "gauge", "per-node local iteration time (ground truth)"),
    "node_time_obs_seconds": (
        "gauge", "per-node iteration time as the fleet sensor observed it "
                 "(NaN while the node's sensor is dead) — the straggler-"
                 "ratio rule input"),
    "node_lead_seconds": (
        "gauge", "per-node lead estimate (barrier-wait shaped)"),
    "node_power_watts": (
        "gauge", "summed device power per node"),
    "fleet_step_seconds": (
        "gauge", "fleet-committed iteration time (barrier-stretched)"),
    "device_temp_celsius": (
        "gauge", "observed device temperature"),
    "device_power_watts": (
        "gauge", "observed device power draw"),
    "device_cap_watts": (
        "gauge", "manager-set device power cap"),
    "device_freq_ghz": (
        "gauge", "device clock (DVFS governor state)"),
    "serve_tail_seconds": (
        "gauge", "per-node serving tail signal (TTFT-quantile ∨ head-of-"
                 "line age) — the SLO burn-rate rule input"),
    "manager_actions_total": (
        "counter", "power-manager mitigation actions by kind"),
    "fault_events_total": (
        "counter", "injected fault onsets by kind"),
    "escalation_events_total": (
        "counter", "escalation stage transitions by stage"),
    "alerts_total": (
        "counter", "alert state transitions by rule and state"),
    "requests_completed_total": (
        "counter", "serving requests recorded (completed + flushed)"),
    "request_ttft_seconds": (
        "histogram", "time to first token over recorded requests"),
    "iteration_seconds": (
        "histogram", "distribution of committed iteration times"),
}

# Geometric bucket ladder covering the simulator's dynamic range: kernel-
# scale milliseconds up through multi-second healing stalls and TTFTs.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 25.0, 60.0)

Labels = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[Dict[str, object]]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: Labels) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def _fmt_value(v: float) -> str:
    if v != v:
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


class Counter:
    """Monotone labeled counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.children: Dict[Labels, float] = {}

    def inc(self, labels: Optional[Dict[str, object]] = None,
            amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(inc {amount})")
        key = _labels_key(labels)
        self.children[key] = self.children.get(key, 0.0) + float(amount)

    def value(self, labels: Optional[Dict[str, object]] = None) -> float:
        return self.children.get(_labels_key(labels), 0.0)

    def total(self) -> float:
        return float(sum(self.children.values()))

    # ------------------------------------------------------------- export
    def expose(self) -> Iterable[str]:
        for key in sorted(self.children):
            yield f"{self.name}{_fmt_labels(key)} " \
                  f"{_fmt_value(self.children[key])}"

    def snapshot_rows(self) -> Iterable[dict]:
        for key in sorted(self.children):
            yield {"metric": self.name, "type": self.kind,
                   "labels": dict(key), "value": self.children[key]}


class Gauge:
    """Labeled last-value gauge.  NaN is a legal value (a dead sensor's
    reading) — rules treat it as condition-false, never as a crash."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.children: Dict[Labels, float] = {}

    def set(self, value: float,
            labels: Optional[Dict[str, object]] = None) -> None:
        self.children[_labels_key(labels)] = float(value)

    def value(self, labels: Optional[Dict[str, object]] = None) -> float:
        return self.children.get(_labels_key(labels), math.nan)

    def items(self) -> List[Tuple[Labels, float]]:
        return sorted(self.children.items())

    # ------------------------------------------------------------- export
    def expose(self) -> Iterable[str]:
        for key in sorted(self.children):
            v = self.children[key]
            yield f"{self.name}{_fmt_labels(key)} {_fmt_value(v)}"

    def snapshot_rows(self) -> Iterable[dict]:
        for key in sorted(self.children):
            v = self.children[key]
            yield {"metric": self.name, "type": self.kind,
                   "labels": dict(key), "value": (None if v != v else v)}


class _HistChild:
    """One labeled histogram series: fixed cumulative buckets + a bounded
    window of recent finite samples for streaming quantiles."""

    def __init__(self, buckets: Tuple[float, ...], window: int):
        self.buckets = buckets
        self.bucket_counts = [0] * (len(buckets) + 1)   # +1: the +Inf bucket
        self.count = 0                                   # finite observations
        self.sum = 0.0
        self.nan_count = 0
        self.window = int(window)
        self._recent: List[float] = []                   # ring of last W
        self._recent_pos = 0

    def observe(self, value: float) -> None:
        v = float(value)
        if v != v:                    # NaN: counted, never binned/windowed
            self.nan_count += 1
            return
        self.count += 1
        self.sum += v
        i = 0
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                break
        else:
            i = len(self.buckets)
        self.bucket_counts[i] += 1
        if len(self._recent) < self.window:
            self._recent.append(v)
        else:                          # fixed-size ring, no deque import
            self._recent[self._recent_pos] = v
            self._recent_pos = (self._recent_pos + 1) % self.window

    def quantile(self, q: float) -> float:
        """Windowed quantile over the most recent finite samples (nearest-
        rank on the sorted window).  Empty window → NaN; a single sample is
        every quantile of itself."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if not self._recent:
            return math.nan
        xs = sorted(self._recent)
        idx = min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))
        return xs[idx]

    def cumulative(self) -> List[int]:
        out, acc = [], 0
        for c in self.bucket_counts:
            acc += c
            out.append(acc)
        return out


class Histogram:
    """Labeled histogram with fixed buckets and windowed quantiles."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                 window: int = 128):
        if list(buckets) != sorted(set(buckets)):
            raise ValueError(f"{name}: buckets must be strictly increasing")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        self.window = int(window)
        self.children: Dict[Labels, _HistChild] = {}

    def child(self, labels: Optional[Dict[str, object]] = None) -> _HistChild:
        key = _labels_key(labels)
        if key not in self.children:
            self.children[key] = _HistChild(self.buckets, self.window)
        return self.children[key]

    def observe(self, value: float,
                labels: Optional[Dict[str, object]] = None) -> None:
        self.child(labels).observe(value)

    def quantile(self, q: float,
                 labels: Optional[Dict[str, object]] = None) -> float:
        key = _labels_key(labels)
        if key not in self.children:
            return math.nan
        return self.children[key].quantile(q)

    # ------------------------------------------------------------- export
    def expose(self) -> Iterable[str]:
        for key in sorted(self.children):
            ch = self.children[key]
            cum = ch.cumulative()
            for ub, c in zip(self.buckets, cum):
                lk = key + (("le", _fmt_value(ub)),)
                yield f"{self.name}_bucket{_fmt_labels(lk)} {c}"
            lk = key + (("le", "+Inf"),)
            yield f"{self.name}_bucket{_fmt_labels(lk)} {cum[-1]}"
            yield f"{self.name}_sum{_fmt_labels(key)} {_fmt_value(ch.sum)}"
            yield f"{self.name}_count{_fmt_labels(key)} {ch.count}"

    def snapshot_rows(self) -> Iterable[dict]:
        for key in sorted(self.children):
            ch = self.children[key]
            p50, p99 = ch.quantile(0.5), ch.quantile(0.99)
            yield {"metric": self.name, "type": self.kind,
                   "labels": dict(key),
                   "count": ch.count, "sum": ch.sum,
                   "nan_count": ch.nan_count,
                   "buckets": {_fmt_value(ub): c for ub, c in
                               zip(self.buckets, ch.cumulative())},
                   "p50": (None if p50 != p50 else p50),
                   "p99": (None if p99 != p99 else p99)}


class MetricsRegistry:
    """All metric families, instantiated lazily from the catalog."""

    def __init__(self, hist_window: int = 128,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.hist_window = int(hist_window)
        self.buckets = tuple(buckets)
        self._families: Dict[str, object] = {}

    # ------------------------------------------------------------- access
    def _family(self, name: str):
        fam = self._families.get(name)
        if fam is None:
            if name not in METRICS:
                raise KeyError(f"unknown metric {name!r} (catalog: "
                               f"{sorted(METRICS)})")
            kind, help_ = METRICS[name]
            if kind == "counter":
                fam = Counter(name, help_)
            elif kind == "gauge":
                fam = Gauge(name, help_)
            else:
                fam = Histogram(name, help_, buckets=self.buckets,
                                window=self.hist_window)
            self._families[name] = fam
        return fam

    def counter(self, name: str) -> Counter:
        fam = self._family(name)
        if not isinstance(fam, Counter):
            raise TypeError(f"{name} is a {fam.kind}, not a counter")
        return fam

    def gauge(self, name: str) -> Gauge:
        fam = self._family(name)
        if not isinstance(fam, Gauge):
            raise TypeError(f"{name} is a {fam.kind}, not a gauge")
        return fam

    def histogram(self, name: str) -> Histogram:
        fam = self._family(name)
        if not isinstance(fam, Histogram):
            raise TypeError(f"{name} is a {fam.kind}, not a histogram")
        return fam

    def series(self, name: str) -> List[Tuple[Dict[str, str], float]]:
        """(labels dict, value) pairs for a gauge family — the rule
        engine's read path.  Unregistered families read as empty."""
        fam = self._families.get(name)
        if fam is None or not isinstance(fam, Gauge):
            return []
        return [(dict(k), v) for k, v in fam.items()]

    # ------------------------------------------------------------- export
    def exposition(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            lines.extend(fam.expose())
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot_jsonl(self, path: str,
                       extra_meta: Optional[dict] = None) -> int:
        """Versioned JSONL snapshot: a header line then one line per
        labeled series.  Returns the line count."""
        meta = dict(extra_meta or {})
        lines = 0
        with open(path, "w") as f:
            f.write(json.dumps({"format": METRICS_FORMAT,
                                "version": METRICS_VERSION,
                                "meta": meta},
                               sort_keys=True, allow_nan=False) + "\n")
            lines += 1
            for name in sorted(self._families):
                for row in self._families[name].snapshot_rows():
                    f.write(json.dumps(row, sort_keys=True,
                                       allow_nan=False) + "\n")
                    lines += 1
        return lines
