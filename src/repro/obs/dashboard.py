"""Fleet-health dashboard: a static, self-contained HTML report.

``render_dashboard`` turns a recorded :class:`TelemetryTrace` into one
HTML file with zero external dependencies — inline CSS and Python-computed
SVG sparklines, no JavaScript — so the artifact survives CI upload and
opens anywhere.  Panels:

  * per-node health strip: temperature (max over devices), node power,
    mean power cap, observed lead — the Lit Silicon signals, one sparkline
    each, with firing-alert counts per node;
  * serve SLO panel (when the trace carries the serve tail signal);
  * the incident list (from :mod:`repro.obs.incidents`) with per-incident
    fault kinds, alert rules and drain outcome;
  * the alert score line (time-to-alert, false positives).

``terminal_summary`` prints the same story as text for the CLI.
"""
from __future__ import annotations

import html
import math
from typing import Dict, List, Optional, Sequence

from repro.obs.incidents import (build_incidents, build_timeline,
                                 score_alerts)
from repro.obs.rules import ALERT_SOURCE

__all__ = ["render_dashboard", "terminal_summary"]

_W, _H = 260, 42                    # sparkline viewport (px)


def _finite(xs: Sequence[float]) -> List[float]:
    return [x for x in xs if x == x]


def _spark(values: Sequence[float], color: str = "#2a6fb0") -> str:
    """One SVG sparkline; NaN samples break the polyline into segments."""
    fin = _finite(values)
    if not fin:
        return (f'<svg width="{_W}" height="{_H}">'
                f'<text x="4" y="{_H - 14}" class="mut">no data</text></svg>')
    lo, hi = min(fin), max(fin)
    span = (hi - lo) or 1.0
    n = max(len(values) - 1, 1)

    def _xy(i: int, v: float) -> str:
        x = 2 + (_W - 4) * i / n
        y = 2 + (_H - 4) * (1.0 - (v - lo) / span)
        return f"{x:.1f},{y:.1f}"

    segs, cur = [], []
    for i, v in enumerate(values):
        if v != v:
            if len(cur) > 1:
                segs.append(cur)
            cur = []
        else:
            cur.append(_xy(i, v))
    if len(cur) > 1:
        segs.append(cur)
    polys = "".join(
        f'<polyline points="{" ".join(s)}" fill="none" '
        f'stroke="{color}" stroke-width="1.4"/>' for s in segs)
    if not polys and fin:           # single isolated points
        polys = "".join(
            f'<circle cx="{_xy(i, v).split(",")[0]}" '
            f'cy="{_xy(i, v).split(",")[1]}" r="1.5" fill="{color}"/>'
            for i, v in enumerate(values) if v == v)
    return f'<svg width="{_W}" height="{_H}" class="spark">{polys}</svg>'


def _fmt(v: float, unit: str = "") -> str:
    if v != v:
        return "—"
    return f"{v:.3g}{unit}"


def _node_series(trace) -> Dict[int, Dict[str, List[float]]]:
    """Per-node sparkline inputs, aligned on the fleet sample grid when
    one exists, else on the node-sample grid."""
    out: Dict[int, Dict[str, List[float]]] = {}
    n_nodes = trace.n_nodes
    for n in range(n_nodes):
        out[n] = {"temp": [], "power": [], "cap": [], "lead": [],
                  "tail": []}
    by_iter: Dict[int, Dict[int, object]] = {}
    for s in trace.samples:
        by_iter.setdefault(s.iteration, {})[s.node] = s
    iters = sorted(by_iter)
    for it in iters:
        row = by_iter[it]
        for n in range(n_nodes):
            s = row.get(n)
            if s is None:
                out[n]["temp"].append(math.nan)
                out[n]["cap"].append(math.nan)
            else:
                t = _finite(list(map(float, s.temp)))
                c = _finite(list(map(float, s.cap)))
                out[n]["temp"].append(max(t) if t else math.nan)
                out[n]["cap"].append(sum(c) / len(c) if c else math.nan)
    for fs in trace.fleet:
        for n in range(n_nodes):
            inr = n < len(fs.t_local)
            out[n]["power"].append(
                float(fs.node_power[n]) if inr else math.nan)
            lead = fs.lead_obs if fs.lead_obs is not None else fs.lead
            out[n]["lead"].append(
                float(lead[n]) if (inr and lead is not None) else math.nan)
            tail = getattr(fs, "tail", None)
            out[n]["tail"].append(
                float(tail[n]) if (inr and tail is not None) else math.nan)
    return out


def _firing_counts(trace) -> Dict[int, int]:
    out: Dict[int, int] = {}
    for ev in trace.events:
        if ev.source == ALERT_SOURCE and ev.kind.endswith("/firing"):
            out[ev.node] = out.get(ev.node, 0) + 1
    return out


_CSS = """
body{font:14px/1.45 system-ui,sans-serif;margin:24px;color:#1c2733}
h1{font-size:20px} h2{font-size:16px;margin-top:28px}
table{border-collapse:collapse;margin-top:8px}
td,th{padding:4px 10px;border-bottom:1px solid #dde4ea;text-align:left;
      vertical-align:middle}
th{font-weight:600;color:#51616f}
.mut{fill:#8a97a3;color:#8a97a3;font-size:11px}
.spark{background:#f6f8fa;border-radius:3px}
.bad{color:#b3261e;font-weight:600} .ok{color:#1b7f4d;font-weight:600}
.pill{display:inline-block;padding:1px 8px;border-radius:9px;
      background:#eef2f5;margin-right:4px;font-size:12px}
"""


def render_dashboard(trace, path: str,
                     title: Optional[str] = None) -> int:
    """Write the HTML fleet-health report; returns bytes written."""
    series = _node_series(trace)
    firing = _firing_counts(trace)
    timeline = build_timeline(trace)
    incidents = build_incidents(timeline)
    score = score_alerts(trace)
    esc = trace.meta.get("escalation") or {}
    patience = esc.get("patience_s", math.nan)
    topo = trace.meta.get("topology", "?")
    title = title or f"Lit Silicon fleet health — {topo}"
    has_tail = any(_finite(s["tail"]) for s in series.values())

    rows = []
    for n in sorted(series):
        s = series[n]
        nf = firing.get(n, 0)
        cls = "bad" if nf else "ok"
        cells = [f"<td>node{n}</td>"]
        for key, color in (("temp", "#b3261e"), ("power", "#2a6fb0"),
                           ("cap", "#7a5af8"), ("lead", "#c77d00")):
            fin = _finite(s[key])
            last = fin[-1] if fin else math.nan
            cells.append(f"<td>{_spark(s[key], color)}<br>"
                         f'<span class="mut">last {_fmt(last)}</span></td>')
        cells.append(f'<td class="{cls}">{nf}</td>')
        rows.append("<tr>" + "".join(cells) + "</tr>")

    tail_rows = ""
    if has_tail:
        trows = []
        for n in sorted(series):
            fin = _finite(series[n]["tail"])
            last = fin[-1] if fin else math.nan
            peak = max(fin) if fin else math.nan
            trows.append(
                f"<tr><td>node{n}</td>"
                f'<td>{_spark(series[n]["tail"], "#1b7f4d")}</td>'
                f"<td>{_fmt(last, ' s')}</td>"
                f"<td>{_fmt(peak, ' s')}</td></tr>")
        tail_rows = ("<h2>Serve tail signal</h2><table>"
                     "<tr><th>node</th><th>tail signal</th><th>last</th>"
                     "<th>peak</th></tr>" + "".join(trows) + "</table>")

    inc_rows = []
    for inc in incidents:
        kinds = "".join(f'<span class="pill">{html.escape(k)}</span>'
                        for k in inc.fault_kinds) or "—"
        rules = "".join(f'<span class="pill">{html.escape(r)}</span>'
                        for r in inc.alert_rules) or "—"
        state = ("drained" if inc.drained
                 else ("open" if inc.open else "resolved"))
        inc_rows.append(
            f"<tr><td>node{inc.node}</td><td>{_fmt(inc.t_open, ' s')}</td>"
            f"<td>{_fmt(inc.t_close, ' s')}</td><td>{kinds}</td>"
            f"<td>{rules}</td><td>{state}</td>"
            f"<td>{len(inc.events)}</td></tr>")
    inc_table = ("<table><tr><th>node</th><th>open</th><th>close</th>"
                 "<th>faults</th><th>alert rules</th><th>state</th>"
                 "<th>events</th></tr>" + "".join(inc_rows) + "</table>"
                 if inc_rows else "<p>No incidents.</p>")

    fp = score["false_positives"]
    tta = score["time_to_alert_s"]
    fp_cls = "ok" if fp == 0 else "bad"
    tta_txt = _fmt(tta, " s")
    if patience == patience and tta == tta:
        tta_cls = "ok" if tta <= patience else "bad"
        tta_txt += f" (patience {_fmt(patience, ' s')})"
    else:
        tta_cls = ""
    doc = f"""<!doctype html><html><head><meta charset="utf-8">
<title>{html.escape(title)}</title><style>{_CSS}</style></head><body>
<h1>{html.escape(title)}</h1>
<p>{len(trace.samples)} node samples · {len(trace.fleet)} fleet samples ·
{len(trace.events)} events · {len(trace.requests)} requests ·
sensor <code>{html.escape(str(trace.meta.get('sensor', {})))}</code></p>
<p>Alerts firing: <b>{int(score['n_alerts_firing'])}</b> ·
false positives: <span class="{fp_cls}">{int(fp)}</span> ·
time-to-alert: <span class="{tta_cls}">{tta_txt}</span></p>
<h2>Node health</h2>
<table><tr><th>node</th><th>temp (max °C)</th><th>power (W)</th>
<th>cap (mean W)</th><th>lead (s)</th><th>alerts</th></tr>
{''.join(rows)}</table>
{tail_rows}
<h2>Incidents</h2>
{inc_table}
</body></html>"""
    data = doc.encode()
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def terminal_summary(trace, patience_s: float = math.nan) -> str:
    """The dashboard's story as plain text for the CLI."""
    if patience_s != patience_s:
        patience_s = float(
            (trace.meta.get("escalation") or {}).get("patience_s",
                                                     math.nan))
    score = score_alerts(trace, patience_s=patience_s)
    timeline = build_timeline(trace)
    incidents = build_incidents(timeline)
    firing = _firing_counts(trace)
    lines = [f"fleet: {trace.n_nodes} node(s), topology "
             f"{trace.meta.get('topology', '?')}, "
             f"{len(trace.fleet)} fleet sample(s), "
             f"{len(trace.events)} event(s)"]
    lines.append(
        f"alerts: {int(score['n_alerts_firing'])} firing "
        f"({int(score['n_alerts_pending'])} pending, "
        f"{int(score['n_alerts_resolved'])} resolved), "
        f"{int(score['false_positives'])} false positive(s)")
    tta = score["time_to_alert_s"]
    if tta == tta:
        extra = ""
        if patience_s == patience_s:
            verdict = "within" if tta <= patience_s else "BEYOND"
            extra = f" — {verdict} patience {patience_s:g}s"
        lines.append(f"time-to-alert: {tta:.3g}s{extra}")
    for n in sorted(firing):
        lines.append(f"  node{n}: {firing[n]} firing alert(s)")
    for inc in incidents:
        state = ("drained" if inc.drained
                 else ("open" if inc.open else "resolved"))
        lines.append(
            f"incident node{inc.node}: t={inc.t_open:.3g}s"
            + (f"→{inc.t_close:.3g}s" if not inc.open else "→…")
            + f" [{state}] faults={','.join(inc.fault_kinds) or '-'}"
              f" rules={','.join(inc.alert_rules) or '-'}")
    if not incidents:
        lines.append("no incidents")
    return "\n".join(lines)
