"""Declarative alert rules with Prometheus-style ``for:`` hysteresis.

A rule names a metric from the :data:`~repro.obs.metrics.METRICS` catalog
and a condition over it; the :class:`AlertEngine` evaluates every rule once
per ingested iteration and runs one state machine per labeled series:

    inactive → pending → firing → (resolved →) inactive

``for_s`` is the hysteresis window: the condition must hold continuously
for that many *simulated* seconds before a pending alert fires — a blip
shorter than the window produces a pending transition and then silently
resets (flap suppression, exactly Prometheus' ``for:`` semantics).  With
``for_s == 0`` the alert fires on the first true evaluation, skipping the
pending phase.  A firing alert emits ``resolved`` when the condition turns
false.

Rule kinds (the Lit Silicon detection vocabulary):

  * ``threshold``   — metric ``op`` threshold per labeled series;
  * ``fleet_ratio`` — node-labeled metric vs the median of the *other*
                      nodes (the paper's straggler-lead detection shaped
                      as a rule: a node running ``threshold``x slower than
                      the fleet median is lit);
  * ``slo_burn``    — metric / ``target`` (the SLO objective) exceeds
                      ``threshold`` — burn rate > 1 means the serve tail
                      signal is consuming error budget;
  * ``temp_slope``  — d(metric)/dt over a trailing ``window_s`` window
                      exceeds ``threshold`` (°C/s) — the thermal-runaway
                      precursor: temperature *slope* leads the absolute
                      limit by many seconds.

Determinism: evaluation is a pure function of the ingested gauge values
and the simulated clock — no wall time, no RNG — so live alert firings
replay bit-for-bit from a recorded trace (tested through
``repro.obs.pipeline.replay_alerts``).  NaN inputs evaluate as
condition-false and are excluded from medians and slope windows.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["RULE_KINDS", "ALERT_STATES", "AlertRule", "AlertTransition",
           "AlertEngine", "default_rules", "ALERT_SOURCE"]

RULE_KINDS = ("threshold", "fleet_ratio", "slo_burn", "temp_slope")

# lifecycle states a series can transition *into* (inactive is the rest
# state transitions depart from; a pending→inactive flap reset is silent)
ALERT_STATES = ("pending", "firing", "resolved")

# FaultRecord.source tag alert transitions persist under in a trace
ALERT_SOURCE = "alert"


@dataclass(frozen=True)
class AlertRule:
    """One declarative rule (JSON round-trips through the scenario codec;
    frozen so a rule set can be shared across engines safely)."""

    name: str
    kind: str                       # RULE_KINDS entry
    metric: str                     # gauge family the rule consumes
    threshold: float
    for_s: float = 0.0              # hysteresis window (simulated seconds)
    op: str = ">"                   # threshold direction: ">" | "<"
    target: float = 1.0             # slo_burn denominator (the SLO itself)
    window_s: float = 6.0           # temp_slope trailing window
    grace_s: float = 0.0            # boot suppression: condition-false
    #                                 until the clock reaches this — the
    #                                 cold-start transient (a fleet climbing
    #                                 to thermal steady state) is not an
    #                                 incident
    severity: str = "warn"          # "warn" | "page" (annotation only)

    def validate(self) -> "AlertRule":
        if not self.name:
            raise ValueError("alert rule needs a name")
        if self.kind not in RULE_KINDS:
            raise ValueError(f"rule {self.name!r}: kind must be one of "
                             f"{RULE_KINDS}, got {self.kind!r}")
        if self.op not in (">", "<"):
            raise ValueError(f"rule {self.name!r}: op must be '>' or '<'")
        if self.for_s < 0:
            raise ValueError(f"rule {self.name!r}: for_s must be >= 0")
        if self.grace_s < 0:
            raise ValueError(f"rule {self.name!r}: grace_s must be >= 0")
        if self.kind == "slo_burn" and self.target <= 0:
            raise ValueError(f"rule {self.name!r}: slo_burn target must "
                             "be > 0")
        if self.kind == "temp_slope" and self.window_s <= 0:
            raise ValueError(f"rule {self.name!r}: temp_slope window_s "
                             "must be > 0")
        if self.severity not in ("warn", "page"):
            raise ValueError(f"rule {self.name!r}: severity must be "
                             "'warn' or 'page'")
        return self

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AlertRule":
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - names)
        if unknown:
            raise ValueError(f"unknown AlertRule key(s) {unknown}")
        return cls(**d).validate()


def default_rules() -> List[AlertRule]:
    """The Lit Silicon default rule set.  Thresholds are calibrated on the
    pinned ``cluster/fault-heal`` and ``serve/straggler-slo`` scenarios so
    that at lossless fidelity every injected fault raises an alert within
    the escalation policy's patience window and the boosted-but-managed
    straggler (a power-cap-fixable lean, not a fault) stays quiet.

      * straggler-ratio mirrors the EscalationPolicy threshold (1.25) with
        half its patience as hysteresis — the alert leads the drain;
      * overtemp sits above the DVFS throttle band (the governor holds
        healthy devices near t_hot), so only runaway-class excursions trip;
      * runaway-slope watches d(temp)/dt: on the pinned fault-heal run the
        steepest healthy 4 s slope is 0.69 °C/s (elastic-restart warmup),
        while the injected runaway crosses 0.8 °C/s 3.1 s after onset and
        keeps accelerating — threshold 0.8 with a short 0.5 s hold fires
        3.84 s after onset, inside the escalation patience (4 s), with the
        healthy fleet never even going pending.  The 6 s boot grace covers
        the one benign excursion above threshold: the air-cooled serve
        node climbs at ~0.88 °C/s for its first ~5 s while it settles
        toward its (hotter) steady state;
      * slo-burn fires when the serve tail signal burns the TTFT deadline
        at >= 1.5x for several seconds.
    """
    return [
        AlertRule("straggler-ratio", "fleet_ratio", "node_time_obs_seconds",
                  threshold=1.25, for_s=2.0, severity="page"),
        AlertRule("device-overtemp", "threshold", "device_temp_celsius",
                  threshold=102.0, for_s=1.0, severity="warn"),
        AlertRule("runaway-slope", "temp_slope", "device_temp_celsius",
                  threshold=0.8, for_s=0.5, window_s=4.0, grace_s=6.0,
                  severity="page"),
        AlertRule("slo-burn", "slo_burn", "serve_tail_seconds",
                  threshold=1.5, target=2.0, for_s=4.0, severity="page"),
    ]


@dataclass(frozen=True)
class AlertTransition:
    """One lifecycle transition of one rule's labeled series."""

    iteration: int
    t: float                        # simulated-seconds pipeline clock
    rule: str
    state: str                      # ALERT_STATES entry
    node: int = -1
    device: int = -1
    value: float = math.nan         # the rule's computed signal value

    @property
    def kind(self) -> str:
        """The ``FaultRecord.kind`` encoding: ``rule/state``."""
        return f"{self.rule}/{self.state}"


@dataclass
class _SeriesState:
    state: str = "inactive"         # inactive | pending | firing
    pending_t0: float = math.nan


@dataclass
class _SlopeWindow:
    ts: List[float] = field(default_factory=list)
    vs: List[float] = field(default_factory=list)

    def push(self, t: float, v: float, window_s: float) -> None:
        self.ts.append(t)
        self.vs.append(v)
        while self.ts and self.ts[0] < t - window_s:
            self.ts.pop(0)
            self.vs.pop(0)

    def slope(self) -> float:
        if len(self.ts) < 2 or self.ts[-1] <= self.ts[0]:
            return math.nan
        return (self.vs[-1] - self.vs[0]) / (self.ts[-1] - self.ts[0])


def _series_ids(labels: Dict[str, str]) -> Tuple[int, int]:
    def _i(k: str) -> int:
        try:
            return int(labels.get(k, -1))
        except (TypeError, ValueError):
            return -1
    return _i("node"), _i("gpu")


class AlertEngine:
    """Evaluates a rule set against a registry once per iteration."""

    def __init__(self, rules: Optional[List[AlertRule]] = None):
        self.rules = [r.validate() for r in (rules if rules is not None
                                             else default_rules())]
        seen = set()
        for r in self.rules:
            if r.name in seen:
                raise ValueError(f"duplicate rule name {r.name!r}")
            seen.add(r.name)
        self._state: Dict[Tuple[str, Tuple], _SeriesState] = {}
        self._slopes: Dict[Tuple[str, Tuple], _SlopeWindow] = {}
        self.transitions: List[AlertTransition] = []

    # ------------------------------------------------------------ queries
    def firing(self) -> List[Tuple[str, Tuple]]:
        return [k for k, s in self._state.items() if s.state == "firing"]

    def firing_nodes(self) -> set:
        """Global node ids with at least one firing series — the optional
        EscalationPolicy corroboration input."""
        out = set()
        for (rule, key), st in self._state.items():
            if st.state != "firing":
                continue
            node, _ = _series_ids(dict(key))
            if node >= 0:
                out.add(node)
        return out

    # ---------------------------------------------------------- evaluation
    def _signals(self, rule: AlertRule, registry) -> List[Tuple[Dict, float]]:
        """(labels, signal value) per labeled series of the rule's metric,
        with the kind-specific arithmetic applied.  NaN signals are kept
        (they evaluate condition-false but still drive resolved)."""
        series = registry.series(rule.metric)
        if rule.kind == "threshold":
            return series
        if rule.kind == "slo_burn":
            return [(lb, v / rule.target) for lb, v in series]
        if rule.kind == "fleet_ratio":
            by_node: Dict[int, List[float]] = {}
            for lb, v in series:
                node, _ = _series_ids(lb)
                by_node.setdefault(node, []).append(v)
            node_val = {n: max(vs) for n, vs in by_node.items()}
            out = []
            for lb, _ in ((lb, v) for lb, v in series):
                node, _ = _series_ids(lb)
                others = [x for n, x in node_val.items()
                          if n != node and x == x]
                med = _median(others)
                v = node_val[node]
                ratio = (v / med if (med == med and med > 0 and v == v)
                         else math.nan)
                out.append((lb, ratio))
            return out
        # temp_slope: handled in evaluate (needs the clock to window)
        return series

    def evaluate(self, iteration: int, t: float,
                 registry) -> List[AlertTransition]:
        """One evaluation pass; returns (and records) the transitions it
        emitted.  Call exactly once per ingested iteration — live and
        replay must agree on the evaluation grid for bit-for-bit parity."""
        out: List[AlertTransition] = []
        seen_keys = set()
        for rule in self.rules:
            if rule.kind == "temp_slope":
                sigs = []
                for lb, v in registry.series(rule.metric):
                    key = (rule.name, tuple(sorted(lb.items())))
                    w = self._slopes.setdefault(key, _SlopeWindow())
                    if v == v:      # NaN reads never enter the window
                        w.push(float(t), float(v), rule.window_s)
                    sigs.append((lb, w.slope()))
            else:
                sigs = self._signals(rule, registry)
            for lb, sig in sigs:
                cond = _cond(sig, rule) and t >= rule.grace_s
                key = (rule.name, tuple(sorted(lb.items())))
                seen_keys.add(key)
                st = self._state.setdefault(key, _SeriesState())
                node, device = _series_ids(lb)
                if cond:
                    if st.state == "inactive":
                        if rule.for_s <= 0:
                            st.state = "firing"
                            out.append(AlertTransition(
                                iteration, t, rule.name, "firing",
                                node, device, float(sig)))
                        else:
                            st.state = "pending"
                            st.pending_t0 = float(t)
                            out.append(AlertTransition(
                                iteration, t, rule.name, "pending",
                                node, device, float(sig)))
                    elif (st.state == "pending"
                          and t - st.pending_t0 >= rule.for_s):
                        st.state = "firing"
                        out.append(AlertTransition(
                            iteration, t, rule.name, "firing",
                            node, device, float(sig)))
                else:
                    if st.state == "firing":
                        st.state = "inactive"
                        out.append(AlertTransition(
                            iteration, t, rule.name, "resolved",
                            node, device, float(sig)))
                    elif st.state == "pending":
                        # flap shorter than for_s: silent reset, no firing
                        st.state = "inactive"
                        st.pending_t0 = math.nan
        # a series that vanished (e.g. its node was drained and trimmed
        # from the registry) reads as condition-false: resolve a firing
        # machine, silently reset a pending one — it must not fire forever
        for key, st in self._state.items():
            if key in seen_keys or st.state == "inactive":
                continue
            if st.state == "firing":
                node, device = _series_ids(dict(key[1]))
                out.append(AlertTransition(
                    iteration, t, key[0], "resolved",
                    node, device, math.nan))
            st.state = "inactive"
            st.pending_t0 = math.nan
        self.transitions.extend(out)
        return out


def _median(xs: List[float]) -> float:
    if not xs:
        return math.nan
    xs = sorted(xs)
    n = len(xs)
    mid = n // 2
    return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])


def _cond(sig: float, rule: AlertRule) -> bool:
    if sig != sig:
        return False
    return sig > rule.threshold if rule.op == ">" else sig < rule.threshold
