"""Online observability over the simulated fleet: streaming metrics,
declarative alert rules with hysteresis, incident timelines, and the
fleet-health dashboard.  See docs/observability.md."""
from repro.obs.dashboard import render_dashboard, terminal_summary
from repro.obs.incidents import (INCIDENTS_FORMAT, INCIDENTS_VERSION,
                                 Incident, TimelineEvent, build_incidents,
                                 build_timeline, save_incidents,
                                 score_alerts)
from repro.obs.metrics import (DEFAULT_BUCKETS, METRICS, METRICS_FORMAT,
                               METRICS_VERSION, Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.pipeline import (ObservabilitySpec, ObsPipeline,
                                alert_replay_matches, replay_alerts,
                                transitions_to_records)
from repro.obs.rules import (ALERT_SOURCE, ALERT_STATES, RULE_KINDS,
                             AlertEngine, AlertRule, AlertTransition,
                             default_rules)

__all__ = [
    "METRICS", "METRICS_FORMAT", "METRICS_VERSION", "DEFAULT_BUCKETS",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "RULE_KINDS", "ALERT_STATES", "ALERT_SOURCE", "AlertRule",
    "AlertTransition", "AlertEngine", "default_rules",
    "ObservabilitySpec", "ObsPipeline", "replay_alerts",
    "alert_replay_matches", "transitions_to_records",
    "TimelineEvent", "Incident", "build_timeline", "build_incidents",
    "score_alerts", "save_incidents", "INCIDENTS_FORMAT",
    "INCIDENTS_VERSION",
    "render_dashboard", "terminal_summary",
]
