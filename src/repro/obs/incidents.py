"""Incident timelines: one ordered, replayable event log per run.

A recorded trace carries three event vocabularies — injected fault onsets
(``source="fault"``), EscalationPolicy stage transitions (``source=
"escalation"``) and alert lifecycle transitions (``source="alert"``) —
plus the manager's mitigation actions.  :func:`build_timeline` merges them
onto one simulated-seconds axis (actions, which carry only an iteration
number, are timestamped from the fleet samples' cumulative clock), and
:func:`build_incidents` groups the per-node story: an incident opens at
the first fault onset or alert on a node, collects everything that
happens to that node, and closes when its last alert resolves or the
node is drained.

Because the timeline is a pure function of the trace, it is replayable:
rebuilding it from the same JSONL yields the identical log — the same
idiom as cap-schedule and drain replay.

:func:`score_alerts` scores the alert stream against fault ground truth:
**time-to-alert** (first unrecoverable onset → first firing alert on that
node — the number gated against the escalation policy's ``patience_s``)
and the **false-positive count** (firing alerts on nodes with no fault
active at/before the firing time).  Run it over traces degraded with
``repro.telemetry.degrade`` to measure how detection quality falls with
sensor fidelity.

Node-id caveat: fault/escalation events carry *global* node ids while
alert labels are *local* fleet indices; the two coincide until a second
post-drain epoch remaps survivors (none of the registered scenarios do).
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.obs.rules import ALERT_SOURCE

__all__ = ["INCIDENTS_FORMAT", "INCIDENTS_VERSION", "TimelineEvent",
           "Incident", "build_timeline", "build_incidents",
           "score_alerts", "save_incidents"]

INCIDENTS_FORMAT = "lit-silicon-incidents"
INCIDENTS_VERSION = 1


@dataclass
class TimelineEvent:
    """One entry of the merged event log."""

    t: float                        # simulated seconds
    iteration: int
    source: str                     # "fault" | "escalation" | "alert" | "action"
    kind: str                       # fault kind / stage / "rule/state" / action
    node: int                       # -1: fleet-scope
    device: int = -1
    value: float = math.nan


@dataclass
class Incident:
    """One node's correlated story: opened by the first fault or alert,
    closed by the last alert resolving or the node draining."""

    node: int
    t_open: float
    t_close: float = math.nan       # NaN: still open at end of trace
    events: List[TimelineEvent] = field(default_factory=list)
    fault_kinds: List[str] = field(default_factory=list)
    alert_rules: List[str] = field(default_factory=list)
    drained: bool = False

    @property
    def open(self) -> bool:
        return self.t_close != self.t_close


def _iteration_clock(trace) -> Dict[int, float]:
    """iteration -> simulated seconds *after* that iteration committed,
    accumulated from the sampled fleet rows (the pipeline clock's basis)."""
    clock, out = 0.0, {}
    for fs in trace.fleet:
        clock += float(fs.t_fleet)
        out[fs.iteration] = clock
    return out


def build_timeline(trace, include_actions: bool = True) -> List[TimelineEvent]:
    """Merge events (+ optionally manager actions) onto one time axis,
    ordered by (t, iteration) with ties kept in recording order."""
    out: List[TimelineEvent] = []
    for ev in trace.events:
        out.append(TimelineEvent(
            t=float(ev.t_sim), iteration=int(ev.iteration),
            source=ev.source, kind=ev.kind, node=int(ev.node),
            device=int(ev.device), value=float(ev.value)))
    if include_actions:
        clock = _iteration_clock(trace)
        for a in trace.actions:
            out.append(TimelineEvent(
                t=clock.get(a.iteration, math.nan),
                iteration=int(a.iteration), source="action", kind=a.kind,
                node=int(a.node),
                value=float(len(a.values))))
    def _key(e: TimelineEvent):
        return (e.t if e.t == e.t else math.inf, e.iteration)
    out.sort(key=_key)              # stable: ties keep recording order
    return out


def build_incidents(timeline: List[TimelineEvent]) -> List[Incident]:
    """Group the timeline into per-node incidents (see module docstring).
    Manager actions never open an incident but are folded into open ones
    on their node."""
    open_by_node: Dict[int, Incident] = {}
    firing: Dict[int, set] = {}     # node -> rules currently firing
    done: List[Incident] = []

    def _close(inc: Incident, t: float) -> None:
        inc.t_close = float(t)
        done.append(inc)
        del open_by_node[inc.node]

    for ev in timeline:
        n = ev.node
        if n < 0:
            continue
        inc = open_by_node.get(n)
        opening = (ev.source == "fault"
                   or (ev.source == ALERT_SOURCE
                       and not ev.kind.endswith("/resolved"))
                   or ev.source == "escalation")
        if inc is None:
            if not opening:
                continue            # actions alone don't open incidents
            inc = Incident(node=n, t_open=float(ev.t))
            open_by_node[n] = inc
            firing.setdefault(n, set())
        inc.events.append(ev)
        if ev.source == "fault" and ev.kind not in inc.fault_kinds:
            inc.fault_kinds.append(ev.kind)
        if ev.source == ALERT_SOURCE:
            rule, _, state = ev.kind.rpartition("/")
            if rule not in inc.alert_rules:
                inc.alert_rules.append(rule)
            if state == "firing":
                firing[n].add(rule)
            elif state == "resolved":
                firing[n].discard(rule)
                # story over: nothing firing and no fault/escalation keeps
                # the node's incident open
                if not firing[n] and not inc.fault_kinds:
                    _close(inc, ev.t)
        if ev.source == "escalation" and ev.kind == "drain":
            inc.drained = True
            _close(inc, ev.t)
    done.extend(open_by_node.values())
    done.sort(key=lambda i: i.t_open)
    return done


def score_alerts(trace, patience_s: float = math.nan) -> dict:
    """Score the recorded alert stream against fault ground truth.

    Returns a NaN-free-where-possible dict:

      * ``n_alerts_firing`` / ``n_alerts_pending`` / ``n_alerts_resolved``
      * ``false_positives`` — firing alerts on a node with no fault onset
        at/before the firing time (a node-less firing counts unless *any*
        fault preceded it)
      * ``time_to_alert_s`` — first unrecoverable onset → first firing
        alert on that node (NaN when never alerted)
      * ``detected`` — 1.0 when every unrecoverable onset eventually had a
        firing alert on its node
      * ``within_patience`` — 1.0 when ``time_to_alert_s <= patience_s``
        (NaN patience → NaN)
      * ``per_fault`` — one entry per fault onset with its own
        time-to-alert
    """
    from repro.core.faults import UNRECOVERABLE_KINDS

    alerts = [ev for ev in trace.events if ev.source == ALERT_SOURCE]
    faults = [ev for ev in trace.events if ev.source == "fault"]
    fir = [ev for ev in alerts if ev.kind.endswith("/firing")]
    n_pending = sum(1 for ev in alerts if ev.kind.endswith("/pending"))
    n_resolved = sum(1 for ev in alerts if ev.kind.endswith("/resolved"))

    first_onset: Dict[int, float] = {}
    for ev in faults:
        if ev.node not in first_onset or ev.t_sim < first_onset[ev.node]:
            first_onset[ev.node] = float(ev.t_sim)
    any_onset = min(first_onset.values()) if first_onset else math.inf

    false_pos = 0
    for ev in fir:
        if ev.node >= 0:
            onset = first_onset.get(ev.node, math.inf)
        else:
            onset = any_onset
        if ev.t_sim < onset:
            false_pos += 1

    per_fault: List[dict] = []
    ttas: List[float] = []
    for ev in faults:
        hits = [a.t_sim - ev.t_sim for a in fir
                if a.node == ev.node and a.t_sim >= ev.t_sim]
        tta = min(hits) if hits else math.nan
        per_fault.append({"kind": ev.kind, "node": ev.node,
                          "onset_t": float(ev.t_sim),
                          "time_to_alert_s": tta})
        if ev.kind in UNRECOVERABLE_KINDS:
            ttas.append(tta)

    detected = (1.0 if ttas and all(t == t for t in ttas)
                else (0.0 if ttas else math.nan))
    tta_first = ttas[0] if ttas else math.nan
    within = math.nan
    if patience_s == patience_s and tta_first == tta_first:
        within = 1.0 if tta_first <= patience_s else 0.0
    return {"n_alerts_firing": float(len(fir)),
            "n_alerts_pending": float(n_pending),
            "n_alerts_resolved": float(n_resolved),
            "false_positives": float(false_pos),
            "time_to_alert_s": tta_first,
            "detected": detected,
            "within_patience": within,
            "per_fault": per_fault}


def save_incidents(trace, path: str,
                   extra_meta: Optional[dict] = None) -> int:
    """Write the timeline + incident groupings as versioned JSONL; returns
    the line count.  One header, then ``{"type": "timeline", ...}`` rows
    in order, then ``{"type": "incident", ...}`` summaries."""
    timeline = build_timeline(trace)
    incidents = build_incidents(timeline)
    score = score_alerts(trace)

    def _nn(x):                     # NaN -> null, everything else verbatim
        return None if isinstance(x, float) and x != x else x

    lines = 0
    with open(path, "w") as f:
        meta = dict(extra_meta or {})
        meta["score"] = {k: _nn(v) for k, v in score.items()
                         if k != "per_fault"}
        f.write(json.dumps({"format": INCIDENTS_FORMAT,
                            "version": INCIDENTS_VERSION,
                            "meta": meta},
                           sort_keys=True, allow_nan=False) + "\n")
        lines += 1
        for ev in timeline:
            d = asdict(ev)
            d = {k: _nn(v) for k, v in d.items()}
            d["type"] = "timeline"
            f.write(json.dumps(d, sort_keys=True, allow_nan=False) + "\n")
            lines += 1
        for inc in incidents:
            f.write(json.dumps({
                "type": "incident", "node": inc.node,
                "t_open": _nn(inc.t_open), "t_close": _nn(inc.t_close),
                "n_events": len(inc.events),
                "fault_kinds": inc.fault_kinds,
                "alert_rules": inc.alert_rules,
                "drained": inc.drained},
                               sort_keys=True, allow_nan=False) + "\n")
            lines += 1
    return lines
