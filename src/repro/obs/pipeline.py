"""ObsPipeline: collector hooks → metrics registry → alert engine.

The pipeline is a *pure observer* over ``TelemetryCollector`` records: it
registers itself on ``collector.observers`` and is handed every record the
collector appends (node/fleet samples, manager actions, fault/escalation
events, serving requests).  It never touches the simulators, so attaching
it cannot perturb physics or RNG streams — the same records are produced
with or without observability.

Per ingested record it updates the :class:`~repro.obs.metrics.MetricsRegistry`
gauges/counters/histograms, and once per sampled iteration (at the fleet
sample in fleet scope, at each node sample in bare-node scope) it runs the
:class:`~repro.obs.rules.AlertEngine`.  Alert transitions are persisted
back into the collector's event ring as ``FaultRecord`` rows with
``source="alert"`` — the same JSONL ``event`` lines fault onsets and
escalation decisions already use, so the trace format version stays 1 and
every existing reader skips them.

The pipeline clock is simulated seconds accumulated from the records
themselves (``t_fleet`` per fleet sample, realigned by event ``t_sim`` —
a drain's heal time enters through the escalation ``restart`` event), so
:func:`replay_alerts` can feed a *recorded* trace through a fresh pipeline
and reproduce every live alert transition bit-for-bit — the exact contract
``replay_escalation`` already established for drain decisions, verified by
:func:`alert_replay_matches`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Set

from repro.obs.metrics import MetricsRegistry
from repro.obs.rules import (ALERT_SOURCE, AlertEngine, AlertRule,
                             AlertTransition, default_rules)

__all__ = ["ObservabilitySpec", "ObsPipeline", "replay_alerts",
           "alert_replay_matches", "transitions_to_records"]


@dataclass
class ObservabilitySpec:
    """The observability section of a Scenario (JSON round-trip like the
    fault/escalation sections).  ``rules=None`` means the default Lit
    Silicon rule set."""

    rules: Optional[List[AlertRule]] = None
    window: int = 128               # histogram quantile window (samples)
    record_alerts: bool = True      # persist transitions into the trace

    def validate(self) -> "ObservabilitySpec":
        if self.window < 1:
            raise ValueError("observability window must be >= 1")
        if self.rules is not None:
            for r in self.rules:
                r.validate()
        return self

    def rule_objects(self) -> List[AlertRule]:
        return list(self.rules) if self.rules is not None else default_rules()

    # manual dict codec (used for trace meta, mirroring EscalationConfig)
    def to_dict(self) -> dict:
        return {"rules": (None if self.rules is None
                          else [r.to_dict() for r in self.rules]),
                "window": self.window,
                "record_alerts": self.record_alerts}

    @classmethod
    def from_dict(cls, d: dict) -> "ObservabilitySpec":
        d = dict(d)
        rules = d.pop("rules", None)
        names = {"window", "record_alerts"}
        unknown = sorted(set(d) - names)
        if unknown:
            raise ValueError(f"unknown ObservabilitySpec key(s) {unknown}")
        spec = cls(**d)
        if rules is not None:
            spec.rules = [r if isinstance(r, AlertRule)
                          else AlertRule.from_dict(r) for r in rules]
        return spec.validate()


# gauge families labeled by (local) node index — trimmed when the fleet
# shrinks after a drain so stale faulty-node readings can't hold an alert
# firing or skew the fleet median forever
_NODE_GAUGES = ("node_step_seconds", "node_time_obs_seconds",
                "node_lead_seconds", "node_power_watts",
                "serve_tail_seconds", "device_temp_celsius",
                "device_power_watts", "device_cap_watts", "device_freq_ghz")


class ObsPipeline:
    """One live (or replayed) observability session.

    ``fleet_scope=True`` (cluster / serve): rules evaluate once per fleet
    sample, after the iteration's node samples and fault onsets were
    ingested — the same intra-iteration order the collector's hooks fire
    in live, which is what makes replay bit-for-bit.  ``fleet_scope=
    False`` (bare NodeSim): rules evaluate at every node sample.
    """

    def __init__(self, spec: Optional[ObservabilitySpec] = None,
                 collector=None, fleet_scope: bool = True):
        self.spec = (spec if spec is not None
                     else ObservabilitySpec()).validate()
        self.registry = MetricsRegistry(hist_window=self.spec.window)
        self.engine = AlertEngine(self.spec.rule_objects())
        self.collector = collector
        self.fleet_scope = bool(fleet_scope)
        self.clock = 0.0                # simulated seconds, record-derived

    # ------------------------------------------------------------ attaching
    def attach(self, collector) -> "ObsPipeline":
        """Register on the collector's observer list and stamp the spec
        into trace meta so offline tooling replays the same rule set."""
        collector.observers.append(self)
        collector.meta["observability"] = self.spec.to_dict()
        self.collector = collector
        return self

    # -------------------------------------------------------------- queries
    @property
    def transitions(self) -> List[AlertTransition]:
        return self.engine.transitions

    def firing_nodes(self) -> Set[int]:
        return self.engine.firing_nodes()

    # ---------------------------------------------------------------- hooks
    def on_node_sample(self, s) -> None:
        reg = self.registry
        lb = {"node": s.node}
        reg.gauge("node_step_seconds").set(s.t_local, lb)
        reg.histogram("iteration_seconds").observe(s.t_wall, lb)
        for g in range(len(s.power)):
            glb = {"node": s.node, "gpu": g}
            reg.gauge("device_temp_celsius").set(float(s.temp[g]), glb)
            reg.gauge("device_power_watts").set(float(s.power[g]), glb)
            reg.gauge("device_cap_watts").set(float(s.cap[g]), glb)
            reg.gauge("device_freq_ghz").set(float(s.freq[g]), glb)
        if not self.fleet_scope:
            self.clock += float(s.t_wall)
            reg.counter("sim_iterations_total").inc()
            self._evaluate(s.iteration)

    def on_fleet_sample(self, fs) -> None:
        reg = self.registry
        reg.gauge("fleet_step_seconds").set(
            float(fs.t_fleet), {"topology": fs.topology})
        n_nodes = len(fs.t_local)
        for n in range(n_nodes):
            lb = {"node": n}
            reg.gauge("node_power_watts").set(float(fs.node_power[n]), lb)
            if fs.t_obs is not None:
                reg.gauge("node_time_obs_seconds").set(
                    float(fs.t_obs[n]), lb)
            if fs.lead_obs is not None:
                reg.gauge("node_lead_seconds").set(
                    float(fs.lead_obs[n]), lb)
            tail = getattr(fs, "tail", None)
            if tail is not None:
                reg.gauge("serve_tail_seconds").set(float(tail[n]), lb)
        self._trim_nodes(n_nodes)
        self.clock += float(fs.t_fleet)
        reg.counter("sim_iterations_total").inc()
        if self.fleet_scope:
            self._evaluate(fs.iteration)

    def on_action(self, a) -> None:
        self.registry.counter("manager_actions_total").inc(
            {"kind": a.kind})

    def on_event(self, ev) -> None:
        if ev.source == ALERT_SOURCE:
            return                   # our own output echoed back
        # events carry the global simulated clock (a fault's scheduled
        # onset; an escalation restart's post-heal time) — realigning here
        # is how drain heal time enters the pipeline clock
        self.clock = max(self.clock, float(ev.t_sim))
        if ev.source == "escalation":
            self.registry.counter("escalation_events_total").inc(
                {"stage": ev.kind})
        else:
            self.registry.counter("fault_events_total").inc(
                {"kind": ev.kind})

    def on_request(self, r) -> None:
        self.registry.counter("requests_completed_total").inc(
            {"node": r.node})
        self.registry.histogram("request_ttft_seconds").observe(
            r.ttft, {"node": r.node})

    # ------------------------------------------------------------ internals
    def _trim_nodes(self, n_nodes: int) -> None:
        """Drop node-labeled gauge children whose node index fell off the
        fleet (post-drain rebuild): a drained node's last faulty reading
        must not keep feeding the rules."""
        for name in _NODE_GAUGES:
            fam = self.registry._families.get(name)
            if fam is None:
                continue
            drop = []
            for key in fam.children:
                node = dict(key).get("node")
                if node is not None and int(node) >= n_nodes:
                    drop.append(key)
            for key in drop:
                del fam.children[key]

    def _evaluate(self, iteration: int) -> None:
        for tr in self.engine.evaluate(int(iteration), self.clock,
                                       self.registry):
            self.registry.counter("alerts_total").inc(
                {"rule": tr.rule, "state": tr.state})
            if self.collector is not None and self.spec.record_alerts:
                self.collector.on_fault_event(
                    tr.iteration, tr.t, tr.kind, tr.node,
                    device=tr.device, value=tr.value, source=ALERT_SOURCE)


# --------------------------------------------------------------------------- #
# offline replay — the bit-for-bit contract
# --------------------------------------------------------------------------- #
def replay_alerts(trace, spec: Optional[ObservabilitySpec] = None,
                  fleet_scope: Optional[bool] = None) -> ObsPipeline:
    """Feed a recorded trace through a fresh pipeline, reconstructing the
    live intra-iteration hook order:

        node samples → fault onsets → fleet sample (rules evaluate)
        → manager actions → escalation events

    ``spec`` defaults to the one stamped into ``trace.meta`` at recording
    time (so a replay runs the same rules), falling back to the defaults.
    Returns the replayed pipeline; its ``transitions`` are what
    :func:`alert_replay_matches` compares against the recorded ones.
    """
    if spec is None:
        meta = trace.meta.get("observability")
        spec = (ObservabilitySpec.from_dict(meta) if meta
                else ObservabilitySpec())
    if fleet_scope is None:
        fleet_scope = bool(trace.fleet)
    pipe = ObsPipeline(spec, collector=None, fleet_scope=fleet_scope)
    samples = list(trace.samples)
    events = list(trace.events)
    actions = list(trace.actions)
    si = ei = ai = 0
    for fs in trace.fleet:
        while si < len(samples) and samples[si].iteration <= fs.iteration:
            pipe.on_node_sample(samples[si])
            si += 1
        # fault onsets are reported before the fleet sample of the same
        # iteration, and an elastic "restart" row carries the iteration it
        # *precedes* (the first step of the new epoch — its post-heal
        # timestamp realigns the clock before that step's sample);
        # all other escalation (and alert) rows of the iteration come after
        while ei < len(events) and (
                events[ei].iteration < fs.iteration
                or (events[ei].iteration == fs.iteration
                    and (events[ei].source == "fault"
                         or events[ei].kind == "restart"))):
            pipe.on_event(events[ei])      # on_event skips source="alert"
            ei += 1
        pipe.on_fleet_sample(fs)
        while ai < len(actions) and actions[ai].iteration <= fs.iteration:
            pipe.on_action(actions[ai])
            ai += 1
        while ei < len(events) and events[ei].iteration <= fs.iteration:
            pipe.on_event(events[ei])
            ei += 1
    # tail: records past the last fleet sample (or a fleet-less node trace)
    for s in samples[si:]:
        pipe.on_node_sample(s)
    for ev in events[ei:]:
        pipe.on_event(ev)
    for a in actions[ai:]:
        pipe.on_action(a)
    for r in trace.requests:
        pipe.on_request(r)
    return pipe


def transitions_to_records(transitions: List[AlertTransition]) -> list:
    """Alert transitions as trace event rows (``FaultRecord`` with
    ``source="alert"``) — what a live run with ``record_alerts`` would
    have persisted.  Used to score a degraded trace's replayed alerts
    through ``repro.obs.incidents.score_alerts``."""
    from repro.telemetry.collector import FaultRecord
    return [FaultRecord(iteration=tr.iteration, t_sim=tr.t, kind=tr.kind,
                        node=tr.node, device=tr.device, value=tr.value,
                        source=ALERT_SOURCE) for tr in transitions]


def _feq(a: float, b: float) -> bool:
    a, b = float(a), float(b)
    if math.isnan(a) and math.isnan(b):
        return True
    return a == b


def alert_replay_matches(trace, spec: Optional[ObservabilitySpec] = None,
                         log=None) -> bool:
    """True iff offline rule evaluation over ``trace`` reproduces the
    recorded alert transitions bit-for-bit (iteration, rule/state, node,
    device, timestamp, signal value).  ``log`` takes a callable (e.g.
    ``print``) or a list (divergence lines are appended)."""
    if log is None:
        say = lambda s: None
    elif callable(log):
        say = log
    else:
        say = log.append
    recorded = [ev for ev in trace.events if ev.source == ALERT_SOURCE]
    pipe = replay_alerts(trace, spec)
    replayed = pipe.transitions
    if len(recorded) != len(replayed):
        say(f"alert replay: {len(replayed)} transitions vs "
            f"{len(recorded)} recorded")
        return False
    ok = True
    for i, (rec, rep) in enumerate(zip(recorded, replayed)):
        if (rec.iteration != rep.iteration or rec.kind != rep.kind
                or rec.node != rep.node or rec.device != rep.device
                or not _feq(rec.t_sim, rep.t)
                or not _feq(rec.value, rep.value)):
            say(f"alert replay mismatch at #{i}: recorded "
                f"(it={rec.iteration}, {rec.kind}, node={rec.node}, "
                f"dev={rec.device}, t={rec.t_sim}, v={rec.value}) vs "
                f"replayed (it={rep.iteration}, {rep.kind}, "
                f"node={rep.node}, dev={rep.device}, t={rep.t}, "
                f"v={rep.value})")
            ok = False
    return ok
