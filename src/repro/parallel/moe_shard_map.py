"""shard_map MoE layer: the §Perf-identified fix for grok-class models.

Under pjit, the sort+scatter capacity dispatch defeats the SPMD partitioner
(it replicates the global (E·C, d) buffer over 'model' and all-reduces it —
and its fp32 backward — every layer; see EXPERIMENTS.md §Perf G1–G3).
This module FORCES the production layout with shard_map:

  * tokens stay on their device: (B/data, S/model, d) block per device;
  * every device holds all experts' TP shards (expert_ffn over 'model'),
    so routing is PURELY LOCAL with per-device capacity;
  * the only communication is one psum over 'model' of the expert-output
    partial sums — ~d·tokens_local bytes/layer instead of the ~E·C·d
    buffer coherence traffic.

Enabled via ``set_moe_dispatch("shard_map")`` (dry-run: --moe-dispatch).
Differentiable (shard_map + psum transpose); validated against the pjit
scatter path in tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

_DISPATCH = "scatter"


def set_moe_dispatch(mode: str) -> None:
    assert mode in ("scatter", "shard_map"), mode
    global _DISPATCH
    _DISPATCH = mode


def get_moe_dispatch() -> str:
    return _DISPATCH


def moe_forward_shard_map(cfg, p, x, gates, idx, mesh, batch_axes,
                          tp_axis: str = "model"):
    """x: (B, S, d); gates/idx: (B, S, k).  Returns (B, S, d).

    Layout (grok-style TP experts — expert_ffn sharded over `tp_axis`):
    tokens are batch-sharded over the data axes and REPLICATED over the TP
    axis inside this region (every TP peer must see every token of its
    group, since each holds only h/TP of every expert); each device routes
    its group's tokens locally against its h-shard, and one psum over the
    TP axis completes the wd contraction.  EP-sharded experts (deepseek) use
    the pjit scatter path (asserted).
    """
    from repro.models.moe import _dispatch_combine_local

    m = cfg.moe
    d = cfg.d_model
    tp = tp_axis in mesh.shape and mesh.shape[tp_axis] > 1
    ep = tp and m.n_experts % mesh.shape[tp_axis] == 0
    assert not ep, \
        "shard_map dispatch supports TP-expert layouts (EP uses scatter)"
    bspec = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))
    # tokens: data-sharded batch, seq REPLICATED over the TP axis
    x_spec = P(bspec, None, None)
    g_spec = P(bspec, None, None)
    # expert weights: (E, d, h) TP-sharded on the expert hidden dim
    sspec = tp_axis if tp else None
    w_spec = P(None, None, sspec)
    wd_spec = P(None, sspec, None)

    def body(xb, gb, ib, wg, wu, wd):
        Bl, Sl, _ = xb.shape
        xf = xb.reshape(Bl * Sl, d)
        pp = {"wg": wg, "wu": wu, "wd": wd}
        out = _dispatch_combine_local(cfg, pp, xf,
                                      gb.reshape(Bl * Sl, -1),
                                      ib.reshape(Bl * Sl, -1))
        if tp:
            # wd contraction ran over the local h shard -> partial sums
            out = jax.lax.psum(out, tp_axis)
        return out.reshape(Bl, Sl, d)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(x_spec, g_spec, g_spec, w_spec, w_spec,
                             wd_spec),
                   out_specs=x_spec, check_rep=False)
    return fn(x, gates.astype(x.dtype), idx, p["wg"].astype(x.dtype),
              p["wu"].astype(x.dtype), p["wd"].astype(x.dtype))
