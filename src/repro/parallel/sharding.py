"""Partition rules: logical param/activation axes -> mesh axes.

The scheme is 2-D "fsdp × tensor" (MaxText-style) with an optional third
DCN axis:

  * ``model`` (ICI): tensor parallel over heads / ffn / vocab / experts;
  * ``data`` (ICI): FSDP (ZeRO-3) over the remaining large axis ('embed')
    plus batch data-parallelism;
  * ``pod``  (DCN): data parallel across pods; joins the FSDP axes for
    >=30 B-param models so optimizer state fits.

Semantic divisibility is checked against head/expert counts (not flat dims):
e.g. qwen2.5's 40 heads or hymba's 25 heads don't divide a 16-way model axis,
so attention falls back to data-parallel heads with TP elsewhere — recorded
per-arch by ``describe_sharding``.  Within one param, each mesh axis is used
at most once (first-fit in dim order: deepseek-moe shards experts over
'model', grok-1 (8 experts) falls through to TP over the expert ffn).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.common import logical_axes


class ShardingRules:
    def __init__(self, mesh: Mesh, cfg: ModelConfig,
                 parallel: ParallelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.parallel = parallel
        self.model_size = int(mesh.shape.get("model", 1))
        fsdp = parallel.fsdp_axes(cfg)
        self.fsdp_axes = tuple(a for a in fsdp if a in mesh.shape)
        self.batch_axes = tuple(a for a in parallel.batch_axes()
                                if a in mesh.shape)
        ms = self.model_size
        self.axis_map: Dict[Optional[str], Tuple[str, ...]] = {
            "embed": self.fsdp_axes,
            "vocab": ("model",),
            "ffn": ("model",),
            "expert_ffn": ("model",),
            "heads": ("model",) if cfg.n_heads % ms == 0 else (),
            "kv_heads": ("model",) if cfg.n_kv_heads % ms == 0 else (),
            "experts": (("model",) if cfg.moe is not None
                        and cfg.moe.n_experts % ms == 0 else ()),
            "layers": (),
            None: (),
        }

    # ---------------------------------------------------------------- params
    def spec_for(self, axes: Tuple[Optional[str], ...],
                 shape: Tuple[int, ...]) -> P:
        spec = []
        used = set()
        for d, name in enumerate(axes):
            cands = self.axis_map.get(name, ())
            cands = tuple(a for a in cands if a not in used)
            if not cands:
                spec.append(None)
                continue
            size = int(np.prod([self.mesh.shape[a] for a in cands]))
            if shape[d] % size:
                spec.append(None)
                continue
            used.update(cands)
            spec.append(cands if len(cands) > 1 else cands[0])
        return P(*spec)

    def param_shardings(self, spec_tree) -> Any:
        """ParamSpec tree -> NamedSharding tree."""
        from repro.models.common import ParamSpec

        def f(s: ParamSpec):
            return NamedSharding(self.mesh, self.spec_for(s.axes, s.shape))
        return jax.tree_util.tree_map(
            f, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))

    # ----------------------------------------------------------- activations
    def activation_rules(self) -> Dict[str, Tuple[str, ...]]:
        seq = (("model",) if self.parallel.sequence_parallel
               and self.model_size > 1 else ())
        return {"act_batch": self.batch_axes, "act_seq": seq,
                "experts_ep": self.axis_map["experts"]}

    def batch_sharding(self, input_tree) -> Any:
        """Sharding for the train/serve input batch (dim 0 = global batch)."""
        def f(x):
            b = x.shape[0] if x.shape else 1
            size = int(np.prod([self.mesh.shape[a]
                                for a in self.batch_axes] or [1]))
            spec = [None] * len(x.shape)
            if x.shape and b % size == 0 and size > 1:
                spec[0] = (self.batch_axes if len(self.batch_axes) > 1
                           else self.batch_axes[0])
            return NamedSharding(self.mesh, P(*spec))
        return jax.tree_util.tree_map(f, input_tree)

    def cache_shardings(self, cache_tree, axes_tree=None) -> Any:
        """KV-cache / decode-state shardings from the model's logical
        ``cache_axes()`` tree.

        kv_heads -> 'model' when the head count divides; otherwise the
        *window* dim takes 'model' (distributed flash-decoding: XLA
        partial-softmaxes seq-sharded attention with small psums instead of
        gathering KV).  Batch -> DP axes when divisible (long_500k batch=1
        stays unsharded).
        """
        ms = self.model_size
        kv_ok = self.cfg.n_kv_heads % ms == 0 if ms else False

        def one(x, axes):
            spec: list = [None] * x.ndim
            used = set()
            win_dim = None
            for d, name in enumerate(axes or ()):
                if name == "act_batch":
                    size = int(np.prod([self.mesh.shape[a]
                                        for a in self.batch_axes] or [1]))
                    if x.shape[d] % size == 0 and size > 1:
                        spec[d] = (self.batch_axes
                                   if len(self.batch_axes) > 1
                                   else self.batch_axes[0])
                        used.update(self.batch_axes)
                elif name == "kv_heads":
                    if kv_ok and ms > 1 and "model" not in used:
                        spec[d] = "model"
                        used.add("model")
                elif name in ("ffn", "heads", "embed_dim"):
                    if (ms > 1 and "model" not in used
                            and x.shape[d] % ms == 0
                            and (name != "heads"
                                 or self.cfg.n_heads % ms == 0)):
                        spec[d] = "model"
                        used.add("model")
                elif name == "window":
                    win_dim = d
            if (win_dim is not None and "model" not in used and ms > 1
                    and x.shape[win_dim] % ms == 0):
                spec[win_dim] = "model"
            return NamedSharding(self.mesh, P(*spec))

        if axes_tree is None:
            return jax.tree_util.tree_map(
                lambda x: NamedSharding(self.mesh, P()), cache_tree)
        return jax.tree_util.tree_map(
            one, cache_tree, axes_tree,
            is_leaf=lambda a: isinstance(a, tuple) or a is None)

    def describe(self) -> Dict[str, Any]:
        return {
            "fsdp_axes": self.fsdp_axes,
            "batch_axes": self.batch_axes,
            "tp_heads": bool(self.axis_map["heads"]),
            "tp_kv_heads": bool(self.axis_map["kv_heads"]),
            "expert_parallel": bool(self.axis_map["experts"]),
            "sequence_parallel": bool(self.activation_rules()["act_seq"]),
        }


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
