"""Activation-sharding hooks (MaxText-style logical partitioning without flax).

Models call ``shard_residual(x)`` / ``constrain(x, *logical_axes)`` at key
points; outside a configured mesh context these are identity, so models stay
mesh-agnostic.  ``repro.parallel.sharding`` installs the active rule set
before tracing the distributed step.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def set_activation_rules(mesh, rules: Optional[dict]) -> None:
    _state.mesh = mesh
    _state.rules = rules


def clear_activation_rules() -> None:
    _state.mesh = None
    _state.rules = None


class activation_sharding:
    """Context manager installing activation rules for a trace."""

    def __init__(self, mesh, rules: dict):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        set_activation_rules(self.mesh, self.rules)
        return self

    def __exit__(self, *exc):
        clear_activation_rules()
        return False


def constrain(x, *logical_axes: Optional[str]):
    """Apply with_sharding_constraint mapping logical axis names via the
    installed rules.  Identity when no rules are installed."""
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", None)
    if mesh is None or rules is None:
        return x
    spec = []
    used = set()
    for name in logical_axes:
        axes = rules.get(name) if name else None
        if axes:
            axes = tuple(a for a in axes if a not in used)
        if not axes:
            spec.append(None)
            continue
        dim = x.shape[len(spec)]
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size:
            spec.append(None)
            continue
        used.update(axes)
        spec.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def shard_residual(x):
    """(batch, seq, embed) residual stream: batch over DP, seq over TP (SP)."""
    return constrain(x, "act_batch", "act_seq", None)


def data_extent() -> int:
    """Size of the data-parallel (batch) axes under the installed rules —
    1 when tracing without a mesh (single-host tests)."""
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", None)
    if mesh is None or rules is None:
        return 1
    n = 1
    for a in rules.get("act_batch", ()):
        n *= mesh.shape[a]
    return n
