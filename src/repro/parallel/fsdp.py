"""Distributed step builders: pjit'd train / prefill / decode steps.

FSDP (ZeRO-3) falls out of the sharding spec: weights sharded over the
'data' (+'pod') axes are all-gathered by XLA SPMD right before use and
gradients reduce-scattered right after — the C3 structure of paper Fig 2 on
TPU, overlapped by XLA's latency-hiding scheduler.  TP/SP come from the
'model'-axis rules and the residual-stream constraints.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models.common import abstract_params, init_params
from repro.parallel.act import activation_sharding
from repro.parallel.compression import (compressed_grad_tree,
                                        init_error_tree)
from repro.parallel.sharding import ShardingRules
from repro.train.optimizer import AdamWState, adamw_update, init_state


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    err: Optional[Any] = None          # grad-compression error feedback


def state_shardings(rules: ShardingRules, spec_tree,
                    with_err: bool) -> TrainState:
    p = rules.param_shardings(spec_tree)
    rep = NamedSharding(rules.mesh, P())
    opt = AdamWState(step=rep,
                     exp_avg=jax.tree_util.tree_map(lambda s: s, p),
                     exp_avg_sq=jax.tree_util.tree_map(lambda s: s, p))
    return TrainState(params=p, opt=opt, err=(p if with_err else None))


def build_train_step(model, train_cfg: TrainConfig, rules: ShardingRules,
                     parallel: ParallelConfig):
    """Returns (train_step jit'd, state_shardings, batch_shardings_fn)."""
    mesh = rules.mesh
    spec_tree = model.param_specs()
    compress = parallel.grad_compression == "int8"
    st_shard = state_shardings(rules, spec_tree, compress)
    rep = NamedSharding(mesh, P())

    def loss_fn(params, batch):
        with activation_sharding(mesh, rules.activation_rules()):
            return model.loss(params, batch)

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        # pin gradient layout to the (ZeRO) param shardings so the backward
        # data-axis psum lowers to reduce-scatter, not all-reduce+replicate
        grads = jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, st_shard.params)
        err = state.err
        if compress:
            grads, err = compressed_grad_tree(grads, err)
        params, opt, om = adamw_update(train_cfg, state.params, grads,
                                       state.opt)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return TrainState(params, opt, err), metrics

    step = jax.jit(
        train_step,
        in_shardings=(st_shard, None),
        out_shardings=(st_shard, rep),
        donate_argnums=(0,),
    )
    return step, st_shard


def init_train_state(model, rules: ShardingRules, parallel: ParallelConfig,
                     seed: int = 0) -> TrainState:
    """Shard-initialized state (each device materializes only its shard)."""
    spec_tree = model.param_specs()
    compress = parallel.grad_compression == "int8"
    st_shard = state_shardings(rules, spec_tree, compress)

    def make():
        params = init_params(spec_tree, jax.random.PRNGKey(seed))
        opt = init_state(params)
        err = init_error_tree(params) if compress else None
        return TrainState(params, opt, err)

    return jax.jit(make, out_shardings=st_shard)()


def abstract_train_state(model, parallel: ParallelConfig) -> TrainState:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    spec_tree = model.param_specs()
    params = abstract_params(spec_tree)
    zeros = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)
    opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                     exp_avg=zeros, exp_avg_sq=zeros)
    err = (zeros if parallel.grad_compression == "int8" else None)
    return TrainState(params, opt, err)


# --------------------------------------------------------------------------- #
# Serving steps
# --------------------------------------------------------------------------- #
def build_prefill_step(model, rules: ShardingRules):
    mesh = rules.mesh
    p_shard = rules.param_shardings(model.param_specs())

    def prefill(params, batch):
        with activation_sharding(mesh, rules.activation_rules()):
            return model.prefill(params, batch)

    return jax.jit(prefill, in_shardings=(p_shard, None)), p_shard


def build_decode_step(model, rules: ShardingRules, cache_abstract):
    """cache_abstract: ShapeDtypeStruct tree (from jax.eval_shape)."""
    mesh = rules.mesh
    p_shard = rules.param_shardings(model.param_specs())
    axes = model.cache_axes() if hasattr(model, "cache_axes") else None
    c_shard = rules.cache_shardings(cache_abstract, axes)

    def decode(params, tokens, cache):
        with activation_sharding(mesh, rules.activation_rules()):
            return model.decode_step(params, tokens, cache)

    step = jax.jit(decode,
                   in_shardings=(p_shard, None, c_shard),
                   out_shardings=(None, c_shard),
                   donate_argnums=(2,))
    return step, p_shard, c_shard
