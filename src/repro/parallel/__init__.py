import repro._jax_compat  # noqa: F401  (sharding-invariant RNG)
from repro.parallel.act import (activation_sharding, constrain,
                                shard_residual)
from repro.parallel.sharding import ShardingRules, replicated

__all__ = ["activation_sharding", "constrain", "shard_residual",
           "ShardingRules", "replicated"]
