"""Explicit collective schedules via shard_map: the manual counterpart to
XLA's auto-inserted FSDP collectives.

``ring_all_gather`` is the ppermute ring (what runs on the ICI torus);
``fsdp_ffn_prefetch`` demonstrates software-pipelined C3: the all-gather for
layer i+1's weights is issued *before* layer i's compute so the scheduler can
overlap them — the explicit form of the paper's Fig 2 overlap window.  Used
by the multi-device tests and as a §Perf A/B against the auto schedule.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def axis_size(axis_name: str) -> int:
    """Version-compat: ``jax.lax.axis_size`` only exists in newer jax; the
    ``psum(1, axis)`` idiom is constant-folded to the axis size everywhere."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_all_gather(x, axis_name: str):
    """All-gather along axis_name via a bidirectional-naive ppermute ring.

    x: local shard (..., d).  Returns (axis_size, ..., d) stacked gathers in
    ring order, rotated so index 0 is rank 0's shard.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        chunks.append(cur)
    stacked = jnp.stack(chunks, 0)                    # [my, my-1, my-2, ...]
    # rotate into rank order: chunk j holds shard (idx - j) mod n
    order = (idx - jnp.arange(n)) % n
    return jnp.zeros_like(stacked).at[order].set(stacked)


def fsdp_ffn_prefetch(x, w_stacked, mesh: Mesh, *, fsdp_axis: str = "data"):
    """Scan an L-layer FFN whose weights are FSDP-sharded over `fsdp_axis`,
    all-gathering layer i+1's weights while layer i computes.

    x: (B_local, d) activations (already sharded by caller via shard_map);
    w_stacked: (L, d/axis, d) local weight shards.  Double-buffered carry:
    (x, gathered weights for the next layer).
    """
    L = w_stacked.shape[0]

    def gather_w(wl):
        g = ring_all_gather(wl, fsdp_axis)            # (n, d/n, d)
        return g.reshape(-1, g.shape[-1])             # (d, d)

    def body(carry, wl_next):
        x, w_cur = carry
        w_nxt = gather_w(wl_next)     # issued before the matmul -> overlaps
        x = jax.nn.relu(x @ w_cur)
        return (x, w_nxt), None

    w0 = gather_w(w_stacked[0])
    (x, w_last), _ = jax.lax.scan(body, (x, w0), w_stacked[1:])
    x = jax.nn.relu(x @ w_last)
    return x


def make_fsdp_prefetch_fn(mesh: Mesh, fsdp_axis: str = "data"):
    """shard_map-wrapped explicit-overlap FFN chain (for tests / A-B)."""
    fn = partial(fsdp_ffn_prefetch, mesh=mesh, fsdp_axis=fsdp_axis)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(fsdp_axis, None), P(None, fsdp_axis, None)),
        out_specs=P(fsdp_axis, None),
        check_rep=False)
