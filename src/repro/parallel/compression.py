"""Gradient compression for the DCN (cross-pod) axis: int8 quantization with
error feedback.

On a 2-pod mesh the 'pod' all-reduce crosses data-center network at ~25x
less bandwidth than ICI; int8 (4x smaller than fp32, 2x vs bf16) with error
feedback (residual carried into the next step) preserves convergence.  Pure
functions here; ``compressed_psum`` wires them into a shard_map collective.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x, *, axis: int = -1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-slice int8 quantization.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad, error) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                 jnp.ndarray]:
    """grad + carried error -> (q, scale, new_error)."""
    g = grad.astype(jnp.float32) + error
    q, s = quantize_int8(g)
    new_error = g - dequantize_int8(q, s)
    return q, s, new_error


def compressed_grad_tree(grads, errors):
    """Tree-wide compression round-trip with error feedback.

    Simulates the lossy DCN all-reduce on any device count: the values that
    WOULD be summed across pods are the dequantized int8 payloads; the
    quantization residual feeds back into the next step.
    """
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_with_feedback(g, e)
        out_g.append(dequantize_int8(q, s).astype(g.dtype))
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(tdef, out_g),
            jax.tree_util.tree_unflatten(tdef, out_e))


def init_error_tree(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(x, axis_name: str):
    """int8 all-reduce over a mesh axis (inside shard_map): quantize, psum
    the int32-accumulated payload, dequantize with the summed scales.

    Exact for the scale handling used here (shared max-scale via psum-max):
    every participant quantizes against the same scale, so the sum of
    dequantized values equals the dequantized sum.
    """
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
