"""jit'd wrapper for the grouped-GEMM kernel."""
from __future__ import annotations

from repro.kernels.moe_gemm.kernel import moe_gemm_fwd

INTERPRET = True


def moe_gemm(x, w):
    """x: (E, C, d), w: (E, d, h) -> (E, C, h)."""
    return moe_gemm_fwd(x, w, interpret=INTERPRET)
