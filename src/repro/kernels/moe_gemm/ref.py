"""Pure-jnp oracle for the grouped (per-expert) GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def moe_gemm_ref(x, w):
    """x: (E, C, d), w: (E, d, h) -> (E, C, h)."""
    return jnp.einsum("ecd,edh->ech", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
