"""Pallas TPU grouped GEMM: the padded per-expert contraction of the MoE
block ((E,C,d) x (E,d,h) -> (E,C,h)) — the paper's §VII-C platform pads
expert GEMMs for balanced computation, which maps exactly to this kernel.

Grid: (E, C/bc, h/bh, d/bd); the contraction (d) dimension is 'arbitrary'
(sequential) with an fp32 VMEM accumulator; (bc, bd) x (bd, bh) tiles are
MXU-aligned 128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _mm_kernel(x_ref, w_ref, o_ref, acc, *, n_d):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(di == n_d - 1)
    def _final():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def _pad_dim(x, axis, m):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block_c", "block_h", "block_d",
                                              "interpret"))
def moe_gemm_fwd(x, w, *, block_c: int = 128, block_h: int = 128,
                 block_d: int = 512, interpret: bool = True):
    """x: (E, C, d), w: (E, d, h) -> (E, C, h)."""
    E, C, d = x.shape
    h = w.shape[2]
    block_c = min(block_c, max(8, 1 << (C - 1).bit_length()))
    block_h = min(block_h, max(8, 1 << (h - 1).bit_length()))
    block_d = min(block_d, max(8, 1 << (d - 1).bit_length()))
    xp = _pad_dim(_pad_dim(x, 1, block_c), 2, block_d)
    wp = _pad_dim(_pad_dim(w, 1, block_d), 2, block_h)
    Cp, dp, hp = xp.shape[1], xp.shape[2], wp.shape[2]
    n_c, n_h, n_d = Cp // block_c, hp // block_h, dp // block_d

    out = pl.pallas_call(
        functools.partial(_mm_kernel, n_d=n_d),
        grid=(E, n_c, n_h, n_d),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, i, j, kk: (e, i, kk)),
            pl.BlockSpec((1, block_d, block_h),
                         lambda e, i, j, kk: (e, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_h),
                               lambda e, i, j, kk: (e, i, j)),
        scratch_shapes=[pltpu.VMEM((block_c, block_h), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((E, Cp, hp), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(xp, wp)
    return out[:, :C, :h]
