"""jit'd wrapper for the chunked WKV6 kernel."""
from __future__ import annotations

from repro.kernels.rwkv6_wkv.kernel import wkv6_fwd

INTERPRET = True


def wkv6(r, k, v, w_log, u, *, chunk: int = 64):
    return wkv6_fwd(r, k, v, w_log, u, chunk=chunk, interpret=INTERPRET)
