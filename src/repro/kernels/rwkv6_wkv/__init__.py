from repro.kernels.rwkv6_wkv.ops import wkv6
from repro.kernels.rwkv6_wkv.ref import wkv6_ref

__all__ = ["wkv6", "wkv6_ref"]
