"""Pure-jnp oracle for the WKV6 recurrence: exact sequential scan.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w_log, u, state=None):
    """r,k,v,w_log: (B, S, H, D); u: (H, D) -> (y (B,S,H,D), S (B,H,D,D))."""
    B, S, H, D = r.shape
    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    def step(Sm, inp):
        rr, kk, vv, ww = (x.astype(jnp.float32) for x in inp)   # (B,H,D)
        kv = kk[..., :, None] * vv[..., None, :]
        y = jnp.einsum("bhd,bhde->bhe", rr,
                       Sm + u.astype(jnp.float32)[None, :, :, None] * kv)
        Sm = Sm * jnp.exp(ww)[..., None] + kv
        return Sm, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w_log))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state
