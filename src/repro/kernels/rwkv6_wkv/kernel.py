"""Pallas TPU chunked WKV6 kernel.

Grid: (B*H, n_chunks) with the chunk dimension 'arbitrary' (sequential);
the (D, D) fp32 recurrent state lives in VMEM scratch across chunks.  Each
step processes an (L, D) tile of r/k/v/log-decay: the intra-chunk pairwise
decay matrix is built from cumulative log-decays (all exponents <= 0 —
numerically safe), the inter-chunk part is one (L,D)x(D,D) matmul against
the carried state.  This is the TPU-native adaptation of the GPU recurrence:
sequential over chunks to keep the state resident, parallel over B*H.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_scr,
                *, L, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)               # (L, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)               # log decay, < 0
    u = u_ref[0].astype(jnp.float32)               # (1, D) block -> (D,)

    cw = jnp.cumsum(w, axis=0)                     # inclusive
    cwx = cw - w                                   # exclusive
    S_prev = s_scr[...]

    # inter-chunk: y_i += (r_i * exp(cwx_i)) @ S_prev
    y = jax.lax.dot(r * jnp.exp(cwx), S_prev,
                    preferred_element_type=jnp.float32)

    # intra-chunk: A_ij = sum_d r_i k_j exp(cwx_i - cw_j), strictly lower
    expo = cwx[:, None, :] - cw[None, :, :]        # (L, L, D)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    pair = jnp.where(tri[..., None], jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
    A = jnp.einsum("id,jd,ijd->ij", r, k, pair)
    diag = jnp.sum(r * u * k, axis=-1)             # u-weighted current token
    y = y + jax.lax.dot(A, v, preferred_element_type=jnp.float32) \
        + diag[:, None] * v

    # state update: S = diag(exp(cw_L)) S + sum_j (k_j exp(cw_L - cw_j))^T v_j
    k_scaled = k * jnp.exp(cw[-1:] - cw)
    s_scr[...] = S_prev * jnp.exp(cw[-1])[:, None] + jax.lax.dot(
        k_scaled.T, v, preferred_element_type=jnp.float32)

    o_ref[0] = y.astype(o_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        s_out_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_fwd(r, k, v, w_log, u, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,w_log: (B,S,H,D); u: (H,D) -> (y (B,S,H,D), state (B,H,D,D))."""
    B, S, H, D = r.shape
    L = min(chunk, S)
    pad = (-S) % L
    n = (S + pad) // L

    def prep(x):
        x = x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        return x

    rf, kf, vf = prep(r), prep(k), prep(v)
    # padded steps: w_log = 0 (identity decay), k = 0 (no contribution)
    wf = prep(w_log)
    if pad:
        valid = (jnp.arange(S + pad) < S)[None, :, None]
        wf = jnp.where(valid, wf, 0.0)
        kf = jnp.where(valid, kf, 0.0)
    # u per (b,h) row: layout must match prep()'s (B*H) ordering
    uf = jnp.broadcast_to(u[None], (B, H, D)).reshape(B * H, D)

    spec_t = pl.BlockSpec((1, L, D), lambda b, c: (b, c, 0))
    out, s_out = pl.pallas_call(
        functools.partial(_wkv_kernel, L=L, n_chunks=n),
        grid=(B * H, n),
        in_specs=[spec_t, spec_t, spec_t, spec_t,
                  pl.BlockSpec((1, D), lambda b, c: (b, 0))],
        out_specs=[spec_t,
                   pl.BlockSpec((1, D, D), lambda b, c: (b, 0, 0))],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        out_shape=[jax.ShapeDtypeStruct((B * H, S + pad, D), r.dtype),
                   jax.ShapeDtypeStruct((B * H, D, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    y = out[:, :S].reshape(B, H, S, D).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, D, D)
