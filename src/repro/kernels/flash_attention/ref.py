"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        mask=None):
    """q: (BH, Sq, D), k/v: (BH, Sk, D) -> (BH, Sq, D).  fp32 softmax."""
    Sq, Sk = q.shape[1], k.shape[1]
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if mask is None:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Sk)[None, :]
        m = jnp.ones((Sq, Sk), bool)
        if causal:
            m &= ki <= qi
        if window:
            m &= ki > qi - window
        mask = m
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)
