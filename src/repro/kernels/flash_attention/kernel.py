"""Pallas TPU flash attention (forward): online-softmax over K blocks.

Grid: (batch*heads, q_blocks, k_blocks) with the k dimension 'arbitrary'
(sequential) — running max / normalizer / output accumulator live in VMEM
scratch across k steps.  BlockSpecs tile Q/K/V as (1, block, D) VMEM slabs;
block sizes default to MXU-aligned 128/512.  Causal + sliding-window masks
are generated from block indices (no mask tensor in HBM); an optional
explicit 2-D mask is streamed in (block_q, block_k) tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale, causal, window, block_q, block_k, n_k):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (block_q, D)
    k = k_ref[0]                                   # (block_k, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _final():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _fa_kernel_masked(q_ref, k_ref, v_ref, mask_ref, o_ref,
                      m_scr, l_scr, acc_scr, *, scale, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = jax.lax.dot_general(q_ref[0], k_ref[0], (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask_ref[...], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _final():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _pad_to(x, axis, m):
    r = x.shape[axis] % m
    if r == 0:
        return x, 0
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad), m - r


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_fwd(q, k, v, mask=None, *, causal: bool = True,
                        window: int = 0, block_q: int = 128,
                        block_k: int = 512, interpret: bool = True):
    """q: (BH, Sq, D); k/v: (BH, Sk, D); mask: optional (Sq, Sk) bool."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, max(8, 1 << (Sq - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (Sk - 1).bit_length()))
    q, padq = _pad_to(q, 1, block_q)
    k, padk = _pad_to(k, 1, block_k)
    v, _ = _pad_to(v, 1, block_k)
    Sqp, Skp = q.shape[1], k.shape[1]
    n_q, n_k = Sqp // block_q, Skp // block_k
    scale = D ** -0.5

    scratch = [pltpu.VMEM((block_q, 1), jnp.float32),
               pltpu.VMEM((block_q, 1), jnp.float32),
               pltpu.VMEM((block_q, D), jnp.float32)]
    grid = (BH, n_q, n_k)
    qspec = pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0))
    kspec = pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0))
    ospec = pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0))
    params = CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))

    if mask is not None:
        mask = jnp.pad(mask, ((0, Sqp - mask.shape[0]),
                              (0, Skp - mask.shape[1])))
        mspec = pl.BlockSpec((block_q, block_k), lambda b, i, j: (i, j))
        kern = functools.partial(_fa_kernel_masked, scale=scale, n_k=n_k)
        out = pl.pallas_call(
            kern, grid=grid, in_specs=[qspec, kspec, kspec, mspec],
            out_specs=ospec, scratch_shapes=scratch,
            out_shape=jax.ShapeDtypeStruct((BH, Sqp, D), q.dtype),
            compiler_params=params, interpret=interpret,
        )(q, k, v, mask)
    else:
        # padded K rows must be masked out: extend window/causal masks
        kern = functools.partial(
            _fa_kernel, scale=scale,
            causal=causal or padk > 0, window=window, block_q=block_q,
            block_k=block_k, n_k=n_k)
        if not causal and padk > 0:
            # bidirectional with padding: use explicit mask path
            m = jnp.ones((Sq, Sk), bool)
            return flash_attention_fwd(
                q[:, :Sq], k[:, :Sk], v[:, :Sk], m, causal=False,
                window=0, block_q=block_q, block_k=block_k,
                interpret=interpret)
        out = pl.pallas_call(
            kern, grid=grid, in_specs=[qspec, kspec, kspec],
            out_specs=ospec, scratch_shapes=scratch,
            out_shape=jax.ShapeDtypeStruct((BH, Sqp, D), q.dtype),
            compiler_params=params, interpret=interpret,
        )(q, k, v)
    return out[:, :Sq]
