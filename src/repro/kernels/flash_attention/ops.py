"""jit'd model-facing wrapper: GQA layout handling around the Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref

INTERPRET = True  # CPU container: interpret mode; False on real TPU


def flash_attention(q, k, v, mask=None, *, causal=None, window: int = 0):
    """q: (B, Sq, H, D), k/v: (B, Sk, kvH, D) -> (B, Sq, H, D).

    mask: None or broadcastable bool whose last two dims are (Sq, Sk).
    Sq == 1 (decode) falls back to the jnp oracle — a single-token matvec
    doesn't benefit from a blocked kernel.
    """
    B, Sq, H, D = q.shape
    Sk, kvH = k.shape[1], k.shape[2]
    if kvH != H:
        rep = H // kvH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)

    mask2d = None
    if mask is not None:
        m = jnp.asarray(mask)
        m = jnp.broadcast_to(m, m.shape[:-2] + (Sq, Sk))
        if m.ndim > 2 and all(s == 1 for s in m.shape[:-2]):
            m = m.reshape(Sq, Sk)
        if m.ndim == 2:
            mask2d = m
        else:                                  # per-batch/head masks: oracle
            out = flash_attention_ref(qf, kf, vf, causal=False,
                                      mask=m.reshape(-1, Sq, Sk))
            return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)

    if Sq == 1:
        out = flash_attention_ref(qf, kf, vf, causal=False, mask=mask2d)
    else:
        out = flash_attention_fwd(
            qf, kf, vf, mask2d,
            causal=bool(causal) if causal is not None else False,
            window=window, interpret=INTERPRET)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
