"""Pallas TPU fused RMSNorm (+ optional residual add).

Grid over row blocks; each step holds an (block_rows, d) VMEM slab, computes
the fp32 mean-square on-chip and writes the scaled rows — one HBM round trip
instead of norm + mul + (add) separately.  The paper's Fig 4 profiles RMSNorm
among the dominant kernels; the fused form is the standard TPU treatment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rms_res_kernel(x_ref, r_ref, w_ref, o_ref, res_ref, *, eps):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = x.astype(res_ref.dtype)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fwd(x, w, residual=None, *, eps: float = 1e-5,
                block_rows: int = 256, interpret: bool = True):
    """x: (..., d); w: (d,).  Optional fused residual add."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows
    xspec = pl.BlockSpec((block_rows, d), lambda i: (i, 0))
    wspec = pl.BlockSpec((d,), lambda i: (0,))

    if residual is None:
        out = pl.pallas_call(
            functools.partial(_rms_kernel, eps=eps), grid=(n,),
            in_specs=[xspec, wspec], out_specs=xspec,
            out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
            interpret=interpret)(xf, w)
        return out[:R].reshape(shape)

    rf = residual.reshape(-1, d)
    if pad:
        rf = jnp.pad(rf, ((0, pad), (0, 0)))
    out, res = pl.pallas_call(
        functools.partial(_rms_res_kernel, eps=eps), grid=(n,),
        in_specs=[xspec, xspec, wspec], out_specs=[xspec, xspec],
        out_shape=[jax.ShapeDtypeStruct(xf.shape, x.dtype),
                   jax.ShapeDtypeStruct(xf.shape, x.dtype)],
        interpret=interpret)(xf, rf, w)
    return out[:R].reshape(shape), res[:R].reshape(shape)
