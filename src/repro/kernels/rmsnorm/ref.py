"""Pure-jnp oracle for fused RMSNorm (optionally with residual add)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, w, residual=None, eps: float = 1e-5):
    """x: (..., d).  Returns normalized x (and the post-add residual).

    The residual add happens in fp32 (matching the fused kernel) and the
    stored residual is rounded back to the input dtype.
    """
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    y = (y * w.astype(jnp.float32)).astype(dt)
    if residual is not None:
        return y, xf.astype(dt)
    return y
