"""jit'd wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

from repro.kernels.rmsnorm.kernel import rmsnorm_fwd

INTERPRET = True


def rmsnorm(x, w, residual=None, eps: float = 1e-5):
    return rmsnorm_fwd(x, w, residual, eps=eps, interpret=INTERPRET)
