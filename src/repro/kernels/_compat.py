"""Pallas API-drift shims shared by the TPU kernels.

``pltpu.CompilerParams`` is the current name of what older jax releases
(<0.5, e.g. the 0.4.x in this container) call ``TPUCompilerParams``; the
constructor fields used here (dimension_semantics, vmem_limit_bytes,
has_side_effects) are identical across the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
