"""Whisper-style encoder-decoder backbone.  The conv/mel frontend is a STUB
per the assignment: inputs are precomputed frame embeddings (B, Sf, frame_dim)
projected into d_model.  Decoder = causal self-attn + cross-attn + MLP;
decode carries a self-KV ring/full cache plus precomputed cross-KV.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ParamSpec, apply_norm, cross_entropy_loss,
                                 norm_spec, pad_vocab, stack_specs,
                                 take_embedding)
from repro.models.mlp import mlp, mlp_specs
from repro.parallel.act import shard_residual
from repro.models.transformer import REMAT_POLICIES


class EncDecLM:
    def __init__(self, cfg, *, max_cache_len: int = 0,
                 remat: str = "nothing", scan_layers: bool = True):
        self.cfg = cfg
        self.vp = pad_vocab(cfg.vocab_size)
        self.max_cache_len = max_cache_len or cfg.max_seq_len
        self.remat = remat
        self.scan_layers = scan_layers

    # ----------------------------------------------------------------- specs
    def _enc_block_specs(self):
        cfg = self.cfg
        return {"ln1": norm_spec(cfg, cfg.d_model),
                "attn": attn.attn_specs(cfg),
                "ln2": norm_spec(cfg, cfg.d_model),
                "ffn": mlp_specs(cfg, cfg.d_ff)}

    def _dec_block_specs(self):
        cfg = self.cfg
        return {"ln1": norm_spec(cfg, cfg.d_model),
                "self_attn": attn.attn_specs(cfg),
                "ln_x": norm_spec(cfg, cfg.d_model),
                "cross_attn": attn.attn_specs(cfg, kv_src_dim=cfg.d_model),
                "ln2": norm_spec(cfg, cfg.d_model),
                "ffn": mlp_specs(cfg, cfg.d_ff)}

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        a = cfg.audio
        return {
            "audio_proj": ParamSpec((a.frame_dim, cfg.d_model),
                                    (None, "embed")),       # conv-stub proj
            "enc_pos": ParamSpec((a.frame_seq, cfg.d_model), (None, "embed"),
                                 "embed"),
            "enc": stack_specs(self._enc_block_specs(), cfg.enc_layers),
            "enc_norm": norm_spec(cfg, cfg.d_model),
            "embed": ParamSpec((self.vp, cfg.d_model), ("vocab", "embed"),
                               "embed"),
            "dec_pos": ParamSpec((self.max_cache_len, cfg.d_model),
                                 (None, "embed"), "embed"),
            "dec": stack_specs(self._dec_block_specs(), cfg.n_layers),
            "final_norm": norm_spec(cfg, cfg.d_model),
        }

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.compute_dtype)) \
            @ params["audio_proj"].astype(jnp.dtype(cfg.compute_dtype))
        x = x + params["enc_pos"][: x.shape[1]].astype(x.dtype)

        def body(x, lp):
            x = shard_residual(x)
            h = apply_norm(cfg, lp["ln1"], x)
            x = x + attn.attention(cfg, lp["attn"], h, None, None, causal=False)
            h = apply_norm(cfg, lp["ln2"], x)
            return shard_residual(x + mlp(cfg, lp["ffn"], h)), None

        body = jax.checkpoint(body, policy=REMAT_POLICIES[self.remat],
                              prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc"])
        return apply_norm(cfg, params["enc_norm"], x)

    # --------------------------------------------------------------- decoder
    def _dec_body(self, lp, x, enc_out, mask, pos_offset_mask=None):
        cfg = self.cfg
        x = shard_residual(x)
        h = apply_norm(cfg, lp["ln1"], x)
        x = x + attn.attention(cfg, lp["self_attn"], h, None, None,
                               causal=True)
        h = apply_norm(cfg, lp["ln_x"], x)
        x = x + attn.attention(cfg, lp["cross_attn"], h, None, None,
                               kv_x=enc_out, causal=False)
        h = apply_norm(cfg, lp["ln2"], x)
        return x + mlp(cfg, lp["ffn"], h)

    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = take_embedding(params["embed"], tokens).astype(enc_out.dtype)
        x = x + params["dec_pos"][:S].astype(x.dtype)
        def body(x, lp):
            return self._dec_body(lp, x, enc_out, None), None

        body = jax.checkpoint(body, policy=REMAT_POLICIES[self.remat],
                              prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["dec"])
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["embed"].T.astype(x.dtype)   # whisper ties head
        if self.vp != cfg.vocab_size:
            logits = jnp.where(jnp.arange(self.vp) < cfg.vocab_size,
                               logits, -1e30)
        return logits

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        loss, metrics = cross_entropy_loss(logits, batch["labels"])
        return loss, metrics

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
        cfg = self.cfg
        W = self.max_cache_len
        shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.head_dim)
        xshape = (cfg.n_layers, batch, cfg.audio.frame_seq, cfg.n_kv_heads,
                  cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype),
                "pos": jnp.zeros((), jnp.int32)}

    def cache_axes(self):
        kv = ("layers", "act_batch", "window", "kv_heads", None)
        return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": ()}

    def prefill(self, params, batch, cache=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        if cache is None:
            cache = self.init_cache(B)
        enc_out = self.encode(params, batch["frames"])
        x = take_embedding(params["embed"], tokens).astype(enc_out.dtype)
        x = x + params["dec_pos"][:S].astype(x.dtype)
        def body(x, lp):
            h = apply_norm(cfg, lp["ln1"], x)
            k, v = attn.project_kv(cfg, lp["self_attn"], h, None)
            q = attn.project_q(cfg, lp["self_attn"], h, None)
            a = attn.sdpa_auto(q, k, v, causal=True).reshape(B, S, cfg.q_dim)
            x = x + a @ lp["self_attn"]["wo"].astype(x.dtype)
            h = apply_norm(cfg, lp["ln_x"], x)
            xk, xv = attn.project_kv(cfg, lp["cross_attn"], enc_out, None)
            qx = attn.project_q(cfg, lp["cross_attn"], h, None)
            a = attn.sdpa_auto(qx, xk, xv, causal=False).reshape(B, S, cfg.q_dim)
            x = x + a @ lp["cross_attn"]["wo"].astype(x.dtype)
            h = apply_norm(cfg, lp["ln2"], x)
            return x + mlp(cfg, lp["ffn"], h), {"k": k, "v": v,
                                                "xk": xk, "xv": xv}

        x, ys = jax.lax.scan(body, x, params["dec"])
        W = self.max_cache_len
        pad = ((0, 0), (0, 0), (0, W - S), (0, 0), (0, 0))
        cache = dict(cache)
        cache["k"] = jnp.pad(ys["k"], pad).astype(cache["k"].dtype)
        cache["v"] = jnp.pad(ys["v"], pad).astype(cache["v"].dtype)
        cache["xk"] = ys["xk"].astype(cache["xk"].dtype)
        cache["xv"] = ys["xv"].astype(cache["xv"].dtype)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        x = take_embedding(params["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype))
        pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)
        x = x + pe.astype(x.dtype)

        def body(x, xs):
            lp, kc, vc, xk, xv = xs
            h = apply_norm(cfg, lp["ln1"], x)
            a, kc, vc = attn.decode_attention(cfg, lp["self_attn"], h, pos,
                                              kc, vc, ring=False)
            x = x + a
            h = apply_norm(cfg, lp["ln_x"], x)
            q = attn.project_q(cfg, lp["cross_attn"], h, None)
            a = attn.sdpa(q, xk, xv, None).reshape(B, 1, cfg.q_dim)
            x = x + a @ lp["cross_attn"]["wo"].astype(x.dtype)
            h = apply_norm(cfg, lp["ln2"], x)
            return x + mlp(cfg, lp["ffn"], h), {"k": kc, "v": vc}

        x, ys = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        cache = dict(cache)
        cache["k"], cache["v"] = ys["k"], ys["v"]
        cache["pos"] = pos + 1
        return self._logits(params, x), cache
